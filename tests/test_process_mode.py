"""Process-per-group execution (``mode="process"``): real OS processes per
operator group, SIGKILL-based failure injection, non-blocking warm restart
of only the failed group, dynamic scaling on live workers, and true
``kill -9`` of the whole engine tree on durable epoch-flushing stores.

Every injected crash in process mode is a genuine ``kill -9`` of the
worker (the injector RPC answers ``("crash",)`` and the worker SIGKILLs
itself), so this matrix exercises the recovery algorithms across actual
process death — volatile state loss is enforced by the OS, not simulated.
"""
import os
import signal
import sqlite3
import subprocess
import sys
import time
from functools import partial

import pytest

from repro.core import (Engine, FailureInjector, GeneratorSource,
                        MapOperator, Pipeline, ReadSource, TerminalSink)
from repro.core.scaling import Controller, DispatcherOperator, MergerOperator
from tests.helpers import (FileExternalSystem, double_v, linear_pipeline,
                           mk_store, sink_outputs)

# several tests here budget waits beyond the global 120s pytest-timeout
# (boot polling + eng.wait(90..150) on loaded runners); 300s still fails a
# genuine hang long before the 30-minute job timeout
pytestmark = pytest.mark.timeout(300)

# the sqlite family is the deployment target: one durable store shared by
# every worker process (plain, group-commit, and sharded+group with the
# global flush-epoch 2PC)
SQLITE_SPECS = ["sqlite", "sqlite+group", "sqlite+sharded+group"]


def _mk(spec):
    return mk_store(spec, shards=3, batch_size=4, interval=0.001)


def _run(build, expected, spec, plan, timeout=60.0, require_fired=True,
         transport="routed", ctx=None):
    inj = FailureInjector(plan)
    eng = Engine(build(), mode="process", store=_mk(spec), injector=inj,
                 transport=transport, ctx=ctx, restart_delay=0.02)
    eng.start()
    ok = eng.wait(timeout)
    eng.stop()
    assert ok, (spec, plan)
    assert sink_outputs(eng) == expected, (spec, plan)
    win_writes = [b for b in eng.external.committed()
                  if isinstance(b, dict) and "inset" in b]
    assert len(win_writes) == 5, (spec, plan)
    if require_fired:        # every plan entry SIGKILL'd a live worker
        assert eng.failures == len(plan), (spec, plan)
    else:
        assert eng.failures == len(inj.fired), (spec, plan)
    return eng


# one crash point per protocol phase x operator role — each case SIGKILLs
# a live worker there and requires exactly-once completion
MATRIX = [
    ("src", "source_post_log", 2),
    ("map", "pre_state_update", 2),
    ("map", "post_send", 1),
    ("win", "post_ack_log", 2),
    ("win", "pre_log", 1),
    ("win", "post_log", 2),
    ("sink", "pre_write", 1),
    ("sink", "post_write_pre_done", 2),
]


@pytest.mark.parametrize("spec", SQLITE_SPECS)
@pytest.mark.parametrize("op_id,point,nth", MATRIX)
def test_sigkill_recovery_matrix(op_id, point, nth, spec, proc_transport,
                                 proc_ctx):
    build, expected = linear_pipeline(writes=1)
    _run(build, expected, spec, [(op_id, point, nth)],
         transport=proc_transport, ctx=proc_ctx)


@pytest.mark.slow
@pytest.mark.parametrize("spec", SQLITE_SPECS)
@pytest.mark.parametrize("op_id", ["src", "map", "win", "sink"])
@pytest.mark.parametrize("point", ["source_pre_log", "source_post_log",
                                   "pre_filter", "pre_state_update",
                                   "post_ack_log", "pre_log", "post_log",
                                   "post_send", "pre_write",
                                   "post_write_pre_done"])
def test_sigkill_recovery_matrix_full(op_id, point, spec, proc_transport,
                                      proc_ctx):
    """Nightly: the full crash-point matrix under real process death.
    Combos whose point never fires for that operator (e.g. a map has no
    write actions) degenerate to failure-free runs, as in the step-mode
    matrix."""
    build, expected = linear_pipeline(writes=1)
    _run(build, expected, spec, [(op_id, point, 2)], require_fired=False,
         transport=proc_transport, ctx=proc_ctx)


def test_multiple_worker_kills(store_spec, proc_transport, proc_ctx):
    """Two distinct groups SIGKILL'd in one run (Case 3 of the proof),
    against the LOGIO_STORE_SPEC-selected backends."""
    build, expected = linear_pipeline(writes=1)
    _run(build, expected, store_spec,
         [("map", "post_ack_log", 2), ("win", "pre_log", 1)],
         transport=proc_transport, ctx=proc_ctx)


def test_nonblocking_recovery_other_groups_advance(proc_transport, proc_ctx):
    """Kill one group mid-stream; the other workers keep processing while
    it restarts (the paper's non-blocking property across processes). The
    credit windows (default channel capacity) absorb the burst, so the
    source advances without the supervisor buffering unboundedly."""
    build, expected = linear_pipeline(n_events=200, window=4,
                                      sink_target=50, writes=1, rate=0.005)
    eng = Engine(build(), mode="process", store=_mk("sqlite+sharded+group"),
                 transport=proc_transport, ctx=proc_ctx, restart_delay=0.3)
    eng.start()
    # wait for steady state first: spawn-context workers boot a fresh
    # interpreter each, so a fixed post-start sleep is ctx-dependent
    boot_deadline = time.time() + 30.0
    while eng.metrics().op("src").processed < 10:
        assert time.time() < boot_deadline, "pipeline never started"
        time.sleep(0.01)
    before = eng.metrics().op("src").processed
    eng.kill_group("win")
    # poll inside the restart_delay window (win is down): the source must
    # advance at some point — a single fixed-time sample is too brittle
    # under CI scheduling load
    deadline = time.time() + 0.25
    during = before
    while during <= before and time.time() < deadline:
        during = eng.metrics().op("src").processed
        time.sleep(0.005)
    assert eng.wait(90)
    eng.stop()
    assert during > before, "source stalled while win was down"
    assert eng.failures >= 1
    assert sink_outputs(eng) == expected


def _mk_replica(rid):
    """Picklable replica factory (spawn-safe) for the scaling tests."""
    return partial(MapOperator, rid, fn=double_v, processing_time=0.004)


def _replica_pipeline(n):
    def build():
        p = Pipeline()
        p.add(partial(GeneratorSource, "src",
                      ReadSource([{"v": i} for i in range(n)]), rate=0.002))
        p.add(partial(DispatcherOperator, "disp", ["r0", "r1"]))
        p.add(_mk_replica("r0"))
        p.add(_mk_replica("r1"))
        p.add(partial(MergerOperator, "mrg", ["r0", "r1"]))
        p.add(partial(TerminalSink, "sink", target=n))
        p.connect("src", "out", "disp", "in")
        p.connect("disp", "to_r0", "r0", "in")
        p.connect("disp", "to_r1", "r1", "in")
        p.connect("r0", "out", "mrg", "from_r0")
        p.connect("r1", "out", "mrg", "from_r1")
        p.connect("mrg", "out", "sink", "in")
        return p
    return build


def test_scaling_on_live_workers(proc_transport, proc_ctx):
    """Algorithms 12-13 against live worker processes: scale up a new
    replica process mid-run, then scale one down; replicas + source + sink
    keep their processes throughout. The transports re-grant / rebuild the
    credit windows of the rewired channels on replica add/remove."""
    n = 60
    eng = Engine(_replica_pipeline(n)(), mode="process",
                 transport=proc_transport, ctx=proc_ctx, restart_delay=0.02)
    ctrl = Controller(eng, "disp", "mrg", replica_factory=_mk_replica)
    eng.start()
    time.sleep(0.3)
    ctrl.scale_up("r2")
    time.sleep(0.3)
    ctrl.scale_down("r1")
    assert eng.wait(90)
    eng.stop()
    assert sorted(b["v"] for b in eng.external.committed()) == \
        sorted(2 * i for i in range(n))


def test_scaling_with_worker_kill(proc_transport, proc_ctx):
    """A replica worker SIGKILL'd while another is being scaled in."""
    n = 60
    inj = FailureInjector([("r0", "post_log", 3)])
    eng = Engine(_replica_pipeline(n)(), mode="process", injector=inj,
                 transport=proc_transport, ctx=proc_ctx, restart_delay=0.02)
    ctrl = Controller(eng, "disp", "mrg", replica_factory=_mk_replica)
    eng.start()
    time.sleep(0.25)
    ctrl.scale_up("r2")
    assert eng.wait(90)
    eng.stop()
    assert sorted(b["v"] for b in eng.external.committed()) == \
        sorted(2 * i for i in range(n))
    assert eng.failures >= 1


# ---------------------------------------------------------------------------
# True kill -9 of the WHOLE engine process tree (supervisor + workers):
# exactly the unflushed/uncommitted epochs are lost; a warm restart on the
# surviving durable files replays to the correct state.
# ---------------------------------------------------------------------------

def _committed_epochs(db_path):
    ep = f"{db_path}.epochs"
    if not os.path.exists(ep):
        return set()
    conn = sqlite3.connect(ep)
    try:
        return {r[0] for r in conn.execute("SELECT epoch_id FROM epochs")}
    finally:
        conn.close()


def _shard_files(db_path, spec):
    if "sharded" in spec:
        return [p for p in
                (f"{db_path}.shard{i}" for i in range(8))
                if os.path.exists(p)]
    return [db_path] if os.path.exists(db_path) else []


@pytest.mark.parametrize("spec", ["sqlite+group", "sqlite+sharded+group"])
@pytest.mark.parametrize("kill_after", [0.25, 0.6])
def test_kill9_whole_engine_loses_exactly_unflushed_epoch(spec, kill_after,
                                                          tmp_path,
                                                          proc_transport,
                                                          proc_ctx):
    db_path = str(tmp_path / "log.db")
    ext_path = str(tmp_path / "external.bin")
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo_root, "src"), repo_root]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.Popen(
        [sys.executable, os.path.join(repo_root, "tests", "kill9_runner.py"),
         spec, db_path, ext_path, proc_transport, proc_ctx],
        stdout=subprocess.PIPE, env=env, start_new_session=True)
    try:
        assert proc.stdout.readline().strip() == b"READY"
        time.sleep(kill_after)
    finally:
        # kill -9 the whole session: supervisor AND workers, no cleanup
        try:
            os.killpg(os.getpgid(proc.pid), signal.SIGKILL)
        except ProcessLookupError:
            pass
        proc.wait()

    # 1) the unflushed epoch is lost, atomically: every epoch-tagged WAL
    #    row that survived belongs to a committed epoch — after the store
    #    reopens (running the restart rollback), no row of an uncommitted
    #    epoch remains in ANY shard (nothing is half-durable).
    committed = _committed_epochs(db_path)
    store = mk_store(spec, path=db_path, shards=3, batch_size=4,
                     interval=60.0)
    for f in _shard_files(db_path, spec):
        conn = sqlite3.connect(f)
        try:
            leftover = [e for (e,) in conn.execute(
                "SELECT DISTINCT epoch FROM wal_ops WHERE epoch IS NOT NULL")]
        finally:
            conn.close()
        assert all(e in committed for e in leftover), (f, leftover, committed)

    # 2) warm restart on the recovered store + surviving external system
    #    replays to the correct state — exactly-once.
    build, expected = linear_pipeline(writes=1, rate=0.01)
    eng = Engine(build(), mode="process", store=store,
                 external=FileExternalSystem(ext_path), resume=True,
                 transport=proc_transport, ctx=proc_ctx,
                 restart_delay=0.01)
    eng.start()
    ok = eng.wait(90)
    eng.stop()
    assert ok
    assert sink_outputs(eng) == expected
    win_writes = [b for b in eng.external.committed()
                  if isinstance(b, dict) and "inset" in b]
    assert len(win_writes) == 5


# ---------------------------------------------------------------------------
# Credit-based back-pressure: a slow consumer bounds every buffer at the
# credit window instead of growing supervisor (or sender) memory.
# ---------------------------------------------------------------------------

def _ident(b):
    return b


def _bp_pipeline(n, window, sink_pt):
    def build():
        p = Pipeline()
        p.add(partial(GeneratorSource, "src",
                      ReadSource([{"v": i} for i in range(n)])))
        p.add(partial(MapOperator, "map", fn=_ident))
        p.add(partial(TerminalSink, "sink", target=n,
                      processing_time=sink_pt))
        p.connect("src", "out", "map", "in", capacity=window)
        p.connect("map", "out", "sink", "in", capacity=window)
        return p
    return build


def test_backpressure_bounds_buffers(proc_transport, proc_ctx):
    """Fast producer, slow consumer, tiny credit window: the supervisor's
    authoritative buffers never exceed the window (routed) / never hold an
    event at all (socket — payloads bypass the supervisor), and the run
    still completes exactly-once."""
    import threading
    n, window = 120, 8
    eng = Engine(_bp_pipeline(n, window, 0.002)(), mode="process",
                 transport=proc_transport, ctx=proc_ctx,
                 store=mk_store("memory"))
    eng.start()
    peak = [0]

    def watch():
        while not eng._done.is_set():
            peak[0] = max(peak[0],
                          max((len(c) for c in eng.channels), default=0))
            time.sleep(0.002)
    t = threading.Thread(target=watch, daemon=True)
    t.start()
    ok = eng.wait(90)
    t.join(timeout=5.0)
    eng.stop()
    assert ok
    assert len(sink_outputs(eng)) == n
    limit = 0 if proc_transport in ("socket", "tcp") else window
    assert peak[0] <= limit, (proc_transport, peak[0], window)


def test_end_of_stream_force_drain_with_lazy_watermark(proc_transport,
                                                       proc_ctx):
    """Group-commit store whose tail batch would never flush on its own
    (huge batch, 60s interval): at end of stream the supervisor must
    detect quiescent-except-deferral — deferred acks keep their events in
    the SENDER's buffer, so 'all buffers empty' alone would deadlock
    against the force-drain — and push the watermark so the run
    completes."""
    build, expected = linear_pipeline(writes=1)
    eng = Engine(build(), mode="process", transport=proc_transport,
                 ctx=proc_ctx,
                 store=mk_store("sqlite+group", batch_size=100,
                                interval=60.0))
    eng.start()
    ok = eng.wait(60)
    eng.stop()
    assert ok
    assert sink_outputs(eng) == expected


def test_blocked_sender_survives_receiver_sigkill(proc_transport, proc_ctx):
    """The producer is credit-blocked on a full window when its consumer
    group is SIGKILL'd; recovery resets the window (routed re-grants from
    the surviving buffer, socket re-transmits on reconnect) and the run
    completes — a killed receiver never strands a sender."""
    n, window = 80, 4
    eng = Engine(_bp_pipeline(n, window, 0.004)(), mode="process",
                 transport=proc_transport, ctx=proc_ctx,
                 store=_mk("sqlite+group"), restart_delay=0.05)
    eng.start()
    # wait until the slow sink consumed a bit — the window is certainly
    # full and the upstream senders are blocked on credits
    deadline = time.time() + 30.0
    while eng.metrics().op("sink").processed < 10:
        assert time.time() < deadline, "pipeline never reached steady state"
        time.sleep(0.005)
    eng.kill_group("sink")
    ok = eng.wait(90)
    eng.stop()
    assert ok
    assert eng.failures >= 1
    assert len(sink_outputs(eng)) == n
