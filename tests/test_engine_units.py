"""Engine-internal unit tests: FailureInjector nth-crash semantics and the
channel deferred-ack cursor used by group-commit pipelining."""

import pytest

from repro.core import Channel, ChannelClosed, Event, FailureInjector
from repro.core.operator import SimulatedCrash


def _fire(inj, op, point):
    try:
        inj(op, point)
        return False
    except SimulatedCrash:
        return True


def test_injector_fires_on_nth_hit_of_exact_point():
    inj = FailureInjector([("A", "p", 3)])
    assert not _fire(inj, "A", "p")
    assert not _fire(inj, "A", "q")      # other points don't advance "p"
    assert not _fire(inj, "A", "p")
    assert not _fire(inj, "B", "p")      # other operators don't either
    assert _fire(inj, "A", "p")          # third hit of (A, p)
    assert inj.fired == [("A", "p", 3)]
    # a fired plan entry is consumed: the 4th hit is quiet
    assert not _fire(inj, "A", "p")


def test_injector_star_counts_any_point():
    inj = FailureInjector([("A", "*", 3)])
    assert not _fire(inj, "A", "x")
    assert not _fire(inj, "A", "y")
    assert not _fire(inj, "B", "z")      # other ops don't count
    assert _fire(inj, "A", "z")          # 3rd crash-point hit of A overall
    assert inj.fired == [("A", "*", 3)]


def test_injector_exact_and_star_counters_are_independent():
    inj = FailureInjector([("A", "p", 2), ("A", "*", 5)])
    hits = ["q", "p", "q", "p"]          # (A,p) #2 on the 4th call
    fired = [_fire(inj, "A", pt) for pt in hits]
    assert fired == [False, False, False, True]
    # the star entry keeps counting every call, including the one that fired
    assert _fire(inj, "A", "q")          # n_any reaches 5 here
    assert inj.counts[("A", "*")] == 5
    assert inj.fired == [("A", "p", 2), ("A", "*", 5)]


def _ch():
    return Channel("A", "out", "B", "in", capacity=8)


def _put(ch, i):
    ch.put(Event(i, "A", "out", "B", "in", body=i))


def test_channel_deferred_ack_fifo():
    ch = _ch()
    for i in range(3):
        _put(ch, i)
    assert ch.peek().event_id == 0
    ch.defer_ack()                        # 0 processed, unreleased
    assert ch.peek().event_id == 1        # processing continues past it
    ch.defer_ack()
    assert len(ch) == 3                   # deferred events still buffered
    assert ch.release_ack().event_id == 0
    assert ch.release_ack().event_id == 1
    assert ch.release_ack() is None
    assert len(ch) == 1
    assert ch.peek().event_id == 2


def test_channel_immediate_ack_skips_deferred_head():
    ch = _ch()
    for i in range(2):
        _put(ch, i)
    ch.defer_ack()                        # 0 pending release
    assert ch.peek().event_id == 1
    assert ch.ack().event_id == 1         # drops 1, not the deferred 0
    assert ch.release_ack().event_id == 0


def test_channel_reset_pending_redelivers():
    ch = _ch()
    for i in range(2):
        _put(ch, i)
    ch.defer_ack()
    assert ch.peek().event_id == 1
    ch.reset_pending()                    # receiver restart
    assert ch.peek().event_id == 0        # unreleased events re-delivered


def test_channel_rejects_puts_after_close():
    """A put absorbed after close() would strand the event forever (nobody
    drains a closed buffer): every put flavour must refuse."""
    ch = _ch()
    _put(ch, 0)
    ch.close()
    ev = Event(1, "A", "out", "B", "in", body=1)
    assert ch.put(ev) is False
    assert ch.try_put(ev) is False
    with pytest.raises(ChannelClosed):
        ch.force_put(ev)
    assert len(ch) == 1                   # only the pre-close event remains


def test_channel_blocked_put_aborts_on_close():
    """A sender blocked on a full window wakes and aborts when the channel
    closes (engine stop), instead of hanging forever."""
    import threading
    ch = Channel("A", "out", "B", "in", capacity=1)
    _put(ch, 0)
    result = []
    t = threading.Thread(
        target=lambda: result.append(
            ch.put(Event(1, "A", "out", "B", "in", body=1), timeout=0.01)))
    t.start()
    t.join(timeout=0.2)
    assert t.is_alive()                   # genuinely blocked on capacity
    ch.close()
    t.join(timeout=5.0)
    assert result == [False]


def test_abs_snapshots_through_log_backend():
    """The ABS baseline persists its epoch snapshots through the formal
    LogBackend interface when one is attached (same storage stack as
    LOG.io)."""
    from repro.core import Engine, GroupCommitStore
    from tests.helpers import linear_pipeline, sink_outputs
    build, expected = linear_pipeline()
    backend = GroupCommitStore(batch_size=4, interval=0.001)
    eng = Engine(build(), mode="thread", protocol="abs",
                 abs_options={"epoch_events": 5, "durable_store": backend})
    eng.start()
    assert eng.wait(30)
    eng.stop()
    assert sink_outputs(eng) == expected
    # every operator's snapshots landed as STATE rows via the backend
    for op in ("src", "map", "win", "sink"):
        assert backend.get_state(f"abs:{op}") is not None
