"""Log store unit tests: transactional atomicity, conditional aborts
(scale-down mutual exclusion), SQLite durability across 'process restarts',
sharded routing equivalence, and group-commit crash semantics (a crash
between flushes loses exactly the unflushed batch)."""
import os
import time

import pytest

from repro.core import (Event, GroupCommitStore, MemoryLogStore,
                        SqliteLogStore, TxnAborted, build_store)
from repro.core.events import DONE, UNDONE

STORE_SPECS = ["memory", "memory+sharded", "memory+group",
               "memory+sharded+group"]


def _mk(spec):
    return build_store(spec, shards=3, batch_size=4, interval=60.0)


def _ev(i, inset=None):
    return Event(i, "A", "out", "B", "in")


@pytest.mark.parametrize("spec", STORE_SPECS)
def test_txn_atomicity_on_abort(spec):
    store = _mk(spec)
    txn = store.begin()
    txn.log_event(_ev(0), UNDONE)
    txn.put_event_data(_ev(0))
    txn.set_inset_status("B", "nonexistent-inset", DONE, require_rows=True)
    with pytest.raises(TxnAborted):
        txn.commit()
    # nothing from the aborted txn is visible
    assert not store.fetch_resend_events("A")
    assert not store.event_status(("A", "out", 0))


@pytest.mark.parametrize("spec", STORE_SPECS)
def test_assign_and_done_lifecycle(spec):
    store = _mk(spec)
    txn = store.begin()
    for i in range(3):
        txn.log_event(_ev(i), UNDONE)
        txn.put_event_data(_ev(i))
    txn.commit()
    txn = store.begin()
    txn.assign_insets(("A", "out", 0), ["B:1"], rec_op="B")
    txn.assign_insets(("A", "out", 1), ["B:1", "B:2"], rec_op="B")   # multi-assignment
    txn.commit()
    acked = store.fetch_ack_events("B")
    assert [(e.event_id, ins) for e, ins, _ in acked] == \
        [(0, "B:1"), (1, "B:1"), (1, "B:2")]
    resend = store.fetch_resend_events("A")
    assert [e.event_id for e, _ in resend] == [2]
    txn = store.begin()
    txn.set_inset_status("B", "B:1", DONE, require_rows=True)
    txn.commit()
    acked = store.fetch_ack_events("B")
    assert [(e.event_id, ins) for e, ins, _ in acked] == [(1, "B:2")]


@pytest.mark.parametrize("spec", STORE_SPECS)
def test_reassign_skips_done_events(spec):
    """Alg 13 mutual exclusion: reassignment applies only to still-undone."""
    store = _mk(spec)
    txn = store.begin()
    txn.log_event(_ev(0), UNDONE)
    txn.log_event(_ev(1), UNDONE)
    txn.commit()
    txn = store.begin()
    txn.set_status(("A", "out", 0), DONE)
    txn.commit()
    txn = store.begin()
    txn.reassign_event(("A", "out", 0), "B", ("A", "to_C", 0), "C", "in")
    txn.reassign_event(("A", "out", 1), "B", ("A", "to_C", 1), "C", "in")
    txn.commit()
    # event 0 was done => untouched; event 1 moved
    assert store.event_status(("A", "out", 0)) == [(None, DONE)]
    assert store.event_status(("A", "out", 1)) == []
    assert store.event_status(("A", "to_C", 1)) == [(None, UNDONE)]
    assert store.consumers_of(("A", "to_C", 1)) == ["C"]


@pytest.mark.parametrize("spec", STORE_SPECS)
def test_assign_insets_without_rec_op(spec):
    """The interface default (rec_op=None) must work on every stack — a
    sharded store may only apply the assignment where rows exist."""
    store = _mk(spec)
    txn = store.begin()
    txn.log_event(_ev(0), UNDONE)
    txn.commit()
    txn = store.begin()
    txn.assign_insets(("A", "out", 0), ["B:1"])
    txn.commit()
    acked = store.fetch_ack_events("B")
    assert [(e.event_id, ins) for e, ins, _ in acked] == [(0, "B:1")]


def test_group_commit_tokens_stay_lost_after_crash():
    """A commit lost in a crash must never become 'durable' later: token
    sequence numbers are not reused."""
    store = GroupCommitStore(batch_size=100, interval=60.0)
    txn = store.begin()
    txn.log_event(_ev(0), UNDONE)
    lost = txn.commit()
    store.crash()                      # token `lost` gone with the batch
    for i in range(3):
        txn = store.begin()
        txn.log_event(_ev(10 + i), UNDONE)
        txn.commit()
    store.flush()
    assert not store.is_durable(lost)


@pytest.mark.parametrize("spec", STORE_SPECS)
def test_gc_keeps_rows_while_lineage_exists(spec):
    """The "lineage exists => keep EVENT_LOG rows" guard is global: on a
    sharded store the lineage rows live only in the producer's shard, but
    consumer-homed rows must still be retained."""
    store = _mk(spec)
    txn = store.begin()
    txn.log_event(_ev(0), UNDONE)
    txn.commit()
    txn = store.begin()
    txn.assign_insets(("A", "out", 0), ["B:1"], rec_op="B")
    txn.put_lineage(5, "A", "out", "B:1")
    txn.set_status(("A", "out", 0), DONE)
    txn.commit()
    store.gc()
    # row survives gc because lineage exists somewhere in the store
    assert store.lineage_events_of_inset("B", "B:1") == [("A", "out", 0)]
    # payloads of done events are still collected
    assert store.lineage_outputs_of_inset("A", "B:1") == [("A", "out", 5)]


def test_undone_events_from():
    store = MemoryLogStore()
    txn = store.begin()
    for i in range(4):
        txn.log_event(_ev(i), UNDONE)
    txn.set_status(("A", "out", 1), DONE)
    txn.commit()
    assert store.undone_events_from("A", "B") == \
        [("A", "out", 0), ("A", "out", 2), ("A", "out", 3)]
    assert store.undone_events_from("A", "X") == []


def test_sqlite_durability(tmp_path):
    path = os.path.join(tmp_path, "log.db")
    store = SqliteLogStore(path)
    txn = store.begin()
    for i in range(4):
        txn.log_event(_ev(i), UNDONE)
        txn.put_event_data(_ev(i))
    txn.put_state("A", 1, b"state-blob")
    txn.commit()
    store.close()
    # 'process restart': reopen from disk
    store2 = SqliteLogStore(path)
    assert len(store2.event_log) == 4
    assert store2.get_state("A") == b"state-blob"
    assert [e.event_id for e, _ in store2.fetch_resend_events("A")] == \
        [0, 1, 2, 3]
    store2.close()


def test_sqlite_engine_end_to_end(tmp_path):
    from repro.core import Engine, FailureInjector
    from tests.helpers import linear_pipeline, sink_outputs
    build, expected = linear_pipeline()
    store = SqliteLogStore(os.path.join(tmp_path, "pipeline.db"))
    inj = FailureInjector([("win", "post_log", 2)])
    eng = Engine(build(), store=store, mode="step", injector=inj)
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected
    store.close()


# ---------------------------------------------------------------------------
# group commit: watermark + crash semantics
# ---------------------------------------------------------------------------

def _wait_durable(store, token, timeout=5.0):
    """Flush I/O runs on the store's flusher thread: durability arrives
    asynchronously shortly after the watermark triggers."""
    deadline = time.monotonic() + timeout
    while not store.is_durable(token):
        assert time.monotonic() < deadline, f"token {token} never durable"
        time.sleep(0.001)


def test_group_commit_watermark_and_tokens():
    store = GroupCommitStore(batch_size=3, interval=60.0)
    tokens = []
    for i in range(3):
        txn = store.begin()
        txn.log_event(_ev(i), UNDONE)
        tokens.append(txn.commit())
    # txns 1-3 flush at the size watermark (async flusher thread)
    _wait_durable(store, tokens[2])
    # 4-5 stay pending below the watermark (committed post-flush so the
    # async cut cannot sweep them into the first batch)
    for i in (3, 4):
        txn = store.begin()
        txn.log_event(_ev(i), UNDONE)
        tokens.append(txn.commit())
    assert not store.is_durable(tokens[4])
    # the speculative view serves reads for all five regardless
    assert [e.event_id for e, _ in store.fetch_resend_events("A")] == \
        [0, 1, 2, 3, 4]
    store.flush()
    assert store.is_durable(tokens[4])


def test_group_commit_crash_loses_exactly_unflushed_batch():
    store = GroupCommitStore(batch_size=3, interval=60.0)
    tokens = []
    for i in range(5):
        txn = store.begin()
        txn.log_event(_ev(i), UNDONE)
        txn.put_event_data(_ev(i))
        tokens.append(txn.commit())
        if i == 2:
            # batch of 3 flushes asynchronously; park here so 3-4 land
            # strictly after the cut and form the unflushed batch
            _wait_durable(store, tokens[2])
    store.crash()
    # events 0-2 were flushed (batch of 3); 3-4 were the unflushed batch
    assert [e.event_id for e, _ in store.fetch_resend_events("A")] == \
        [0, 1, 2]
    # post-crash commits continue from the durable watermark
    txn = store.begin()
    txn.log_event(_ev(7), UNDONE)
    token = txn.commit()
    store.flush()
    assert store.is_durable(token)
    assert [e.event_id for e, _ in store.fetch_resend_events("A")] == \
        [0, 1, 2, 7]


def test_group_commit_over_sqlite(tmp_path):
    path = os.path.join(tmp_path, "g.db")
    store = GroupCommitStore(SqliteLogStore(path), batch_size=2,
                             interval=60.0)
    tokens = []
    for i in range(5):
        txn = store.begin()
        txn.log_event(_ev(i), UNDONE)
        tokens.append(txn.commit())
        if i % 2:
            _wait_durable(store, tokens[i])     # async batch of 2 lands
    # two batches of 2 flushed; event 4 pending. A crash drops it...
    store.crash()
    assert [e.event_id for e, _ in store.fetch_resend_events("A")] == \
        [0, 1, 2, 3]
    store.close()
    # ...and the durable image survives a real process restart, including
    # a warm reopen through the group-commit stack itself
    store2 = GroupCommitStore(SqliteLogStore(path))
    assert [e.event_id for e, _ in store2.fetch_resend_events("A")] == \
        [0, 1, 2, 3]
    store2.close()


def test_sharded_group_crash_per_shard_watermark():
    store = build_store("memory+sharded+group", shards=3, batch_size=2,
                        interval=60.0)
    # rows homed by receiver: B and C may land in different shards
    txn = store.begin()
    txn.log_event(Event(0, "A", "out", "B", "in"), UNDONE)
    txn.commit()
    token = store.begin()
    token.log_event(Event(1, "A", "out", "C", "in"), UNDONE)
    tok = token.commit()
    store.flush()
    assert store.is_durable(tok)
    txn = store.begin()
    txn.log_event(Event(2, "A", "out", "B", "in"), UNDONE)
    txn.commit()
    store.crash()       # event 2 unflushed -> lost; 0 and 1 durable
    assert [e.event_id for e, _ in store.fetch_resend_events("A")] == [0, 1]


# ---------------------------------------------------------------------------
# Global flush epochs (2PC): the sharded+group flush protocol
# ---------------------------------------------------------------------------

def _epoch_prepare_only(store, events):
    """Drive the flush protocol up to (but not including) the epoch-commit
    record: cut + prepare every shard, then 'crash' before the commit
    point — the window the old all-locks barrier closed by blocking."""
    for ev in events:
        txn = store.begin()
        txn.log_event(ev, UNDONE)
        txn.put_event_data(ev)
        txn.commit()
    eid = store.epoch_coord.next_epoch()
    with store._epoch_barrier.write():
        cut = [(s, s.cut_pending(eid)) for s in store._group_shards]
    for s, batch in cut:
        if batch:
            s.persist_prepared(eid)
    return eid


@pytest.mark.parametrize("base", ["memory", "sqlite"])
def test_epoch_crash_between_prepare_and_commit(base, tmp_path):
    """A crash after every shard prepared but before the epoch-commit
    record rolls the whole epoch back — no shard keeps its slice, so no
    multi-shard transaction is half-durable."""
    kw = {"path": os.path.join(tmp_path, "log.db")} if base == "sqlite" else {}
    store = build_store(f"{base}+sharded+group", shards=3, batch_size=100,
                        interval=60.0, **kw)
    durable = [_ev(i) for i in range(4)]
    for ev in durable:
        txn = store.begin()
        txn.log_event(ev, UNDONE)
        txn.put_event_data(ev)
        txn.commit()
    store.flush()
    # rows homed at different receivers => slices in different shards
    lost = [Event(10, "A", "out", "B", "in"), Event(11, "A", "out", "C", "in"),
            Event(12, "A", "out", "D", "in")]
    _epoch_prepare_only(store, lost)
    store.crash()
    got = sorted(e.event_id for e, _ in store.fetch_resend_events("A"))
    assert got == [0, 1, 2, 3], got


def test_epoch_crash_after_commit_record_is_durable(tmp_path):
    """The epoch-commit record is the atomicity point: once it lands, a
    crash before the shards advance their watermarks must still surface
    the whole epoch after restart."""
    path = os.path.join(tmp_path, "log.db")
    store = build_store("sqlite+sharded+group", shards=3, batch_size=100,
                        interval=60.0, path=path)
    evs = [Event(i, "A", "out", r, "in")
           for i, r in enumerate(["B", "C", "D"])]
    eid = _epoch_prepare_only(store, evs)
    store.epoch_coord.commit_epoch(eid)     # commit point reached
    store.close()
    # real restart: fresh stack over the surviving files
    store2 = build_store("sqlite+sharded+group", shards=3, batch_size=100,
                         interval=60.0, path=path)
    got = sorted(e.event_id for e, _ in store2.fetch_resend_events("A"))
    assert got == [0, 1, 2], got
    store2.close()


def test_epoch_restart_rolls_back_uncommitted_epoch(tmp_path):
    """Real process restart (fresh build_store over the files): WAL rows of
    a prepared-but-uncommitted epoch are deleted before replay."""
    path = os.path.join(tmp_path, "log.db")
    store = build_store("sqlite+sharded+group", shards=3, batch_size=100,
                        interval=60.0, path=path)
    durable = [_ev(i) for i in range(3)]
    for ev in durable:
        txn = store.begin()
        txn.log_event(ev, UNDONE)
        txn.commit()
    store.flush()
    _epoch_prepare_only(store, [Event(7, "A", "out", "B", "in"),
                                Event(8, "A", "out", "C", "in")])
    for s in store.shards:      # die without commit_epoch / finish_epoch
        s.inner.close()
    store.epoch_coord.close()
    store2 = build_store("sqlite+sharded+group", shards=3, batch_size=100,
                         interval=60.0, path=path)
    got = sorted(e.event_id for e, _ in store2.fetch_resend_events("A"))
    assert got == [0, 1, 2], got
    store2.close()


def test_epoch_flush_does_not_block_commits():
    """Commits land while a flush's prepare I/O is in progress (the barrier
    is exclusive only for the cut), and tokens stay correct."""
    store = build_store("memory+sharded+group", shards=3, batch_size=1000,
                        interval=60.0)
    import threading as _t
    for i in range(20):
        txn = store.begin()
        txn.log_event(_ev(i), UNDONE)
        txn.commit()
    stop = _t.Event()
    errs = []

    def committer():
        i = 100
        while not stop.is_set():
            txn = store.begin()
            txn.log_event(_ev(i), UNDONE)
            try:
                txn.commit()
            except Exception as exc:   # noqa: BLE001 - surfaced to assert
                errs.append(exc)
                return
            i += 1

    t = _t.Thread(target=committer)
    t.start()
    for _ in range(30):
        store.flush()
    stop.set()
    t.join()
    assert not errs
    store.flush()
    rows = store.fetch_resend_events("A")
    assert len(rows) >= 20
    assert store.epochs_flushed >= 1
