"""Log store unit tests: transactional atomicity, conditional aborts
(scale-down mutual exclusion), SQLite durability across 'process restarts'."""
import os

import pytest

from repro.core import Event, MemoryLogStore, SqliteLogStore, TxnAborted
from repro.core.events import DONE, UNDONE


def _ev(i, inset=None):
    return Event(i, "A", "out", "B", "in")


def test_txn_atomicity_on_abort():
    store = MemoryLogStore()
    txn = store.begin()
    txn.log_event(_ev(0), UNDONE)
    txn.put_event_data(_ev(0))
    txn.set_inset_status("B", "nonexistent-inset", DONE, require_rows=True)
    with pytest.raises(TxnAborted):
        txn.commit()
    # nothing from the aborted txn is visible
    assert not store.event_log
    assert not store.event_data


def test_assign_and_done_lifecycle():
    store = MemoryLogStore()
    txn = store.begin()
    for i in range(3):
        txn.log_event(_ev(i), UNDONE)
        txn.put_event_data(_ev(i))
    txn.commit()
    txn = store.begin()
    txn.assign_insets(("A", "out", 0), ["B:1"], rec_op="B")
    txn.assign_insets(("A", "out", 1), ["B:1", "B:2"], rec_op="B")   # multi-assignment
    txn.commit()
    acked = store.fetch_ack_events("B")
    assert [(e.event_id, ins) for e, ins, _ in acked] == \
        [(0, "B:1"), (1, "B:1"), (1, "B:2")]
    resend = store.fetch_resend_events("A")
    assert [e.event_id for e, _ in resend] == [2]
    txn = store.begin()
    txn.set_inset_status("B", "B:1", DONE, require_rows=True)
    txn.commit()
    acked = store.fetch_ack_events("B")
    assert [(e.event_id, ins) for e, ins, _ in acked] == [(1, "B:2")]


def test_reassign_skips_done_events():
    """Alg 13 mutual exclusion: reassignment applies only to still-undone."""
    store = MemoryLogStore()
    txn = store.begin()
    txn.log_event(_ev(0), UNDONE)
    txn.log_event(_ev(1), UNDONE)
    txn.commit()
    txn = store.begin()
    txn.set_status(("A", "out", 0), DONE)
    txn.commit()
    txn = store.begin()
    txn.ops.append(("reassign_event", ("A", "out", 0), "B", ("A", "to_C", 0),
                    "C", "in"))
    txn.ops.append(("reassign_event", ("A", "out", 1), "B", ("A", "to_C", 1),
                    "C", "in"))
    txn.commit()
    # event 0 was done => untouched; event 1 moved
    assert any(k[:3] == ("A", "out", 0) for k in store.event_log)
    assert not any(k[:3] == ("A", "out", 1) for k in store.event_log)
    assert any(k[:3] == ("A", "to_C", 1) for k in store.event_log)


def test_sqlite_durability(tmp_path):
    path = os.path.join(tmp_path, "log.db")
    store = SqliteLogStore(path)
    txn = store.begin()
    for i in range(4):
        txn.log_event(_ev(i), UNDONE)
        txn.put_event_data(_ev(i))
    txn.put_state("A", 1, b"state-blob")
    txn.commit()
    store.close()
    # 'process restart': reopen from disk
    store2 = SqliteLogStore(path)
    assert len(store2.event_log) == 4
    assert store2.get_state("A") == b"state-blob"
    assert [e.event_id for e, _ in store2.fetch_resend_events("A")] == \
        [0, 1, 2, 3]
    store2.close()


def test_sqlite_engine_end_to_end(tmp_path):
    from repro.core import Engine, FailureInjector
    from tests.helpers import linear_pipeline, sink_outputs
    build, expected = linear_pipeline()
    store = SqliteLogStore(os.path.join(tmp_path, "pipeline.db"))
    inj = FailureInjector([("win", "post_log", 2)])
    eng = Engine(build(), store=store, mode="step", injector=inj)
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected
    store.close()
