"""The published LOG.io API (Sec. 6.2): a custom operator written directly
against Tables 7-9 (like Listing 2) interoperates with the framework."""
from repro.core import (Engine, GeneratorSource, Operator, Pipeline,
                        ReadSource, TerminalSink)
from repro.core.api import LogioAPI


class ListingStyleOperator(Operator):
    """A Middle operator implemented via the paper API (Listing 2 shape):
    accumulates 3 events, emits their sum. The framework runtime still
    drives scheduling/recovery; the hooks use LogioAPI calls."""

    def __init__(self, op_id):
        super().__init__(op_id)
        self.count = 0
        self.windows = {}

    @property
    def logio(self) -> LogioAPI:
        return LogioAPI(self.runtime)

    def update_global(self, event):
        self.count += 1
        self.logio.UpdateContext(event)

    def global_state(self):
        return {"count": self.count}

    def restore_global(self, blob):
        if blob:
            self.count = blob["count"]

    def on_event(self, event, *, recovery_inset=None):
        if recovery_inset is None:
            assert self.logio.CheckEvent(event)     # Step 1 of Algorithm 2
        inset = recovery_inset or f"{self.id}:w{(self.count - 1) // 3}"
        self.windows.setdefault(inset, []).append(event.body)
        return [inset]

    def triggers(self):
        return [i for i, w in self.windows.items() if len(w) >= 3]

    def generate(self, inset_id):
        bodies = self.windows[inset_id]
        return [("out", {"s": sum(b["v"] for b in bodies)})], []

    def clear_inset(self, inset_id):
        self.windows.pop(inset_id, None)


def test_listing_style_operator_end_to_end():
    p = Pipeline()
    p.add(lambda: GeneratorSource(
        "src", ReadSource([{"v": i} for i in range(12)])))
    p.add(lambda: ListingStyleOperator("mid"))
    p.add(lambda: TerminalSink("sink", target=4))
    p.connect("src", "out", "mid", "in")
    p.connect("mid", "out", "sink", "in")
    eng = Engine(p, mode="step")
    assert eng.run_to_completion()
    got = [b for b in eng.external.committed()]
    assert got == [{"s": 0 + 1 + 2}, {"s": 3 + 4 + 5}, {"s": 6 + 7 + 8},
                   {"s": 9 + 10 + 11}]


def test_api_surface_matches_tables():
    """Every method name from Tables 7/8/9 exists."""
    table7 = ["GetActionID", "GetStateID", "BeginTransaction",
              "InitializeReadAction", "CompleteReadAction", "DropReadAction",
              "LogStateEvent", "UpdateContext", "GetWriteActions",
              "CheckEvent", "AssignInSets"]
    table8 = ["Commit", "LogSourceEvent", "LogOutputEvents", "DoneEvent",
              "StoreState"]
    table9 = ["FetchAckEvents", "FetchResendEvents", "GetProcState"]
    from repro.core.api import LogioAPI, LogioTransaction
    for m in table7 + table9:
        assert hasattr(LogioAPI, m), m
    for m in table8:
        assert hasattr(LogioTransaction, m), m
