"""Dynamic scaling (Algorithms 12-13) + the ABS baseline protocol."""
import time

import pytest

from repro.core import (Engine, FailureInjector, GeneratorSource, MapOperator,
                        Pipeline, ReadSource, TerminalSink)
from repro.core.scaling import Controller, DispatcherOperator, MergerOperator
from tests.helpers import linear_pipeline, sink_outputs


def _replica_pipeline(n):
    def build():
        p = Pipeline()
        p.add(lambda: GeneratorSource(
            "src", ReadSource([{"v": i} for i in range(n)]), rate=0.002))
        p.add(lambda: DispatcherOperator("disp", ["r0", "r1"]))
        p.add(lambda: MapOperator("r0", fn=lambda b: {"v": b["v"] * 2},
                                  processing_time=0.004))
        p.add(lambda: MapOperator("r1", fn=lambda b: {"v": b["v"] * 2},
                                  processing_time=0.004))
        p.add(lambda: MergerOperator("mrg", ["r0", "r1"]))
        p.add(lambda: TerminalSink("sink", target=n))
        p.connect("src", "out", "disp", "in")
        p.connect("disp", "to_r0", "r0", "in")
        p.connect("disp", "to_r1", "r1", "in")
        p.connect("r0", "out", "mrg", "from_r0")
        p.connect("r1", "out", "mrg", "from_r1")
        p.connect("mrg", "out", "sink", "in")
        return p
    return build


def _controller(eng):
    return Controller(
        eng, "disp", "mrg",
        replica_factory=lambda rid: (lambda: MapOperator(
            rid, fn=lambda b: {"v": b["v"] * 2}, processing_time=0.004)))


def test_replicas_exactly_once():
    n = 40
    eng = Engine(_replica_pipeline(n)(), mode="thread", restart_delay=0.01)
    eng.start()
    assert eng.wait(30)
    assert sorted(b["v"] for b in eng.external.committed()) == \
        sorted(2 * i for i in range(n))


def test_replica_failure_nonblocking():
    n = 40
    inj = FailureInjector([("r0", "post_log", 3)])
    eng = Engine(_replica_pipeline(n)(), mode="thread", injector=inj,
                 restart_delay=0.01)
    eng.start()
    assert eng.wait(30)
    assert sorted(b["v"] for b in eng.external.committed()) == \
        sorted(2 * i for i in range(n))
    assert eng.failures == 1


def test_scale_up_and_down_with_failure():
    n = 60
    inj = FailureInjector([("r0", "post_log", 3)])
    eng = Engine(_replica_pipeline(n)(), mode="thread", injector=inj,
                 restart_delay=0.01)
    ctrl = _controller(eng)
    eng.start()
    time.sleep(0.05)
    ctrl.scale_up("r2")
    time.sleep(0.08)
    ctrl.scale_down("r1")
    assert eng.wait(40)
    assert sorted(b["v"] for b in eng.external.committed()) == \
        sorted(2 * i for i in range(n))


def test_scale_down_to_one():
    n = 30
    eng = Engine(_replica_pipeline(n)(), mode="thread", restart_delay=0.01)
    ctrl = _controller(eng)
    eng.start()
    time.sleep(0.05)
    ctrl.scale_down("r0")
    assert eng.wait(30)
    assert sorted(b["v"] for b in eng.external.committed()) == \
        sorted(2 * i for i in range(n))


# ---------------------------------------------------------------------------
# ABS baseline
# ---------------------------------------------------------------------------

def test_abs_normal_processing():
    build, expected = linear_pipeline()
    eng = Engine(build(), mode="thread", protocol="abs",
                 abs_options={"epoch_events": 5})
    eng.start()
    assert eng.wait(30)
    assert sink_outputs(eng) == expected


@pytest.mark.parametrize("nth", [3, 7, 12, 17])
def test_abs_global_restart_recovery(nth):
    build, expected = linear_pipeline()
    inj = FailureInjector([("win", "abs_input", nth)])
    eng = Engine(build(), mode="thread", protocol="abs", injector=inj,
                 restart_delay=0.01, abs_options={"epoch_events": 5})
    eng.start()
    assert eng.wait(30)
    assert sink_outputs(eng) == expected
    assert eng.failures == 1


def test_abs_two_failures():
    build, expected = linear_pipeline()
    inj = FailureInjector([("win", "abs_input", 5), ("map", "abs_input", 9)])
    eng = Engine(build(), mode="thread", protocol="abs", injector=inj,
                 restart_delay=0.01, abs_options={"epoch_events": 5})
    eng.start()
    assert eng.wait(40)
    assert sink_outputs(eng) == expected
    assert eng.failures == 2


def test_abs_restart_quiesces_slow_operators():
    """A global restart must wait for every group thread to leave its step
    section before restoring state: a slow operator mid-step from the old
    generation must not pollute the rebuilt WAL/offsets (would show up
    here as a duplicated or missing value)."""
    n = 160

    def slow_mid(b):
        if 40 <= b["v"] < 120:
            time.sleep(0.012)
        return {"v": b["v"] * 2}

    def build():
        p = Pipeline()
        p.add(lambda: GeneratorSource(
            "src", ReadSource([{"v": i} for i in range(n)]), rate=0.002))
        p.add(lambda: MapOperator("map", fn=slow_mid))
        p.add(lambda: TerminalSink("sink", target=n))
        p.connect("src", "out", "map", "in")
        p.connect("map", "out", "sink", "in")
        return p

    inj = FailureInjector([("map", "abs_input", 50), ("map", "abs_input", 90)])
    eng = Engine(build(), mode="thread", protocol="abs", injector=inj,
                 restart_delay=0.005, abs_options={"epoch_events": 15})
    eng.start()
    assert eng.wait(60)
    assert sorted(b["v"] for b in sink_outputs(eng)) == \
        sorted(2 * i for i in range(n))
    assert eng.failures == 2
