"""Unit tests for lineage path enumeration + capture-port derivation
(Sec. 3.1): diamond topologies, reconvergent fan-out, multi-scope overlap,
and terminal-operator targets (the case the connection graph alone cannot
see — the walk must still find the scope's final output port)."""

import time

from repro.core import (Engine, GeneratorSource, LineageQuery, LineageScope,
                        MapOperator, Pipeline, ReadSource, SyncJoinOperator,
                        TerminalSink, enabled_ports)
from repro.core.lineage import _paths
from tests.helpers import diamond_pipeline


def _graph(connections):
    p = Pipeline()
    p.connections = [c + (64,) for c in connections]
    return p


DIAMOND = _graph([
    ("src", "out", "fast", "in"),
    ("src", "out", "slow", "in"),
    ("fast", "out", "join", "in1"),
    ("slow", "out", "join", "in2"),
    ("join", "out", "sink", "in"),
])


def test_paths_diamond_enumerates_each_branch_once():
    paths = _paths(DIAMOND, ("src", "out"), ("join", "out"))
    assert len(paths) == 2
    assert len({tuple(p) for p in paths}) == 2      # no double-enumeration
    branches = {p[1][0] for p in paths}
    assert branches == {"fast", "slow"}
    for p in paths:
        assert p[0] == ("src", "out") and p[-1] == ("join", "out")


def test_paths_terminal_target_output_port():
    """The scope target may be an output port with no outgoing connection
    (the terminal operator of the scope); the walk must still reach it."""
    g = _graph([
        ("s", "out", "a", "in"),
        ("s", "out", "b", "in"),
        ("a", "out", "j", "i1"),
        ("b", "out", "j", "i2"),
        ("j", "out", "c", "in"),
        ("j", "out", "d", "in"),
        ("c", "out", "k", "i1"),
        ("d", "out", "k", "i2"),
    ])
    paths = _paths(g, ("s", "out"), ("k", "out"))
    # double diamond: 2 upstream branches x 2 downstream branches
    assert len(paths) == 4
    assert len({tuple(p) for p in paths}) == 4
    ports = enabled_ports(g, [LineageScope(("s", "out"), ("k", "out"))])
    assert ports["j"] == ({"i1", "i2"}, {"out"})
    assert ports["k"] == ({"i1", "i2"}, {"out"})
    assert ports["s"] == (set(), {"out"})


def test_paths_reconvergent_fanout_distinct_ports():
    g = _graph([
        ("src", "out", "x", "in"),
        ("x", "o1", "y", "a"),
        ("x", "o2", "y", "b"),
        ("y", "out", "z", "in"),
    ])
    paths = _paths(g, ("src", "out"), ("y", "out"))
    assert len(paths) == 2
    assert {p[2] for p in paths} == {("x", "o1"), ("x", "o2")}


def test_paths_cycle_terminates_without_duplicates():
    g = _graph([
        ("s", "out", "x", "in"),
        ("x", "out", "y", "in"),
        ("y", "out", "x", "fb"),      # feedback edge
        ("y", "out", "t", "in"),
    ])
    paths = _paths(g, ("s", "out"), ("t", "in"))
    assert len(paths) == 1
    assert len({tuple(p) for p in paths}) == 1


def _wide_diamond_chain(width: int, length: int):
    """``length`` cascaded diamonds, each ``width`` parallel one-op
    branches — ``width ** length`` distinct paths."""
    conns = []
    prev = ("src", "out")
    for i in range(length):
        for w in range(width):
            b = f"d{i}b{w}"
            conns.append((prev[0], prev[1], b, "in"))
            conns.append((b, "out", f"j{i}", f"in{w}"))
        prev = (f"j{i}", "out")
    return _graph(conns), prev


def test_paths_wide_diamond_cascade_scales():
    """Regression for the per-candidate edge-membership check in the path
    walk: it rebuilt the path's consecutive-pair list for every candidate
    step (O(path length) allocations per check) instead of carrying a set.
    A cascade of wide diamonds — long paths, hundreds of thousands of
    membership checks — must enumerate fast and exactly."""
    g, target = _wide_diamond_chain(width=5, length=6)
    t0 = time.time()
    paths = _paths(g, ("src", "out"), target)
    elapsed = time.time() - t0
    assert len(paths) == 5 ** 6
    assert len({tuple(p) for p in paths}) == 5 ** 6
    assert elapsed < 20.0, f"path walk took {elapsed:.1f}s"
    # capture derivation over the same cascade stays exact
    ports = enabled_ports(
        g, [LineageScope(("src", "out"), target)])
    assert ports["d0b0"] == ({"in"}, {"out"})
    assert ports["j5"] == ({f"in{w}" for w in range(5)}, {"out"})


def test_enabled_ports_diamond_covers_both_branches():
    ports = enabled_ports(
        DIAMOND, [LineageScope(("src", "out"), ("join", "out"))])
    assert ports["fast"] == ({"in"}, {"out"})
    assert ports["slow"] == ({"in"}, {"out"})
    assert ports["join"] == ({"in1", "in2"}, {"out"})
    assert ports["src"] == (set(), {"out"})
    assert "sink" not in ports


def test_enabled_ports_multi_scope_union():
    """Overlapping scopes union their capture ports per operator."""
    g = _graph([
        ("s", "out", "a", "in"),
        ("a", "out", "b", "in"),
        ("b", "out", "c", "in"),
    ])
    scopes = [LineageScope(("s", "out"), ("a", "out")),
              LineageScope(("a", "out"), ("c", "out"))]
    ports = enabled_ports(g, scopes)
    assert ports["a"] == ({"in"}, {"out"})
    assert ports["b"] == ({"in"}, {"out"})
    assert ports["c"] == ({"in"}, {"out"})
    # 's' contributes only its start port (capture enabled as an output)
    assert ports["s"] == (set(), {"out"})


def test_enabled_ports_scope_start_equals_target():
    g = _graph([("s", "out", "a", "in")])
    ports = enabled_ports(g, [LineageScope(("s", "out"), ("s", "out"))])
    assert ports["s"] == (set(), {"out"})


def test_diamond_lineage_queries_end_to_end():
    """Run the UC2-style diamond with a scope across both branches and
    check backward/forward queries join over the join operator."""
    build, expected = diamond_pipeline(n_events=12, n1=6, n2=3,
                                      sink_target=2)
    scopes = [LineageScope(("src", "out"), ("join", "out"))]
    eng = Engine(build(), mode="step", lineage_scopes=scopes)
    eng.start()
    assert eng.run_to_completion()
    eng.stop()
    q = LineageQuery(eng.store)
    # backward from the first join output: contributors from BOTH branches
    contributors = q.backward(("join", "out", 0))
    ops = {c[0] for c in contributors.keys()}
    assert {"fast", "slow", "src"} <= ops
    # forward from the first source event reaches a join output
    fwd = q.forward(("src", "out", 0), "fast")
    assert any(k.op == "join" for k in fwd)


def test_multi_scope_diamond_engine_capture():
    """Two scopes, one per branch, enable capture only on their paths."""
    def build():
        p = Pipeline()
        p.add(lambda: GeneratorSource(
            "src", ReadSource([{"v": i} for i in range(8)])))
        p.add(lambda: MapOperator("fast", fn=lambda b: b))
        p.add(lambda: MapOperator("slow", fn=lambda b: b))
        p.add(lambda: SyncJoinOperator("join", 4, 4,
                                       agg=lambda a, b: len(a) + len(b)))
        p.add(lambda: TerminalSink("sink", target=2))
        p.connect("src", "out", "fast", "in")
        p.connect("src", "out", "slow", "in")
        p.connect("fast", "out", "join", "in1")
        p.connect("slow", "out", "join", "in2")
        p.connect("join", "out", "sink", "in")
        return p
    pipe = build()
    fast_only = enabled_ports(
        pipe, [LineageScope(("fast", "out"), ("join", "out"))])
    assert "slow" not in fast_only
    assert fast_only["join"] == ({"in1"}, {"out"})
    both = enabled_ports(
        pipe, [LineageScope(("fast", "out"), ("join", "out")),
               LineageScope(("slow", "out"), ("join", "out"))])
    assert both["join"] == ({"in1", "in2"}, {"out"})
