"""Multi-host process mode: spawn-safe worker bootstrap, the TCP channel
family, and the LocalCluster node-agent harness.

These tests always run, independent of the LOGIO_PROC_CTX/LOGIO_TRANSPORT
matrix axes: they pin the multi-host path specifically — workers started
under the ``spawn`` context (or by node agents) are rebuilt purely from
the picklable :class:`WorkerBootstrap` payload + the shared log, and their
channels ride authkey-authenticated ``AF_INET`` sockets brokered as
``(host, port)`` tuples.  No fork inheritance anywhere.
"""
import pickle
import time
from multiprocessing import AuthenticationError
from multiprocessing import connection as mpc

import pytest

from repro.core import Engine, FailureInjector, LocalCluster, Placement
from repro.core.scaling import Controller
from tests.helpers import linear_pipeline, mk_store, sink_outputs
from tests.test_process_mode import _mk_replica, _replica_pipeline

# cluster boots + eng.wait budgets exceed the global 120s pytest-timeout;
# 300s still catches genuine hangs well inside the CI job timeout
pytestmark = pytest.mark.timeout(300)


def _mk(spec="sqlite+group"):
    return mk_store(spec, shards=3, batch_size=4, interval=0.001)


# ---------------------------------------------------------------------------
# units: placement + bootstrap payload
# ---------------------------------------------------------------------------

def test_placement_units():
    p = Placement({"a": "n0", "b": None}, default="n1")
    assert p.node_of("a") == "n0"
    assert p.node_of("b") is None
    assert p.node_of("zzz") == "n1"        # default applies to unknowns
    p.assign("c", "n2")
    assert p.node_of("c") == "n2"
    assert p.nodes() == ["n0", "n1", "n2"]
    assert Placement().node_of("anything") is None
    assert Placement().nodes() == []


def test_bootstrap_payload_is_picklable_and_complete():
    """The whole point of the bootstrap: it crosses process boundaries by
    stdlib pickle and carries everything a worker rebuild needs."""
    build, _ = linear_pipeline(writes=1)
    eng = Engine(build(), mode="process", transport="tcp",
                 store=mk_store("memory"))
    try:
        bs = eng.make_bootstrap("map", recover=True, incarnation=7)
        bs2 = pickle.loads(pickle.dumps(bs))
        assert bs2.group == "map" and bs2.incarnation == 7 and bs2.recover
        assert bs2.group_ops() == ["map"]
        assert set(bs2.factories) == {"map"}     # only this group's ops
        op = bs2.factories["map"]()              # rebuilds a live operator
        assert op.id == "map"
        names = {c.name for c in bs2.channels}
        assert "src.out->map.in" in names and "map.out->win.in" in names
        assert all(c.capacity > 0 for c in bs2.channels)
        assert bs2.transport == "tcp"
        assert bs2.transport_options["family"] == "inet"
        assert isinstance(bs2.transport_options["authkey"], bytes)
    finally:
        eng.stop()


def test_socket_family_is_per_engine_config():
    """The family is engine configuration, not an import-time constant:
    AF_INET must be selectable (and work) on a host that also has
    AF_UNIX, and two engines with different families can coexist."""
    build, expected = linear_pipeline(writes=1)
    eng = Engine(build(), mode="process", transport="socket",
                 transport_options={"family": "inet"}, store=_mk())
    eng.start()
    ok = eng.wait(60)
    eng.stop()
    assert ok and sink_outputs(eng) == expected
    # transport="tcp" is the same selection spelled as a transport name
    eng2 = Engine(linear_pipeline(writes=1)[0](), mode="process",
                  transport="tcp", store=mk_store("memory"))
    assert eng2.transport_options["family"] == "inet"
    eng2.stop()
    with pytest.raises(ValueError):
        Engine(linear_pipeline()[0](), mode="process", transport="socket",
               transport_options={"family": "bogus"})


# ---------------------------------------------------------------------------
# spawn + AF_INET recovery: reconnect-replay and obsolete-filter correctness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op_id,point,nth", [
    ("map", "post_send", 1),       # sender dies: buffer rebuilt from log
    ("win", "post_ack_log", 2),    # receiver dies: reconnect + resend,
                                   # obsolete filter drops the recovered
                                   # prefix
])
def test_spawn_tcp_sigkill_recovery(op_id, point, nth):
    """SIGKILL a spawn-context worker mid-protocol over AF_INET channels:
    the respawned worker is rebuilt purely from bootstrap + log (no fork
    inheritance exists under spawn), senders re-transmit their reliable
    buffers on reconnect, and the obsolete filter keeps the output
    exactly-once."""
    build, expected = linear_pipeline(writes=1)
    inj = FailureInjector([(op_id, point, nth)])
    eng = Engine(build(), mode="process", ctx="spawn", transport="tcp",
                 store=_mk(), injector=inj, restart_delay=0.02)
    eng.start()
    ok = eng.wait(90)
    eng.stop()
    assert ok, (op_id, point)
    assert eng.failures == 1, (op_id, point)
    assert sink_outputs(eng) == expected       # no duplicates, no holes


def test_spawn_tcp_midstream_kill_reconnect_replay():
    """Kill a spawn worker mid-stream (not at an injected point): the
    sender's buffered events for the dead receiver are re-transmitted to
    its fresh AF_INET listener and filtered exactly-once."""
    build, expected = linear_pipeline(n_events=200, window=4,
                                      sink_target=50, writes=1, rate=0.005)
    eng = Engine(build(), mode="process", ctx="spawn", transport="tcp",
                 store=_mk("sqlite+sharded+group"), restart_delay=0.05)
    eng.start()
    deadline = time.time() + 30.0
    while eng.metrics().op("win").processed < 20:
        assert time.time() < deadline, "pipeline never reached steady state"
        time.sleep(0.01)
    eng.kill_group("win")
    ok = eng.wait(120)
    eng.stop()
    assert ok
    assert eng.failures >= 1
    assert sink_outputs(eng) == expected


# ---------------------------------------------------------------------------
# LocalCluster: node agents, bootstrap-only workers, whole-node death
# ---------------------------------------------------------------------------

def _cluster_engine(build, *, store, n_nodes=2, placement=None, **kw):
    cluster = LocalCluster(n_nodes)
    placement = placement or {"src": "node0", "map": "node0",
                              "win": "node1", "sink": "node1"}
    eng = Engine(build(), mode="process", ctx="spawn", transport="tcp",
                 store=store, cluster=cluster, placement=placement, **kw)
    return eng, cluster


def test_localcluster_bootstrap_only_recovery_matches_thread_mode():
    """The acceptance claim: a worker rebuilt purely from the bootstrap
    payload + log — launched by a node agent, crashed with SIGKILL,
    relaunched by the agent — recovers to exactly the output thread mode
    produces."""
    build, expected = linear_pipeline(writes=1)
    ref = Engine(build(), mode="thread", store=mk_store("memory"))
    ref.start()
    assert ref.wait(60)
    ref.stop()

    inj = FailureInjector([("win", "post_log", 2)])
    eng, _cluster = _cluster_engine(build, store=_mk(), injector=inj,
                                    restart_delay=0.02)
    eng.start()
    ok = eng.wait(120)
    eng.stop()
    assert ok
    assert eng.failures == 1
    assert sink_outputs(eng) == sink_outputs(ref) == expected


def test_localcluster_rejects_unauthenticated_control_connections():
    """The control hub (and every worker listener) runs the mpc authkey
    challenge: a client with the wrong key never gets a connection."""
    build, expected = linear_pipeline(writes=1)
    eng, _cluster = _cluster_engine(build, store=_mk())
    eng.start()
    try:
        addr = eng._proc._hub.address
        with pytest.raises(AuthenticationError):
            mpc.Client(addr, authkey=b"wrong-key")
        ok = eng.wait(120)     # the rejected probe must not disturb the run
    finally:
        eng.stop()
    assert ok and sink_outputs(eng) == expected


def test_localcluster_kill_node_nonblocking():
    """Pull the plug on one node (SIGKILL of its agent's whole process
    group): the other node's workers keep processing while the dead
    node's groups warm-restart on a fresh agent — the paper's
    non-blocking recovery across node boundaries."""
    build, expected = linear_pipeline(n_events=200, window=4,
                                      sink_target=50, writes=1, rate=0.005)
    eng, cluster = _cluster_engine(build, store=_mk("sqlite+sharded+group"),
                                   restart_delay=0.3)
    eng.start()
    deadline = time.time() + 30.0
    while eng.metrics().op("sink").processed < 5:
        assert time.time() < deadline, "pipeline never reached steady state"
        time.sleep(0.01)
    before = eng.metrics().op("src").processed
    cluster.kill_node("node1")                 # win + sink die with it
    assert cluster.wait_node_dead("node1")
    # node0's source must advance while node1 is down
    probe_deadline = time.time() + 1.0
    during = before
    while during <= before and time.time() < probe_deadline:
        during = eng.metrics().op("src").processed
        time.sleep(0.005)
    ok = eng.wait(150)
    eng.stop()
    assert ok, "run did not complete after node death"
    assert during > before, "source stalled while node1 was down"
    assert eng.failures >= 2                   # both of node1's groups
    assert sink_outputs(eng) == expected       # exactly-once across nodes


def test_localcluster_scale_up_across_nodes():
    """Dynamic scaling lands new replicas on other nodes: place r2 on
    node1 before scale_up, then scale r1 away — Algorithms 12-13 against
    node-agent workers."""
    n = 60
    placement = {"src": "node0", "disp": "node0", "r0": "node0",
                 "r1": "node1", "mrg": "node1", "sink": "node1"}
    cluster = LocalCluster(2)
    eng = Engine(_replica_pipeline(n)(), mode="process", ctx="spawn",
                 transport="tcp", cluster=cluster, placement=placement,
                 restart_delay=0.02)
    ctrl = Controller(eng, "disp", "mrg", replica_factory=_mk_replica)
    eng.start()
    time.sleep(0.5)
    eng.placement.assign("r2", "node1")
    ctrl.scale_up("r2")
    time.sleep(0.5)
    ctrl.scale_down("r1")
    ok = eng.wait(150)
    eng.stop()
    assert ok
    assert sorted(b["v"] for b in eng.external.committed()) == \
        sorted(2 * i for i in range(n))
