"""Units for the training substrate: quantization, optimizer, compression,
data pipeline determinism, checkpoint store."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointStore
from repro.data.pipeline import SyntheticCorpus, pack_fn
from repro.training import quant
from repro.training.optimizer import (OptHParams, adamw_update,
                                      init_opt_state)


def test_quant_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    for shape in [(100,), (33, 77), (4, 5, 6)]:
        x = jnp.asarray(rng.standard_normal(shape) * 3, jnp.float32)
        q = quant.quant(x)
        back = quant.dequant(q)
        assert back.shape == x.shape
        # per-row scaling: error bounded by each row's max/127
        row_scale = np.abs(np.asarray(x)).max(-1, keepdims=True)
        err = np.abs(np.asarray(back - x))
        assert (err <= row_scale / 127 + 1e-6).all()


def test_quant_shape_preserving():
    q = quant.qzeros_like(jnp.zeros((35, 7168)))
    assert q.q.shape == (35, 7168)          # sharding-compatible with param
    assert q.scale.shape == (35, 1)


@pytest.mark.parametrize("moment_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_descends(moment_dtype):
    hp = OptHParams(lr=0.1, warmup=1, weight_decay=0.0,
                    moment_dtype=moment_dtype)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    opt = init_opt_state(params, hp)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(30):
        g = jax.grad(loss)(params)
        params, opt, gn = adamw_update(params, g, opt, hp)
    assert float(loss(params)) < 1.0


def test_synthetic_corpus_replayable():
    c = SyntheticCorpus(n_shards=8, shard_tokens=64, vocab=100, seed=5)
    a = c.effect("read", 0)
    b = c.effect("read", 3)
    assert len(a) == 8 and len(b) == 5
    np.testing.assert_array_equal(a[3]["tokens"], b[0]["tokens"])


def test_pack_fn_shapes():
    fn = pack_fn(seq_len=16)
    out = fn({"shard": 0, "tokens": np.arange(100, dtype=np.int32)})
    assert out["seqs"].shape == (100 // 17, 17)


def test_checkpoint_store_checkable(tmp_path):
    store = CheckpointStore(str(tmp_path))
    state = {"w": np.arange(10.0), "step": np.int32(7)}
    assert store.status(7) == "unknown"
    store.save(state, 7)
    assert store.status(7) == "success"          # checkable write action
    step, back = store.latest()
    assert step == 7
    np.testing.assert_array_equal(back["w"], state["w"])
    store.save(state, 14)
    store.gc(keep=1)
    assert store.status(7) == "unknown" and store.status(14) == "success"


def test_grad_compression_roundtrip_small_error():
    from repro.training.step import train_step
    from repro.configs import get_config, reduced
    from repro.models import model as M
    cfg = reduced(get_config("internlm2-1.8b"), d_model=64, n_layers=2,
                  vocab=128)
    hp = OptHParams(lr=1e-3)
    rt = M.Runtime(q_chunk=8, remat="none")
    from repro.training.step import init_train_state
    state = init_train_state(jax.random.PRNGKey(0), cfg, hp,
                             dtype=jnp.float32)
    toks = jnp.arange(2 * 2 * 17).reshape(2, 2, 17) % cfg.vocab
    batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    s1, m1 = train_step(state, batch, cfg=cfg, hp=hp, rt=rt,
                        compress_grads=False)
    s2, m2 = train_step(state, batch, cfg=cfg, hp=hp, rt=rt,
                        compress_grads=True)
    # int8 grad compression perturbs the update only slightly
    w1 = jax.tree.leaves(s1["params"])[1]
    w2 = jax.tree.leaves(s2["params"])[1]
    rel = np.abs(np.asarray(w1 - w2)).max() / (
        np.abs(np.asarray(w1)).max() + 1e-9)
    assert rel < 0.02
    assert np.isfinite(float(m2["loss"]))
