"""Queryable lineage (Sec. 7.3) + partial replay-from-lineage:

  * typed surface validation — EventKey / LineageFilter / LineageQuery
    reject malformed input with loud ValueErrors (StoreConfig style)
  * pushdown parity — the filtered store ops must answer every query
    identically to the legacy full-scan + client-filter path, across the
    whole backend matrix (memory / sharded / group / sqlite / segment)
  * bounded results — ``limit`` and ``depth`` set the explicit
    ``truncated`` flag instead of growing or silently stopping
  * no-full-scan proof — sqlite answers a filtered backward query through
    SQL indexes, the segment reader skips sealed segments via the sidecar
    lineage summary (both asserted on the row/segment counters)
  * ``Engine.replay`` — re-executes ONLY the lineage-derived sub-DAG
    (executed-operator accounting) and reproduces deterministic outputs
    byte-identically, in thread AND process mode, surviving a real
    ``kill -9`` inside the replay run, with ``gc_protect`` holding the
    slice payloads against a checkpoint compaction racing the replay.
"""
import threading
import time

import pytest

from repro.core import (Engine, EventKey, FailureInjector, LineageFilter,
                        LineageQuery, LineageScope, MemoryLogStore)
from repro.core.logstore import StoreConfig, build_store
from repro.core.replay import ReplayMismatch
from tests.helpers import diamond_pipeline, linear_pipeline, mk_store


def _run_linear(spec="memory", n_events=20, window=4, sink_target=5,
                mode="thread", store=None, scope=("src", "win")):
    build, expected = linear_pipeline(n_events=n_events, window=window,
                                      sink_target=sink_target)
    scopes = [LineageScope((scope[0], "out"), (scope[1], "out"))]
    eng = Engine(build(), store=store if store is not None else mk_store(spec),
                 mode=mode, lineage_scopes=scopes)
    eng.start()
    assert eng.wait(60)
    eng.stop()
    return eng


def _run_diamond(spec="memory", mode="thread", sink_target=4):
    build, expected = diamond_pipeline(n_events=30, n1=6, n2=3,
                                       sink_target=sink_target)
    scopes = [LineageScope(("src", "out"), ("join", "out"))]
    eng = Engine(build(), store=mk_store(spec), mode=mode,
                 lineage_scopes=scopes)
    eng.start()
    assert eng.wait(60)
    eng.stop()
    return eng


# ---------------------------------------------------------------------------
# typed surface validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,match", [
    (dict(op="", port="out", ssn=0), "non-empty operator id"),
    (dict(op=3, port="out", ssn=0), "non-empty operator id"),
    (dict(op="a", port="", ssn=0), "non-empty port name"),
    (dict(op="a", port="out", ssn=-1), "non-negative int"),
    (dict(op="a", port="out", ssn=1.5), "non-negative int"),
    (dict(op="a", port="out", ssn=True), "non-negative int"),
])
def test_event_key_rejects(kw, match):
    with pytest.raises(ValueError, match=match):
        EventKey(**kw)


def test_event_key_coerce():
    k = EventKey("a", "out", 3)
    assert EventKey.coerce(k) is k
    assert EventKey.coerce(("a", "out", 3)) == k
    assert EventKey.coerce(["a", "out", 3]) == k
    assert k.astuple() == ("a", "out", 3)
    with pytest.raises(ValueError, match="3-tuple|must be"):
        EventKey.coerce(("a", "out"))
    with pytest.raises(ValueError, match="EventKey or"):
        EventKey.coerce("a.out.3")


@pytest.mark.parametrize("kw,match", [
    (dict(ops=42), "ops"),
    (dict(ports=7), "ports"),
    (dict(ssn_min="x"), "ssn_min"),
    (dict(epoch_max=1.5), "epoch_max"),
    (dict(ssn_min=5, ssn_max=2), "ssn range is empty"),
])
def test_lineage_filter_rejects(kw, match):
    with pytest.raises(ValueError, match=match):
        LineageFilter(**kw)


def test_lineage_filter_matches():
    flt = LineageFilter(ops="a", ports=["out", "aux"], ssn_min=2, ssn_max=5)
    assert flt.ops == frozenset({"a"})
    assert flt.matches("a", "out", 2) and flt.matches("a", "aux", 5)
    assert not flt.matches("b", "out", 3)
    assert not flt.matches("a", "in", 3)
    assert not flt.matches("a", "out", 6)
    # epoch bounds are scan hints, not row predicates
    assert LineageFilter(epoch_min=99).matches("a", "out", 0)


@pytest.mark.parametrize("kw,match", [
    (dict(start=("a",), target=("b", "out")), "pair of"),
    (dict(start=("a", ""), target=("b", "out")), "pair of"),
    (dict(start="a.out", target=("b", "out")), "pair of"),
])
def test_lineage_scope_rejects(kw, match):
    with pytest.raises(ValueError, match=match):
        LineageScope(**kw)


def test_query_arg_validation():
    with pytest.raises(ValueError, match="LogBackend"):
        LineageQuery(42)
    q = LineageQuery(MemoryLogStore())
    with pytest.raises(ValueError, match="depth"):
        q.backward(("a", "out", 0), depth=0)
    with pytest.raises(ValueError, match="limit"):
        q.backward(("a", "out", 0), limit=-1)
    with pytest.raises(ValueError, match="rec_op"):
        q.forward(("a", "out", 0), "")
    with pytest.raises(ValueError, match="at least one target"):
        q.slice([])


# ---------------------------------------------------------------------------
# pushdown parity + bounded results (whole backend matrix)
# ---------------------------------------------------------------------------

def test_query_parity_and_limits_across_backends(store_spec):
    eng = _run_linear(store_spec)
    qs = {pd: LineageQuery(eng.store, pushdown=pd) for pd in (True, False)}

    key = ("win", "out", 1)
    # traversal-pruning filter: must match the intermediate map events too,
    # or the walk never reaches src (non-matching events aren't expanded)
    flt = LineageFilter(ops={"src", "map"}, ssn_min=4, ssn_max=6)
    for query in (
            lambda q: q.backward(key),
            lambda q: q.backward(key, where=flt),
            lambda q: q.backward(key, where=LineageFilter(ports={"out"})),
            lambda q: q.forward(("src", "out", 5), "map"),
            lambda q: q.forward(("src", "out", 5), "map",
                                where=LineageFilter(ops={"map", "win"})),
    ):
        on, off = query(qs[True]), query(qs[False])
        assert sorted(on.keys()) == sorted(off.keys()), store_spec
        assert on.truncated == off.truncated is False
    # the filtered backward walk keeps only the matching contributors
    filtered = qs[True].backward(key, where=flt)
    assert sorted(filtered.keys()) == \
        [("map", "out", 4), ("map", "out", 5), ("map", "out", 6),
         ("src", "out", 4), ("src", "out", 5), ("src", "out", 6)]

    # slice parity: same closure, sources, ops and edges either way
    s_on = qs[True].slice(key)
    s_off = qs[False].slice(key)
    assert sorted(s_on.events) == sorted(s_off.events)
    assert sorted(s_on.sources) == sorted(s_off.sources)
    assert (s_on.ops, s_on.edges) == (s_off.ops, s_off.edges)
    assert s_on.ops == frozenset({"map", "win"})
    assert {e.op for e in s_on.sources} == {"src"}
    assert ("src", "out", "map") in s_on.edges
    assert ("map", "out", "win") in s_on.edges

    # bounded growth: limit truncates loudly, exhaustive walks don't
    full = qs[True].backward(key)
    capped = qs[True].backward(key, limit=2)
    assert len(capped) == 2 and capped.truncated
    assert list(capped)[:2] == list(full)[:2]
    shallow = qs[True].backward(key, depth=1)
    assert shallow.truncated      # map events found, src frontier unexpanded
    assert not full.truncated


def test_forward_matches_backward_closure():
    eng = _run_linear()
    q = LineageQuery(eng.store)
    fwd = q.forward(("src", "out", 2), "map")
    assert EventKey("win", "out", 0) in list(fwd)
    bwd = q.backward(("win", "out", 0))
    assert EventKey("src", "out", 2) in list(bwd)


# ---------------------------------------------------------------------------
# no-full-scan proofs (scan counters)
# ---------------------------------------------------------------------------

def test_memory_pushdown_avoids_full_scans():
    eng = _run_linear("memory")
    store = eng.store
    key = ("win", "out", 1)
    store.reset_query_stats()
    LineageQuery(store, pushdown=False).backward(key)
    legacy = eng.metrics().store.rows_scanned
    store.reset_query_stats()
    LineageQuery(store, pushdown=True).backward(key)
    native = eng.metrics().store.rows_scanned
    assert native < legacy, (native, legacy)


def test_sqlite_filtered_query_uses_index_not_full_scan(tmp_path):
    store = build_store("sqlite", path=str(tmp_path / "log.db"))
    eng = _run_linear(store=store, n_events=40, sink_target=10)
    store = eng.store
    n_rows = len(store.conn.execute("SELECT * FROM lineage").fetchall())
    assert n_rows > 20
    store.reset_query_stats()
    ins = store.query_lineage_insets(("win", "out", 3))
    assert len(ins) == 1
    sm = eng.metrics().store
    # the SQL WHERE answered from the (sop, sport, eid) index: the scan
    # counter reflects returned rows, nowhere near the full table
    assert sm.rows_scanned <= 2, sm
    assert sm.rows_scanned < n_rows / 10
    # filtered table walk restricted by sender op + ssn range
    store.reset_query_stats()
    rows = store.query_lineage(LineageFilter(ops={"win"}, ssn_min=0,
                                             ssn_max=3))
    assert {r[2] for r in rows} == {0, 1, 2, 3}
    assert eng.metrics().store.rows_scanned <= len(rows)


def test_segment_reader_skips_sealed_segments(tmp_path):
    cfg = StoreConfig(base="segment", path=str(tmp_path / "segs"),
                      segment_bytes=8 * 1024, checkpoint_interval=0)
    store = build_store(cfg)
    eng = _run_linear(store=store, n_events=60, sink_target=15)
    store = eng.store
    assert len(store._segments) > 2, "need several segments for skip proof"

    reader = store.lineage_reader()
    flt = LineageFilter(ops={"win"}, ssn_min=0, ssn_max=0)
    rows = reader.query_lineage(flt)
    assert [(r[0], r[2]) for r in rows] == [("win", 0)]
    stats = reader.query_stats()
    assert stats["segments_skipped"] >= 1, stats
    # an unfiltered audit scan must visit everything instead
    reader.reset_query_stats()
    all_rows = reader.query_lineage(None)
    assert len(all_rows) > len(rows)
    assert reader.query_stats()["segments_skipped"] == 0

    # exact-key lookup goes through the same skip logic
    reader.reset_query_stats()
    ins = reader.query_lineage_insets(("win", "out", 0))
    assert len(ins) == 1
    assert reader.query_stats()["segments_skipped"] >= 1


# ---------------------------------------------------------------------------
# Engine.replay — partial replay-from-lineage
# ---------------------------------------------------------------------------

def test_replay_reexecutes_only_sub_dag(store_spec):
    eng = _run_linear(store_spec)
    rep = eng.replay(("win", "out", 1))
    assert rep.ok and rep.completed and rep.deterministic
    # executed-operator accounting: ONLY the lineage-derived sub-DAG ran —
    # no source, no sink, nothing outside the slice
    assert rep.executed_ops == frozenset({"map", "win"}), store_spec
    assert rep.matches[EventKey("win", "out", 1)] is True
    assert rep.rederived[EventKey("win", "out", 1)] == \
        {"s": sum(2 * j for j in range(4, 8))}


def test_replay_diamond_multi_target_alignment():
    """Count-based windows re-derive correctly because injection is
    per-edge: each join input edge gets exactly the events it originally
    consumed (a shared union stream would misalign the 6/3 windows)."""
    eng = _run_diamond()
    rep = eng.replay([("join", "out", 0), ("join", "out", 2)])
    assert rep.ok
    assert rep.executed_ops == frozenset({"fast", "slow", "join"})
    assert all(v is True for v in rep.matches.values())
    assert len(rep.rederived) == 2


def test_replay_process_mode(store_spec):
    eng = _run_diamond(store_spec)
    rep = eng.replay(("join", "out", 1), mode="process", timeout=90)
    assert rep.ok, store_spec
    assert rep.executed_ops == frozenset({"fast", "slow", "join"})
    assert rep.matches[EventKey("join", "out", 1)] is True


def test_replay_scope_cuts_the_walk():
    """A LineageScope starting at ``map`` makes map's outputs the replay
    sources: their logged payloads are injected and only ``win``
    re-executes."""
    eng = _run_linear()
    scope = LineageScope(("map", "out"), ("win", "out"))
    rep = eng.replay(("win", "out", 1), scope=scope)
    assert rep.ok
    assert rep.executed_ops == frozenset({"win"})
    assert {e.op for e in rep.slice.sources} == {"map"}


def test_replay_survives_sigkill_inside_replay_run():
    """The replay run is itself a recoverable pipeline: a real kill -9 of
    a replay worker warm-restarts it and the rederived bytes still match."""
    eng = _run_linear()
    inj = FailureInjector([("map", "post_log", 2)])
    rep = eng.replay(("win", "out", 1), mode="process", timeout=90,
                     injector=inj)
    assert rep.ok
    assert inj.fired, "the injected crash never hit the replay worker"
    assert rep.matches[EventKey("win", "out", 1)] is True


def test_replay_races_checkpoint_compaction(tmp_path):
    """gc_protect holds the slice payloads while checkpoint compactions
    run concurrently with the replay — and is restored afterwards."""
    cfg = StoreConfig(base="segment", path=str(tmp_path / "segs"),
                      segment_bytes=8 * 1024, checkpoint_interval=0)
    eng = _run_linear(store=build_store(cfg), n_events=40, sink_target=10)
    store = eng.store
    # the deployment posture for replayable history: the slice operators
    # are registered up front so compaction keeps their payloads (without
    # this the FIRST checkpoint would collect the done events' payloads
    # long before any replay asks for them)
    pinned = frozenset({"src", "map", "win"})
    store.set_gc_protect(pinned)

    protect_seen = []
    orig_set = store.set_gc_protect

    def spy(ops):
        protect_seen.append(frozenset(ops))
        orig_set(ops)

    store.set_gc_protect = spy
    stop = threading.Event()

    def compactor():
        while not stop.is_set():
            store.checkpoint()
            time.sleep(0.001)

    t = threading.Thread(target=compactor, daemon=True)
    t.start()
    try:
        for _ in range(3):
            rep = eng.replay(("win", "out", 2))
            assert rep.ok
            assert rep.matches[EventKey("win", "out", 2)] is True
    finally:
        stop.set()
        t.join(timeout=10)
    # the slice producers were protected during the replay...
    assert any({"src", "map", "win"} <= c for c in protect_seen)
    # ...and the registry was restored when the replay handle closed
    assert store.gc_protect == pinned


def test_replay_errors_are_loud():
    eng = _run_linear()
    # a source event has no lineage inputs: nothing to re-execute
    with pytest.raises(ValueError, match="no recorded lineage"):
        eng.replay(("src", "out", 0))
    # a truncated slice must never silently replay a partial closure
    with pytest.raises(ValueError, match="truncated"):
        eng.replay(("win", "out", 1), depth=1)
    with pytest.raises(ValueError, match="LineageScope"):
        eng.replay(("win", "out", 1), scope=("src", "out"))
    with pytest.raises(ValueError, match="EventKey or"):
        eng.replay("win.out.1")


def test_replay_mismatch_is_a_value_error():
    assert issubclass(ReplayMismatch, ValueError)
