"""The batched wire protocol and the shared-memory rings underneath the
byte transports: superframe codec round-trips, vectored writes over real
sockets, ring byte-pipe semantics (wrap, blocking, incarnation resync),
the shared event-payload encode, and mid-stream SIGKILL with batches and
coalesced acks in flight on every byte transport."""
import os
import pickle
import socket
import threading
import time

import pytest

from repro.core import Engine, FailureInjector
from repro.core.events import Event
from repro.core.transport import wire
from repro.core.transport.shmring import ShmRing, sweep_stale_rings
from tests.helpers import linear_pipeline, mk_store, sink_outputs

# ---------------------------------------------------------------------------
# superframe codec
# ---------------------------------------------------------------------------


def _payload(i):
    return wire.encode_payload({"n": i}, {"v": i, "blob": b"x" * (i % 7)})


def _entries(n):
    """A deterministic interleaving of all entry kinds."""
    out = []
    for i in range(n):
        kind = ("ev", "ack", "defer", "release")[i % 4]
        name = f"op{i % 3}.out->op{(i + 1) % 3}.in"
        if kind == "ev":
            out.append(("ev", name, i, _payload(i)))
        else:
            out.append((kind, name, i))
    return out


def _decoded_matches(entries, decoded):
    assert len(decoded) == len(entries)
    for ent, dec in zip(entries, decoded):
        assert dec[0] == ent[0]
        assert dec[1] == ent[1]
        assert dec[2] == ent[2]
        if ent[0] == "ev":
            header, body = pickle.loads(ent[3])
            assert dec[3] == header
            assert dec[4] == body


def test_superframe_roundtrip_one_feed():
    entries = _entries(17)
    bufs, total, n_ev, n_ctrl = wire.encode_superframe(entries)
    assert n_ev == len([e for e in entries if e[0] == "ev"])
    assert n_ctrl == len(entries) - n_ev
    assert sum(len(b) for b in bufs) == total
    dec = wire.SuperframeDecoder()
    out = dec.feed(b"".join(bytes(b) for b in bufs))
    _decoded_matches(entries, out)
    assert dec.pending() == 0


def test_superframe_roundtrip_byte_by_byte():
    entries = _entries(9)
    bufs, total, _, _ = wire.encode_superframe(entries)
    data = b"".join(bytes(b) for b in bufs)
    dec = wire.SuperframeDecoder()
    out = []
    for i in range(len(data)):
        out.extend(dec.feed(data[i:i + 1]))
    _decoded_matches(entries, out)
    assert dec.pending() == 0


def test_multiple_superframes_in_one_chunk():
    e1, e2 = _entries(5), _entries(8)
    b1, _, _, _ = wire.encode_superframe(e1)
    b2, _, _, _ = wire.encode_superframe(e2)
    data = b"".join(bytes(b) for b in b1) + b"".join(bytes(b) for b in b2)
    out = wire.SuperframeDecoder().feed(data)
    _decoded_matches(e1 + e2, out)


def test_entry_size_agrees_with_encoder():
    entries = _entries(12)
    _, total, _, _ = wire.encode_superframe(entries)
    assert total == 4 + sum(wire.entry_size(e) for e in entries)


def test_empty_superframe():
    bufs, total, n_ev, n_ctrl = wire.encode_superframe([])
    assert (n_ev, n_ctrl) == (0, 0)
    out = wire.SuperframeDecoder().feed(b"".join(bytes(b) for b in bufs))
    assert out == []


def test_write_buffers_over_socketpair():
    """Vectored writes with partial-write handling deliver the byte
    stream intact — big payloads against a small kernel buffer force the
    writev loop through its offset-slice path."""
    entries = [("ev", "a.out->b.in", i,
                wire.encode_payload({}, {"big": os.urandom(70_000)}))
               for i in range(4)]
    bufs, total, _, _ = wire.encode_superframe(entries)
    a, b = socket.socketpair()
    a.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 16384)
    received = bytearray()

    def drain():
        while len(received) < total:
            chunk = b.recv(65536)
            if not chunk:
                return
            received.extend(chunk)

    t = threading.Thread(target=drain)
    t.start()
    wire.write_buffers(a.fileno(), bufs, total)
    t.join(timeout=10)
    a.close(), b.close()
    assert len(received) == total
    out = wire.SuperframeDecoder().feed(bytes(received))
    assert len(out) == 4
    for i, dec in enumerate(out):
        assert dec[2] == i


# ---------------------------------------------------------------------------
# event payload cache (the shared encode)
# ---------------------------------------------------------------------------

def test_event_blob_cache_roundtrip_and_pickle_exclusion():
    ev = Event(7, "a", "out", "b", "in", body={"v": 1}, header={"h": 2})
    assert ev.cached_blob() is None
    blob = ev.cache_blob()
    assert ev.cached_blob() is blob
    assert ev.cache_blob() is blob              # cached, not re-pickled
    assert pickle.loads(blob) == ({"h": 2}, {"v": 1})
    # the cache is process-local derived state: never shipped by pickle,
    # never inherited by clones (their header may diverge)
    copy = pickle.loads(pickle.dumps(ev))
    assert copy.cached_blob() is None
    assert copy.body == ev.body
    assert ev.clone_for("c", "in2").cached_blob() is None


# ---------------------------------------------------------------------------
# shm rings
# ---------------------------------------------------------------------------

def _alive():
    return True


def test_ring_byte_pipe_with_wraparound():
    ring = ShmRing.create(256)
    try:
        rng_in, rng_out = [], []
        # push enough traffic through a tiny ring that the cursors wrap
        # the capacity many times, reader racing the writer
        def read_all():
            got = bytearray()
            while len(got) < 10_000:
                chunk = ring.read_avail()
                if chunk:
                    got.extend(chunk)
                else:
                    time.sleep(0.0002)
            rng_out.append(bytes(got))

        t = threading.Thread(target=read_all)
        t.start()
        for i in range(100):
            chunk = bytes([i % 251]) * 100
            rng_in.append(chunk)
            ring.write_bytes(chunk, _alive)
        t.join(timeout=10)
        assert rng_out and rng_out[0] == b"".join(rng_in)
    finally:
        ring.unlink()
        ring.close()


def test_ring_attach_handshake_and_writer_resync():
    """The generation dance: a fresh attacher-writer must not publish
    until the creator-reader discarded the dead incarnation's bytes; a
    fresh attacher-reader must start at a frame boundary."""
    ring = ShmRing.create(1024)
    try:
        # dead incarnation left a partial frame in the ring
        ring.write_bytes(b"\xff" * 10, _alive)
        att = ShmRing.attach(ring.name)
        done = []

        def handshake():
            assert att.attacher_handshake(_alive)
            att.write_bytes(b"fresh", _alive)
            done.append(True)

        t = threading.Thread(target=handshake)
        t.start()
        time.sleep(0.05)
        assert not done          # blocked until the creator acknowledges
        assert ring.reader_resync_check()       # discards the 10 bytes
        t.join(timeout=10)
        assert done
        assert not ring.reader_resync_check()
        assert ring.read_avail() == b"fresh"
        att.close()
    finally:
        ring.unlink()
        ring.close()


def test_ring_creator_writer_resyncs_for_fresh_reader():
    """Ack-ring shape: the creator writes, a respawned attacher reads.
    Unread bytes addressed to the dead reader are discarded before the
    next frame so the fresh reader starts on a boundary."""
    ring = ShmRing.create(1024)
    try:
        ring.write_bytes(b"stale-acks", _alive)     # never read
        att = ShmRing.attach(ring.name)
        got = []

        def attach_read():
            assert att.attacher_handshake(_alive)
            deadline = time.time() + 10
            while time.time() < deadline:
                chunk = att.read_avail()
                if chunk:
                    got.append(chunk)
                    return
                time.sleep(0.0005)

        t = threading.Thread(target=attach_read)
        t.start()
        time.sleep(0.05)
        ring.write_bytes(b"fresh-acks", _alive)     # resyncs, then writes
        t.join(timeout=10)
        assert got == [b"fresh-acks"]
        att.close()
    finally:
        ring.unlink()
        ring.close()


def test_sweep_stale_rings_reclaims_dead_pid_names():
    ring = ShmRing.create(128)
    name = ring.name
    ring.close()
    # forge a dead-creator name: pid 2**22-odd is (virtually) never live
    stale = f"logio-{2**22 - 1}-0"
    import multiprocessing.shared_memory as sm
    seg = sm.SharedMemory(name=stale, create=True, size=128)
    from multiprocessing import resource_tracker
    try:
        resource_tracker.unregister(seg._name, "shared_memory")
    except Exception:
        pass
    seg.close()
    swept = sweep_stale_rings()
    assert swept >= 1
    with pytest.raises(FileNotFoundError):
        sm.SharedMemory(name=stale)
    # this process is alive: its ring survives the sweep
    reattach = ShmRing.attach(name)
    reattach.close()
    ShmRing.attach(name).unlink()


# ---------------------------------------------------------------------------
# mid-stream SIGKILL with batching in flight, across every byte transport
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("transport", ["socket", "tcp", "shm"])
@pytest.mark.parametrize("victim,point", [
    ("map", "post_send"),            # sender dies with superframes queued
    ("win", "post_ack_log"),         # receiver dies with coalesced acks
])
def test_sigkill_mid_batch(transport, victim, point):
    """Exactly-once under real process death while superframes and
    delayed acks are in flight, on each byte transport (the group-commit
    store keeps acks deferred, so kills land with coalesced credit grants
    pending)."""
    build, expected = linear_pipeline(n_events=120, window=4,
                                      sink_target=30, writes=1)
    inj = FailureInjector([(victim, point, 7)])
    eng = Engine(build(), mode="process", transport=transport,
                 store=mk_store("sqlite+group", batch_size=4,
                                interval=0.001),
                 injector=inj, restart_delay=0.02)
    eng.start()
    ok = eng.wait(90)
    eng.stop()
    assert ok, (transport, victim, point)
    assert sink_outputs(eng) == expected
    assert eng.failures == 1
    tm = eng.metrics().transport
    assert tm.frames > 0
    assert tm.events > 0
