"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


FLASH_CASES = [
    # (B, S, H, D, causal, window, softcap, dtype, block)
    (2, 128, 4, 64, True, None, None, jnp.float32, 64),
    (1, 256, 2, 128, True, 64, None, jnp.float32, 64),
    (2, 128, 4, 64, True, None, 50.0, jnp.float32, 32),
    (1, 128, 2, 64, False, None, None, jnp.float32, 64),
    (1, 128, 2, 256, True, None, None, jnp.float32, 128),
    (2, 64, 8, 64, True, 32, 30.0, jnp.float32, 32),
    (1, 128, 2, 64, True, None, None, jnp.bfloat16, 64),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    B, S, H, D, causal, window, softcap, dtype, blk = case
    q, k, v = (_rand((B, S, H, D), dtype) for _ in range(3))
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_q=blk, block_k=blk)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


SCAN_CASES = [
    (2, 64, 32, 8, 16, 64),
    (1, 256, 16, 16, 32, 128),
    (3, 128, 8, 4, 128, 32),
    (1, 32, 64, 16, 32, 1024),
]


@pytest.mark.parametrize("case", SCAN_CASES)
def test_selective_scan_matches_ref(case):
    B, S, DI, DS, chunk, bf = case
    a = jnp.asarray(RNG.uniform(0.5, 0.999, (B, S, DI, DS)), jnp.float32)
    b = _rand((B, S, DI, DS), jnp.float32)
    out = ops.selective_scan(a, b, chunk=chunk, block_f=bf)
    want = ref.selective_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


DECODE_CASES = [
    (2, 256, 4, 64, None, None, 64),
    (1, 512, 2, 128, 128, None, 128),
    (2, 128, 8, 64, None, 50.0, 32),
    (4, 64, 2, 256, 32, None, 64),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_matches_ref(case):
    B, S, H, D, window, softcap, blk = case
    q = _rand((B, H, D), jnp.float32)
    k = _rand((B, S, H, D), jnp.float32)
    v = _rand((B, S, H, D), jnp.float32)
    lens = jnp.asarray(RNG.integers(1, S + 1, (B,)), jnp.int32)
    out = ops.decode_attention(q, k, v, lens, window=window, softcap=softcap,
                               block_k=blk)
    want = ref.decode_attention_ref(q, k, v, lens, window=window,
                                    softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_model_attention_path_uses_kernel_consistently():
    """The model's XLA attention path and the Pallas kernel agree."""
    import jax
    from repro.configs import get_config, reduced
    from repro.models import layers as L
    from repro.configs.base import AttnSpec

    cfg = reduced(get_config("qwen3-32b"), d_model=64, n_heads=4,
                  n_kv_heads=2, vocab=128)
    p, _ = L.init_attention(jax.random.PRNGKey(0), cfg, AttnSpec(),
                            jnp.float32)
    x = _rand((2, 64, cfg.d_model), jnp.float32)
    pos = jnp.arange(64)[None, :]
    out_xla = L.apply_attention(p, x, AttnSpec(), cfg, pos, q_chunk=32)
    out_pallas = L.apply_attention(p, x, AttnSpec(), cfg, pos,
                                   attn_impl="pallas")
    np.testing.assert_allclose(np.asarray(out_xla), np.asarray(out_pallas),
                               rtol=2e-4, atol=2e-4)
