"""Typed config API + curated public surface:

  * StoreConfig <-> legacy spec-string round-trip, loud ValueErrors on
    malformed specs/fields, build_store accepting either form
  * TransportConfig validation + legacy transport_options equivalence
  * the ``repro.core`` API-surface snapshot (the documented import path —
    changing it is an API decision, not a refactor side-effect)
  * the deprecated ``repro.core.channels`` shim warns
"""
import dataclasses
import warnings

import pytest

import repro.core
from repro.core import Engine, StoreConfig, TransportConfig, build_store
from repro.core.logstore import (GroupCommitStore, MemoryLogStore,
                                 NullLogStore, SegmentLogStore,
                                 ShardedLogStore, SqliteLogStore)
from tests.helpers import linear_pipeline


# ---------------------------------------------------------------------------
# StoreConfig
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec,base,sharded,group", [
    ("memory", "memory", False, False),
    ("sqlite+group", "sqlite", False, True),
    ("segment+sharded", "segment", True, False),
    ("segment+sharded+group", "segment", True, True),
    ("null", "null", False, False),
])
def test_spec_round_trip(spec, base, sharded, group):
    cfg = StoreConfig.parse(spec)
    assert (cfg.base, cfg.sharded, cfg.group) == (base, sharded, group)
    assert str(cfg) == spec
    assert str(StoreConfig.parse(str(cfg))) == spec


@pytest.mark.parametrize("spec,match", [
    ("rocksdb", "unknown store base"),
    ("memory+turbo", "unknown store modifier"),
    ("memory+group+group", "duplicate store modifier"),
    ("", "non-empty string"),
    (None, "non-empty string"),
    ("+group", "unknown store base"),
])
def test_malformed_specs_raise(spec, match):
    with pytest.raises(ValueError, match=match):
        StoreConfig.parse(spec)


@pytest.mark.parametrize("field,value,match", [
    ("shards", 0, "shards must be >= 1"),
    ("batch_size", 0, "batch_size must be >= 1"),
    ("interval", -1.0, "interval must be >= 0"),
    ("segment_bytes", 0, "segment_bytes must be >= 1"),
    ("checkpoint_interval", -1, "checkpoint_interval must be >= 0"),
])
def test_malformed_fields_raise(field, value, match):
    with pytest.raises(ValueError, match=match):
        StoreConfig(**{field: value})


def test_config_is_frozen():
    cfg = StoreConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.base = "sqlite"


def test_build_store_accepts_config_and_spec(tmp_path):
    # typed path: segment knobs (no spec-string syntax) thread through
    cfg = StoreConfig(base="segment", group=True,
                      path=str(tmp_path / "segs"),
                      segment_bytes=1024, compress=False,
                      checkpoint_interval=7)
    store = build_store(cfg)
    assert isinstance(store, GroupCommitStore)
    assert isinstance(store.inner, SegmentLogStore)
    assert store.inner.segment_bytes == 1024
    assert store.inner.compress is False
    assert store.inner.checkpoint_interval == 7
    store.close()
    # legacy path: spec string + keyword overrides still work
    store = build_store("sqlite", path=str(tmp_path / "log.db"))
    assert isinstance(store, SqliteLogStore)
    store.close()
    assert isinstance(build_store("memory"), MemoryLogStore)
    assert isinstance(build_store("null"), NullLogStore)
    sharded = build_store("memory+sharded", shards=2)
    assert isinstance(sharded, ShardedLogStore)
    assert len(sharded.shards) == 2


def test_build_store_rejects_overrides_with_config(tmp_path):
    cfg = StoreConfig(base="sqlite", path=str(tmp_path / "log.db"))
    with pytest.raises(ValueError, match="inside the StoreConfig"):
        build_store(cfg, path=str(tmp_path / "other.db"))
    with pytest.raises(ValueError, match="StoreConfig or a spec"):
        build_store(42)


def test_durable_bases_require_path():
    with pytest.raises(ValueError, match="sqlite store needs a path"):
        build_store("sqlite")
    with pytest.raises(ValueError, match="segment store needs a path"):
        build_store("segment")


def test_engine_accepts_store_config(tmp_path):
    build, expected = linear_pipeline()
    cfg = StoreConfig(base="segment", path=str(tmp_path / "segs"),
                      checkpoint_interval=10)
    eng = Engine(build(), mode="step", store=cfg)
    eng.run_to_completion()
    assert isinstance(eng.store, SegmentLogStore)
    assert eng.store.compactions > 0


# ---------------------------------------------------------------------------
# TransportConfig
# ---------------------------------------------------------------------------

def test_transport_config_options():
    assert TransportConfig().options() == {}
    cfg = TransportConfig(name="socket", family="inet", host="127.0.0.1",
                          authkey=b"s")
    assert cfg.options() == {"family": "inet", "host": "127.0.0.1",
                             "authkey": b"s"}


@pytest.mark.parametrize("kw,match", [
    ({"name": "carrier-pigeon"}, "unknown transport"),
    ({"family": "ipx"}, "unknown socket family"),
])
def test_transport_config_rejects(kw, match):
    with pytest.raises(ValueError, match=match):
        TransportConfig(**kw)


def test_engine_accepts_transport_config():
    build, expected = linear_pipeline()
    eng = Engine(build(), mode="step", transport=TransportConfig(name="local"))
    eng.run_to_completion()
    # options must live inside the config once the typed form is used
    with pytest.raises(ValueError, match="inside the TransportConfig"):
        Engine(build(), transport=TransportConfig(name="local"),
               transport_options={"family": "unix"})


# ---------------------------------------------------------------------------
# Curated public surface
# ---------------------------------------------------------------------------

def test_api_surface_snapshot():
    # THE documented public surface (docs/api.md). A mismatch here means an
    # intentional API change: update the docs and this snapshot together.
    assert sorted(repro.core.__all__) == [
        "ControllerConfig",
        "Engine",
        "EventKey",
        "LineageFilter",
        "LineageQuery",
        "LineageScope",
        "LocalCluster",
        "LogioAPI",
        "MetricsSnapshot",
        "OpMetrics",
        "Pipeline",
        "Placement",
        "StoreConfig",
        "TransportConfig",
        "build_store",
    ]
    for name in repro.core.__all__:
        assert getattr(repro.core, name) is not None


def test_channels_shim_warns():
    import importlib
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        # first import AND reload both inside the catch: the shim's
        # warning must never leak into the test session (tier-1 is
        # DeprecationWarning-clean)
        import repro.core.channels as ch
        importlib.reload(ch)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    # the shim still re-exports the moved names
    from repro.core.transport.local import Channel
    assert ch.Channel is Channel


def test_lineage_free_functions_shim_warns():
    """The free-function query surface moved to LineageQuery; the shims
    must warn on CALL (not import) and still return the old tuple lists."""
    from repro.core import Event, LineageQuery, backward, forward
    from repro.core.events import UNDONE
    from repro.core.logstore import MemoryLogStore

    store = MemoryLogStore()
    txn = store.begin()
    txn.log_event(Event(0, "a", "out", "b", "in"), UNDONE)
    txn.commit()
    txn = store.begin()
    txn.assign_insets(("a", "out", 0), ["i0"], rec_op="b")
    txn.put_lineage(0, "b", "out", "i0")
    txn.commit()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        old_bw = backward(store, ("b", "out", 0))
        old_fw = forward(store, ("a", "out", 0), "b")
    assert len([w for w in caught
                if issubclass(w.category, DeprecationWarning)]) == 2
    assert all("LineageQuery" in str(w.message) for w in caught)
    # the shims delegate: identical answers to the typed facade
    assert old_bw == LineageQuery(store).backward(("b", "out", 0)).keys()
    assert old_fw == LineageQuery(store).forward(("a", "out", 0), "b").keys()


# ---------------------------------------------------------------------------
# ControllerConfig
# ---------------------------------------------------------------------------

def test_controller_config_round_trip():
    from repro.core import ControllerConfig
    cfg = ControllerConfig(slo_ms=50.0, switch_hysteresis=2, max_replicas=6)
    assert ControllerConfig.parse(str(cfg)) == cfg
    parsed = ControllerConfig.parse("slo_ms=50,switch_hysteresis=2,"
                                    "max_replicas=6")
    assert parsed == cfg
    # overrides win over the spec
    assert ControllerConfig.parse("slo_ms=50", slo_ms=75.0).slo_ms == 75.0


@pytest.mark.parametrize("spec,match", [
    ("", "non-empty string"),
    (None, "non-empty string"),
    ("slo_ms", "malformed controller spec"),
    ("warp_factor=9", "unknown controller spec key"),
    ("slo_ms=50,slo_ms=60", "duplicate controller spec key"),
    ("slo_ms=fast", "bad value for controller spec key"),
])
def test_controller_config_malformed_specs_raise(spec, match):
    from repro.core import ControllerConfig
    with pytest.raises(ValueError, match=match):
        ControllerConfig.parse(spec)


@pytest.mark.parametrize("kw,match", [
    ({"slo_ms": 0}, "slo_ms must be > 0"),
    ({"sample_interval": 0}, "sample_interval must be > 0"),
    ({"switch_hysteresis": 0}, "switch_hysteresis must be >= 1"),
    ({"min_replicas": 0}, "min_replicas must be >= 1"),
    ({"min_replicas": 3, "max_replicas": 2},
     "max_replicas must be >= min_replicas"),
    ({"high_rate_eps": 0}, "high_rate_eps must be > 0"),
    ({"epoch_interval": 1}, "epoch_interval must be >= 2"),
    ({"scale_cooldown": -1}, "scale_cooldown must be >= 0"),
])
def test_controller_config_bad_fields_raise(kw, match):
    from repro.core import ControllerConfig
    with pytest.raises(ValueError, match=match):
        ControllerConfig(**kw)


def test_controller_config_is_frozen():
    from repro.core import ControllerConfig
    cfg = ControllerConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.slo_ms = 1.0


# ---------------------------------------------------------------------------
# the typed metrics plane + legacy-accessor deprecation shims
# ---------------------------------------------------------------------------

def test_metrics_snapshot_is_typed_and_frozen():
    from repro.core import MetricsSnapshot, OpMetrics
    build, expected = linear_pipeline()
    eng = Engine(build(), mode="step")
    eng.run_to_completion()
    m = eng.metrics()
    assert isinstance(m, MetricsSnapshot)
    assert m.mode == "step" and m.protocol == "logio"
    win = m.op("win")
    assert isinstance(win, OpMetrics)
    assert win.processed == win.events_in + win.events_out > 0
    assert m.recovery_modes["win"] == "log"
    with pytest.raises(dataclasses.FrozenInstanceError):
        win.events_in = 0
    with pytest.raises(TypeError):
        m.ops["win"] = win       # frozen mapping view


def test_legacy_stats_accessors_warn_and_delegate():
    build, expected = linear_pipeline()
    eng = Engine(build(), mode="step")
    eng.run_to_completion()
    m = eng.metrics()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        ps = eng.process_stats()
        detail = eng.op_stats_detail()
        ws = eng.wire_stats()
    deps = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 3
    assert all("Engine.metrics()" in str(w.message) for w in deps)
    assert ps == {op: om.processed for op, om in m.ops.items()}
    assert detail["win"]["txns"] == m.op("win").txns
    assert ws == {}              # step mode: no byte wire


def test_backend_query_stats_shim_warns():
    from repro.core.logstore import MemoryLogStore
    store = MemoryLogStore()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        stats = store.query_stats()
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert stats == store._query_stats()
