"""Segment-log backend tests (checkpoint compaction tentpole):

  * rotation + sealed-segment compression, torn-tail tolerance
  * bounded-replay recovery — warm restart replays O(checkpoint interval)
    records, not O(pipeline lifetime)
  * bounded on-disk size under continuous done-event traffic
  * recovery-counter floors surviving truncation
  * gc_protect keeping replay-feeding payloads across compaction
  * TRUE ``kill -9`` at exact compaction/rotation control points: the
    reopened store is always either the complete old image or the complete
    new one — committed records are never lost, the index is never torn.
"""
import json
import os
import signal
import subprocess
import sys
import time

import pytest

from repro.core import Engine, Event
from repro.core.events import DONE, UNDONE
from repro.core.logstore.segment import SegmentLogStore
from tests.helpers import (FileExternalSystem, linear_pipeline, mk_store,
                           sink_outputs)


def _fill(store, n, start=0, body=True):
    for i in range(start, start + n):
        txn = store.begin()
        ev = Event(i, "A", "out", "B", "in",
                   body={"v": i} if body else None)
        txn.log_event(ev, UNDONE)
        txn.put_event_data(ev)
        txn.commit()


def _mark_done(store, ids):
    txn = store.begin()
    for i in ids:
        txn.set_status(("A", "out", i), DONE)
    txn.commit()


# ---------------------------------------------------------------------------
# Rotation, compression, torn tails
# ---------------------------------------------------------------------------

def test_rotation_seals_and_compresses(tmp_path):
    path = str(tmp_path / "segs")
    store = SegmentLogStore(path, segment_bytes=2048)
    _fill(store, 60)
    assert store.rotations > 0
    store.close()   # drains the background sealer
    sealed = [f for f in os.listdir(path) if f.endswith(".logz")]
    active = [f for f in os.listdir(path) if f.endswith(".log")]
    assert sealed and len(active) == 1
    # every committed record replays from the sealed + active segments
    store2 = SegmentLogStore(path)
    assert store2.recovery_replay_count() == 60
    assert store2.last_sent_ssn("A") == {"out": 59}
    assert [e.body for e, _ in store2.fetch_resend_events("A")] == \
        [{"v": i} for i in range(60)]
    store2.close()


def test_torn_tail_frame_is_dropped(tmp_path):
    path = str(tmp_path / "segs")
    store = SegmentLogStore(path, segment_bytes=1 << 20)
    _fill(store, 10)
    active = [f for f in os.listdir(path) if f.endswith(".log")][0]
    store.close()
    # a kill mid-append leaves a partial frame at the tail of the active
    # segment; it must not poison the committed prefix
    with open(os.path.join(path, active), "ab") as f:
        f.write(b"\xff\x00\x00\x00garbage-partial-frame")
    store2 = SegmentLogStore(path)
    assert store2.recovery_replay_count() == 10
    assert store2.last_sent_ssn("A") == {"out": 9}
    store2.close()


# ---------------------------------------------------------------------------
# Checkpoint watermark: bounded replay, bounded disk, counter floors
# ---------------------------------------------------------------------------

def test_warm_restart_replays_at_most_checkpoint_interval(tmp_path):
    K = 20
    path = str(tmp_path / "segs")
    store = SegmentLogStore(path, segment_bytes=8192, checkpoint_interval=K)
    for i in range(300):
        _fill(store, 1, start=i)
        _mark_done(store, [i])
        store.maybe_checkpoint()
    assert store.compactions > 0
    store.close()
    # 600 records were ever appended; a warm restart replays only the tail
    # above the checkpoint watermark — O(K), not O(lifetime)
    store2 = SegmentLogStore(path, checkpoint_interval=K)
    assert store2.recovery_replay_count() <= K
    # the truncated history is still fully summarized by the image + floors
    assert store2.last_sent_ssn("A") == {"out": 299}
    store2.close()


def test_disk_stays_bounded_under_done_traffic(tmp_path):
    path = str(tmp_path / "segs")
    store = SegmentLogStore(path, segment_bytes=4096, checkpoint_interval=50)
    peak = 0
    for i in range(400):
        _fill(store, 1, start=i)
        _mark_done(store, [i])
        store.maybe_checkpoint()
        peak = max(peak, store.disk_bytes())
    # done events are truncated at each checkpoint: peak on-disk size is a
    # function of the checkpoint interval, far below the total log volume
    assert store.bytes_written > 2 * peak
    assert peak < 128 * 1024
    store.close()


def test_counter_floors_survive_truncation(tmp_path):
    path = str(tmp_path / "segs")
    store = SegmentLogStore(path)
    _fill(store, 10)
    txn = store.begin()
    for i in range(10):
        txn.assign_insets(("A", "out", i), ["B:1"], rec_op="B")
    txn.commit()
    _mark_done(store, range(10))
    store.compact()
    # all rows truncated, yet the per-port counters must not rewind —
    # recovery would otherwise reuse SSNs / re-ack acked events
    assert store.last_sent_ssn("A") == {"out": 9}
    assert store.last_acked("B") == {"in": 9}
    store.close()
    store2 = SegmentLogStore(path)
    assert store2.recovery_replay_count() == 0
    assert store2.last_sent_ssn("A") == {"out": 9}
    assert store2.last_acked("B") == {"in": 9}
    store2.close()


def test_gc_protect_keeps_replay_feeding_payloads(tmp_path):
    path = str(tmp_path / "segs")
    store = SegmentLogStore(path)
    store.set_gc_protect({"A"})
    _fill(store, 5)
    _mark_done(store, range(5))
    store.compact()
    # a replay flip can turn these done inputs back into needed ones
    # (Sec. 5): the protected sender's payloads must survive compaction
    assert store.event_status(("A", "out", 3)) == [(None, DONE)]
    store.close()
    store2 = SegmentLogStore(path)
    store2.set_gc_protect({"A"})
    # the replay flip itself: done -> undone, and the payload is still there
    txn = store2.begin()
    txn.set_status(("A", "out", 3), UNDONE)
    txn.commit()
    assert [(e.event_id, e.body) for e, _ in store2.fetch_resend_events("A")] \
        == [(3, {"v": 3})]
    store2.close()


# ---------------------------------------------------------------------------
# kill -9 at exact compaction / rotation control points
# ---------------------------------------------------------------------------

_CHILD = r"""
import os, signal, sys
from repro.core.events import DONE, UNDONE, Event
from repro.core.logstore.segment import SegmentLogStore

path, stage = sys.argv[1], sys.argv[2]
store = SegmentLogStore(path, segment_bytes=2048)
for i in range(40):
    txn = store.begin()
    ev = Event(i, "A", "out", "B", "in", body={"v": i})
    txn.log_event(ev, UNDONE)
    txn.put_event_data(ev)
    txn.commit()
txn = store.begin()
for i in range(20):
    txn.set_status(("A", "out", i), DONE, rec_op=None)
txn.commit()
def hook(s):
    if s == stage:
        os.kill(os.getpid(), signal.SIGKILL)
store.test_hook = hook
store.compact()
print("SURVIVED", flush=True)
"""


def _env():
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo_root, "src"), repo_root]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    return env


@pytest.mark.parametrize("stage", ["compact:pre_swap", "compact:post_swap"])
def test_kill9_mid_compaction_never_tears_the_store(stage, tmp_path):
    path = str(tmp_path / "segs")
    proc = subprocess.run([sys.executable, "-c", _CHILD, path, stage],
                          env=_env(), capture_output=True, timeout=60)
    assert proc.returncode == -signal.SIGKILL, proc.stderr.decode()

    # the index is never torn: whatever survived parses and names files
    # that all exist
    with open(os.path.join(path, "index.json")) as f:
        idx = json.load(f)
    for name in idx["segments"] + ([idx["checkpoint"]] if idx["checkpoint"]
                                   else []):
        assert os.path.exists(os.path.join(path, name)), name

    # committed records are never lost: pre_swap reopens the OLD store
    # image, post_swap the compacted one — both agree on every live fact
    store = SegmentLogStore(path)
    assert store.last_sent_ssn("A") == {"out": 39}
    resend = {e.event_id: e.body for e, _ in store.fetch_resend_events("A")}
    assert resend == {i: {"v": i} for i in range(20, 40)}
    if stage == "compact:pre_swap":
        # old image: the done rows (and the full log) are still there
        assert store.event_status(("A", "out", 5)) == [(None, DONE)]
        assert store.recovery_replay_count() == 41
    else:
        # new image: done rows truncated, replay starts at the checkpoint
        assert store.event_status(("A", "out", 5)) == []
        assert store.recovery_replay_count() == 0
    store.close()


_CHILD_ROTATE = r"""
import os, signal, sys
from repro.core.events import UNDONE, Event
from repro.core.logstore.segment import SegmentLogStore

path = sys.argv[1]
store = SegmentLogStore(path, segment_bytes=2048)
def hook(s):
    if s == "rotate:pre_index":
        os.kill(os.getpid(), signal.SIGKILL)
store.test_hook = hook
for i in range(200):
    txn = store.begin()
    ev = Event(i, "A", "out", "B", "in", body={"v": i})
    txn.log_event(ev, UNDONE)
    txn.put_event_data(ev)
    txn.commit()
    print(i, flush=True)
"""


def test_kill9_mid_rotation_keeps_every_acked_commit(tmp_path):
    path = str(tmp_path / "segs")
    proc = subprocess.run([sys.executable, "-c", _CHILD_ROTATE, path],
                          env=_env(), capture_output=True, timeout=60)
    assert proc.returncode == -signal.SIGKILL, proc.stderr.decode()
    acked = [int(x) for x in proc.stdout.split()]
    assert acked, "child died before any commit"
    store = SegmentLogStore(path)
    have = {e.event_id for e, _ in store.fetch_resend_events("A")}
    # every commit the child saw acknowledged survived the kill (the one
    # in-flight commit beyond the last ack may or may not have landed)
    assert set(acked) <= have
    store.close()


# ---------------------------------------------------------------------------
# Whole-engine kill -9 on the segment family (compaction runs live via
# mk_store's checkpoint interval) -> warm restart is exactly-once
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["segment+group", "segment+sharded+group"])
def test_kill9_whole_engine_segment_exactly_once(spec, tmp_path,
                                                 proc_transport, proc_ctx):
    db_path = str(tmp_path / "log.segs")
    ext_path = str(tmp_path / "external.bin")
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.Popen(
        [sys.executable, os.path.join(repo_root, "tests", "kill9_runner.py"),
         spec, db_path, ext_path, proc_transport, proc_ctx],
        stdout=subprocess.PIPE, env=_env(), start_new_session=True)
    try:
        assert proc.stdout.readline().strip() == b"READY"
        time.sleep(0.4)
    finally:
        try:
            os.killpg(os.getpgid(proc.pid), signal.SIGKILL)
        except ProcessLookupError:
            pass
        proc.wait()

    store = mk_store(spec, path=db_path, shards=3, batch_size=4,
                     interval=60.0)
    build, expected = linear_pipeline(writes=1, rate=0.01)
    eng = Engine(build(), mode="process", store=store,
                 external=FileExternalSystem(ext_path), resume=True,
                 transport=proc_transport, ctx=proc_ctx, restart_delay=0.01)
    eng.start()
    ok = eng.wait(90)
    eng.stop()
    assert ok
    assert sink_outputs(eng) == expected
    win_writes = [b for b in eng.external.committed()
                  if isinstance(b, dict) and "inset" in b]
    assert len(win_writes) == 5
