"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step + one decode step on CPU; shapes + finiteness asserted.
The FULL configs are exercised via the dry-run only (no allocation)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced, shapes_for
from repro.models import model as M
from repro.training.optimizer import OptHParams
from repro.training.step import init_train_state, train_step

KEY = jax.random.PRNGKey(0)


def _reduced(name):
    cfg = ARCHS[name]
    n = 2 * len(cfg.block) if len(cfg.block) == 1 else len(cfg.block)
    return reduced(cfg, n_layers=n)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_decode(name):
    cfg = _reduced(name)
    params = M.init_params(KEY, cfg, jnp.float32)
    B, S = 2, 16
    batch = {"tokens": jnp.arange(B * S).reshape(B, S) % cfg.vocab}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(KEY, (B, S, cfg.d_model),
                                            jnp.float32)
    rt = M.Runtime(q_chunk=8)
    logits, aux = M.forward(params, batch, cfg, rt)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    cache = M.init_cache(cfg, B, 32, jnp.float32, cross_len=S)
    lg, new_cache = M.decode_step(params, cache, batch["tokens"][:, 0],
                                  jnp.zeros(B, jnp.int32), cfg, rt)
    assert lg.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all()


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_one_train_step(name):
    cfg = _reduced(name)
    hp = OptHParams(lr=1e-3)
    rt = M.Runtime(q_chunk=8, remat="none")
    state = init_train_state(KEY, cfg, hp, dtype=jnp.float32)
    B, S, accum = 2, 16, 2
    toks = jnp.arange(accum * B * (S + 1)).reshape(accum, B, S + 1) % cfg.vocab
    batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(KEY, (accum, B, S, cfg.d_model),
                                            jnp.float32)
    new_state, metrics = jax.jit(
        lambda st, b: train_step(st, b, cfg=cfg, hp=hp, rt=rt))(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    d0 = jax.tree.leaves(state["params"])[1]
    d1 = jax.tree.leaves(new_state["params"])[1]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_count_matches_init(name):
    cfg = _reduced(name)
    shapes = jax.eval_shape(lambda: M.init_params(KEY, cfg, jnp.float32))
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    assert actual == cfg.param_count()


def test_full_configs_match_nominal_sizes():
    expect = {"chameleon-34b": 34, "internlm2-1.8b": 1.9, "qwen3-32b": 33,
              "gemma2-9b": 9.2, "jamba-1.5-large-398b": 399,
              "grok-1-314b": 316, "arctic-480b": 477, "falcon-mamba-7b": 7.3}
    for name, nominal in expect.items():
        got = ARCHS[name].param_count() / 1e9
        assert abs(got - nominal) / nominal < 0.08, (name, got)


def test_long_500k_skips_note():
    subq = [c.name for c in ARCHS.values() if c.subquadratic]
    assert sorted(subq) == ["falcon-mamba-7b", "jamba-1.5-large-398b"]
    for cfg in ARCHS.values():
        names = [s.name for s in shapes_for(cfg)]
        assert ("long_500k" in names) == cfg.subquadratic


def test_tp_padding_preserves_semantics():
    """Padded heads/vocab (for the 16-way model axis) must not change
    logits: padded wo rows are masked, padded vocab rows forced to -inf."""
    cfg = _reduced("starcoder2-7b")
    padded = dataclasses.replace(cfg, pad_heads_to=6, pad_vocab_to=520)
    params = M.init_params(KEY, padded, jnp.float32)
    B, S = 2, 16
    batch = {"tokens": jnp.arange(B * S).reshape(B, S) % cfg.vocab}
    logits, _ = M.forward(params, batch, padded, M.Runtime(q_chunk=8))
    assert logits.shape == (B, S, 520)
    assert np.all(np.asarray(logits[..., cfg.vocab:]) < -1e29)
    assert np.isfinite(np.asarray(logits[..., :cfg.vocab])).all()
