"""Adaptive micro-batching: governor units, vectored backend batch ops,
batched recovery scans, and mid-batch SIGKILL exactly-once.

The batched hot path must be invisible to the protocol: every record of a
vectored ``log_events`` / ``set_status_many`` stays individually keyed, so
a crash landing inside a batch replays exactly the unlogged suffix — at
most one batch beyond the durability watermark plus the credit window of
in-flight events."""
import os

import pytest

from repro.core import Engine, FailureInjector, build_store
from repro.core.batching import (DEFAULT_MAX_BATCH, BatchGovernor,
                                 make_governor, resolve_batching)
from repro.core.events import DONE, REPLAY, UNDONE, Event
from tests.helpers import linear_pipeline, mk_store, sink_outputs

#: pipeline default channel capacity (Pipeline.connect) — bounds how many
#: in-flight events a kill can strand beyond the watermark
CHANNEL_CAPACITY = 256


# ---------------------------------------------------------------------------
# governor units
# ---------------------------------------------------------------------------

def test_resolve_batching_specs():
    assert resolve_batching("off") == "off"
    assert resolve_batching("adaptive") == "adaptive"
    assert resolve_batching(16) == 16
    assert resolve_batching("16") == 16
    with pytest.raises(ValueError):
        resolve_batching(0)
    with pytest.raises(ValueError):
        resolve_batching(True)
    with pytest.raises(ValueError):
        resolve_batching("junk")


def test_resolve_batching_env(monkeypatch):
    monkeypatch.delenv("LOGIO_BATCH", raising=False)
    assert resolve_batching(None) == "off"
    monkeypatch.setenv("LOGIO_BATCH", "adaptive")
    assert resolve_batching(None) == "adaptive"
    monkeypatch.setenv("LOGIO_BATCH", "8")
    assert resolve_batching(None) == 8
    # an explicit spec wins over the environment
    assert resolve_batching("off") == "off"


def test_make_governor_off_is_none():
    assert make_governor("off") is None
    assert make_governor(1) is None
    assert make_governor("adaptive") is not None
    assert make_governor(4) is not None


def test_governor_degenerates_to_one_when_idle():
    """The moderate-rate regime: one queued event at a time -> batch=1,
    the scalar path, unchanged latency."""
    gov = BatchGovernor("adaptive")
    assert gov.limit(0) == 1
    assert gov.limit(1) == 1
    gov = BatchGovernor(32)
    assert gov.limit(1) == 1


def test_governor_fixed_mode_caps_at_spec():
    gov = BatchGovernor(8)
    assert gov.limit(100) == 8
    assert gov.limit(5) == 5


def test_governor_adaptive_respects_latency_bound():
    gov = BatchGovernor("adaptive", max_batch=1000, latency_bound=0.010)
    # teach it events cost ~1ms each: a run must stay under ~10 events
    for _ in range(50):
        gov.observe(10, 0.010)
    assert gov.limit(1000) <= 12
    # cheap events: the cap opens up to max_batch
    for _ in range(200):
        gov.observe(100, 0.0001)
    assert gov.limit(1000) == 1000
    s = gov.stats()
    assert s["mode"] == "adaptive" and s["ev_cost"] > 0


# ---------------------------------------------------------------------------
# vectored backend ops: one txn, individually keyed rows, on every stack
# ---------------------------------------------------------------------------

BATCH_SPECS = ["memory", "memory+sharded", "memory+group",
               "memory+sharded+group", "sqlite", "sqlite+group",
               "segment", "segment+group", "sqlite+sharded+group"]


def _ev(i, port="out"):
    return Event(i, "A", port, "B", "in")


def _mk(spec, **kw):
    return mk_store(spec, shards=3, batch_size=4, interval=0.001, **kw)


@pytest.mark.parametrize("spec", BATCH_SPECS)
def test_log_events_rows_individually_keyed(spec):
    store = _mk(spec)
    txn = store.begin()
    txn.log_events([(_ev(i), UNDONE, None) for i in range(5)])
    txn.commit()
    store.flush()
    assert [e.event_id for e, _ in store.fetch_resend_events("A")] == \
        [0, 1, 2, 3, 4]
    # each row is independently addressable — flip two of them
    txn = store.begin()
    txn.set_status_many([(("A", "out", 1), DONE, None, None, None),
                         (("A", "out", 3), DONE, None, None, None)])
    txn.commit()
    store.flush()
    assert [e.event_id for e, _ in store.fetch_resend_events("A")] == \
        [0, 2, 4]


@pytest.mark.parametrize("spec", BATCH_SPECS)
def test_set_status_many_only_status_guard(spec):
    """The conditional form (only_status) must hold per entry: DONE rows
    keep DONE when a replay flip targets still-UNDONE rows."""
    store = _mk(spec)
    txn = store.begin()
    txn.log_events([(_ev(i), UNDONE, None) for i in range(3)])
    txn.commit()
    txn = store.begin()
    txn.set_status(("A", "out", 0), DONE)
    txn.commit()
    txn = store.begin()
    txn.set_status_many([(("A", "out", i), REPLAY, "*", None, UNDONE)
                         for i in range(3)])
    txn.commit()
    store.flush()
    assert store.event_status(("A", "out", 0)) == [(None, DONE)]
    assert store.event_status(("A", "out", 1)) == [(None, REPLAY)]
    assert store.event_status(("A", "out", 2)) == [(None, REPLAY)]


@pytest.mark.parametrize("spec", ["memory+sharded", "memory+sharded+group",
                                  "sqlite+sharded+group"])
def test_log_events_split_across_shards(spec):
    """A run whose records home to different shards must land each record
    exactly once, queryable from both the sender and receiver views."""
    store = _mk(spec)
    txn = store.begin()
    recs = []
    for i in range(6):
        e = Event(i, f"OP{i % 3}", "out", f"OP{(i + 1) % 3}", "in")
        recs.append((e, UNDONE, None))
    txn.log_events(recs)
    txn.commit()
    store.flush()
    for i in range(3):
        assert [e.event_id for e, _ in store.fetch_resend_events(f"OP{i}")] \
            == [i, i + 3]


@pytest.mark.parametrize("spec", ["sqlite", "sqlite+group",
                                  "segment", "segment+group"])
def test_batched_rows_survive_reopen(spec, tmp_path):
    """Crash/reopen: rows written through one vectored txn replay from
    disk (sqlite WAL / segment frames) as individually keyed records."""
    ext = "db" if spec.startswith("sqlite") else "segs"
    path = str(tmp_path / f"log.{ext}")
    store = _mk(spec, path=path)
    txn = store.begin()
    txn.log_events([(_ev(i), UNDONE, None) for i in range(4)])
    txn.commit()
    txn = store.begin()
    txn.set_status_many([(("A", "out", 0), DONE, None, None, None)])
    txn.commit()
    store.flush()
    store.close()
    reopened = _mk(spec, path=path)
    assert [e.event_id for e, _ in reopened.fetch_resend_events("A")] == \
        [1, 2, 3]
    assert reopened.event_status(("A", "out", 0)) == [(None, DONE)]


def test_group_commit_batch_lost_before_flush():
    """A vectored log txn lost in a crash before its flush loses the WHOLE
    run atomically — no partial batch becomes durable."""
    store = build_store("memory+group", batch_size=100, interval=60.0)
    txn = store.begin()
    txn.log_events([(_ev(i), UNDONE, None) for i in range(5)])
    token = txn.commit()
    store.crash()
    assert not store.is_durable(token)
    assert store.fetch_resend_events("A") == []


# ---------------------------------------------------------------------------
# batched recovery read path (one range scan per operator)
# ---------------------------------------------------------------------------

def test_recovery_scan_batches_counter(store_spec):
    """Each recovery performs exactly one resend scan + one ack-events
    scan — never per-event round trips."""
    build, expected = linear_pipeline(writes=1)
    inj = FailureInjector([("win", "post_ack_log", 2)])
    eng = Engine(build(), mode="step", injector=inj,
                 store=_mk(store_spec))
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected
    m = eng.metrics()
    win = m.op("win")
    assert win.recovered_inputs > 0
    assert win.recovery_scan_batches == 2          # one resend + one ack scan
    for op, s in m.ops.items():
        if op != "win":
            assert s.recovery_scan_batches == 0


# ---------------------------------------------------------------------------
# batched hot path end-to-end (thread mode, governor forced on)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batching", ["adaptive", 16])
def test_batched_pipeline_exactly_once(batching, store_spec):
    build, expected = linear_pipeline(n_events=64, window=4, sink_target=16)
    eng = Engine(build(), mode="thread", store=_mk(store_spec),
                 batching=batching)
    eng.start()
    assert eng.wait(30)
    eng.stop()
    assert sink_outputs(eng) == expected
    ops = eng.metrics().ops
    # saturation (rate=0): the governed operators actually formed runs
    assert any(s.batched_events > 0 for s in ops.values()), ops


def test_batched_pipeline_with_crash_thread_mode(store_spec):
    build, expected = linear_pipeline(n_events=64, window=4, sink_target=16,
                                      writes=1)
    inj = FailureInjector([("map", "pre_state_update", 5),
                           ("win", "post_ack_log", 3)])
    eng = Engine(build(), mode="thread", store=_mk(store_spec),
                 injector=inj, batching="adaptive", restart_delay=0.01)
    eng.start()
    assert eng.wait(30)
    eng.stop()
    assert sink_outputs(eng) == expected


# ---------------------------------------------------------------------------
# mid-batch SIGKILL: real process death landing inside a batch apply/flush
# ---------------------------------------------------------------------------

KILL_SPECS = ["memory", "sqlite+group", "segment+group"]
KILL_TRANSPORTS = ["routed", "socket", "shm"]

# kills landing inside the batched phases: mid-classify (phase 1), after
# the one vectored commit before the coalesced acks (phase 3), and inside
# a batched source emission
KILL_POINTS = [
    ("src", "source_post_log", 2),
    ("map", "pre_state_update", 5),
    ("win", "post_ack_log", 3),
]


@pytest.mark.parametrize("spec", KILL_SPECS)
@pytest.mark.parametrize("transport", KILL_TRANSPORTS)
@pytest.mark.parametrize("op_id,point,nth", KILL_POINTS)
def test_mid_batch_sigkill_exactly_once(op_id, point, nth, spec, transport,
                                        proc_ctx):
    build, expected = linear_pipeline(n_events=64, window=4, sink_target=16,
                                      writes=1)
    inj = FailureInjector([(op_id, point, nth)])
    eng = Engine(build(), mode="process", store=_mk(spec), injector=inj,
                 transport=transport, ctx=proc_ctx, batching="adaptive",
                 restart_delay=0.02)
    eng.start()
    ok = eng.wait(60)
    eng.stop()
    assert ok, (spec, transport, op_id, point)
    assert sink_outputs(eng) == expected, (spec, transport, op_id, point)
    win_writes = [b for b in eng.external.committed()
                  if isinstance(b, dict) and "inset" in b]
    assert len(win_writes) == 16, (spec, transport, op_id, point)
    assert eng.failures == 1, (spec, transport, op_id, point)
    # replay length: at most one batch beyond the durability watermark
    # (plus the credit window of events that were legitimately in flight)
    bound = DEFAULT_MAX_BATCH + CHANNEL_CAPACITY
    for op, s in eng.metrics().ops.items():
        assert s.recovered_resends <= bound, (op, s)
        assert s.recovered_inputs <= bound, (op, s)


def test_env_forced_governor_reaches_workers(proc_ctx):
    """LOGIO_BATCH=adaptive (the CI cell's knob) resolves at the engine
    and rides the bootstrap into worker processes."""
    os.environ["LOGIO_BATCH"] = "adaptive"
    try:
        build, expected = linear_pipeline(n_events=64, window=4,
                                          sink_target=16)
        eng = Engine(build(), mode="process", store=_mk("memory"),
                     transport="routed", ctx=proc_ctx, restart_delay=0.02)
        assert eng.batching == "adaptive"
        eng.start()
        ok = eng.wait(60)
        eng.stop()
        assert ok
        assert sink_outputs(eng) == expected
        ops = eng.metrics().ops
        assert any(s.batched_events > 0 for s in ops.values())
    finally:
        os.environ.pop("LOGIO_BATCH", None)
