"""Crash-point matrices: exactly-once under failure at every protocol point,
for the pessimistic (default) and replay-mode (Sec. 5) configurations, plus
multi-operator simultaneous failures (Case 3 of the correctness proof).

The whole matrix runs against the log-backend configurations selected by
the LOGIO_STORE_SPEC env var (see ``conftest.py``; the local default is the
four memory-family stacks, CI adds the sqlite-family ones) — the protocol
must be oblivious to the storage stack behind the LogBackend interface."""
import pytest

from repro.core import Engine, FailureInjector, LineageScope
from tests.helpers import linear_pipeline, mk_store, sink_outputs


def _mk_store(spec):
    # small batches so group-commit flush boundaries actually interleave
    # with the injected crashes
    return mk_store(spec, shards=3, batch_size=4, interval=0.001)


POINTS = ["source_pre_log", "source_post_log", "pre_filter",
          "pre_state_update", "post_ack_log", "pre_log", "post_log",
          "post_send", "pre_write", "post_write_pre_done"]


@pytest.mark.parametrize("op_id", ["src", "map", "win", "sink"])
@pytest.mark.parametrize("point", POINTS)
def test_single_failure_exactly_once(op_id, point, store_spec):
    build, expected = linear_pipeline(writes=1)
    for nth in (1, 3):
        inj = FailureInjector([(op_id, point, nth)])
        eng = Engine(build(), mode="step", injector=inj,
                     store=_mk_store(store_spec))
        assert eng.run_to_completion(), (op_id, point, nth)
        assert sink_outputs(eng) == expected, (op_id, point, nth)
        win_writes = [b for b in eng.external.committed()
                      if isinstance(b, dict) and "inset" in b]
        assert len(win_writes) == 5, (op_id, point, nth)


@pytest.mark.parametrize("plan", [
    [("map", "post_log", 2), ("win", "pre_log", 1)],
    [("src", "source_post_log", 5), ("win", "post_send", 2)],
    [("map", "pre_state_update", 1), ("map", "post_ack_log", 4),
     ("sink", "pre_write", 2)],
    [("win", "recovery_post_resend", 1), ("win", "pre_log", 1)],  # crash DURING recovery
])
def test_multiple_failures(plan, store_spec):
    build, expected = linear_pipeline()
    eng = Engine(build(), mode="step", injector=FailureInjector(plan),
                 store=_mk_store(store_spec))
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected


REPLAY_POINTS = ["pre_filter", "pre_state_update", "post_ack_log", "pre_log",
                 "post_log", "post_send"]


@pytest.mark.parametrize("op_id", ["map", "win"])
@pytest.mark.parametrize("point", REPLAY_POINTS)
def test_replay_mode_exactly_once(op_id, point, store_spec):
    """map runs as a replay operator (no payload logging; lineage on all
    ports): its own failures regenerate outputs from Input Sets; consumer
    failures cascade a 'replay'-state restart of map (Algorithms 10-11)."""
    build, expected = linear_pipeline()
    scopes = [LineageScope(("src", "out"), ("map", "out"))]
    for nth in (1, 2, 3):
        inj = FailureInjector([(op_id, point, nth)])
        eng = Engine(build(), mode="step", lineage_scopes=scopes,
                     replay_ops={"map"}, injector=inj,
                     store=_mk_store(store_spec))
        assert eng.run_to_completion(), (op_id, point, nth)
        assert sink_outputs(eng) == expected, (op_id, point, nth)


def test_replay_mode_logs_no_payloads():
    build, expected = linear_pipeline()
    scopes = [LineageScope(("src", "out"), ("map", "out"))]
    eng = Engine(build(), mode="step", lineage_scopes=scopes,
                 replay_ops={"map"})
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected
    assert sum(1 for k in eng.store.event_data if k[0] == "map") == 0


def test_full_process_crash_replays_to_committed_outputs(store_spec):
    """Crash-equivalence: kill the WHOLE process mid-run (store loses its
    unflushed batch via crash(), channels lost), warm-restart a new engine
    on the recovered store + surviving external system — the committed
    outputs must equal the unbatched straight-through run (exactly-once)."""
    if "group" not in store_spec:
        pytest.skip("full-process crash() only loses data with group commit")
    build, expected = linear_pipeline(writes=1)
    # no time-based flushing: crashes land with maximal pending batches
    # (6/14/22 historically hit window boundaries mid-batch — they caught
    # the cross-shard partial-durability bug the coordinated flush fixes)
    for steps in (6, 10, 14, 22, 25, 40, 70):
        store = mk_store(store_spec, shards=3, batch_size=4,
                         interval=60.0)
        eng = Engine(build(), mode="step", store=store)
        external = eng.external
        done = eng.run_to_completion(max_steps=steps)
        # full-process crash: unflushed batch gone, channels gone
        store.crash()
        eng2 = Engine(build(), mode="step", store=store, external=external,
                      resume=True)
        assert eng2.run_to_completion(), steps
        assert sink_outputs(eng2) == expected, (steps, done)
        win_writes = [b for b in external.committed()
                      if isinstance(b, dict) and "inset" in b]
        assert len(win_writes) == 5, steps


def test_full_process_crash_resume_in_thread_mode(store_spec):
    """The warm-restart path must also recover when the resumed engine runs
    in thread mode (start() drives recovery, not run_to_completion)."""
    if "group" not in store_spec:
        pytest.skip("full-process crash() only loses data with group commit")
    build, expected = linear_pipeline(writes=1)
    store = mk_store(store_spec, shards=3, batch_size=4, interval=60.0)
    eng = Engine(build(), mode="step", store=store)
    eng.run_to_completion(max_steps=14)
    store.crash()
    eng2 = Engine(build(), mode="thread", store=store,
                  external=eng.external, resume=True)
    eng2.start()
    assert eng2.wait(30)
    eng2.stop()
    assert sink_outputs(eng2) == expected
