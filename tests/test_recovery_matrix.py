"""Crash-point matrices: exactly-once under failure at every protocol point,
for the pessimistic (default) and replay-mode (Sec. 5) configurations, plus
multi-operator simultaneous failures (Case 3 of the correctness proof)."""
import pytest

from repro.core import Engine, FailureInjector, LineageScope
from tests.helpers import linear_pipeline, sink_outputs

POINTS = ["source_pre_log", "source_post_log", "pre_filter",
          "pre_state_update", "post_ack_log", "pre_log", "post_log",
          "post_send", "pre_write", "post_write_pre_done"]


@pytest.mark.parametrize("op_id", ["src", "map", "win", "sink"])
@pytest.mark.parametrize("point", POINTS)
def test_single_failure_exactly_once(op_id, point):
    build, expected = linear_pipeline(writes=1)
    for nth in (1, 3):
        inj = FailureInjector([(op_id, point, nth)])
        eng = Engine(build(), mode="step", injector=inj)
        assert eng.run_to_completion(), (op_id, point, nth)
        assert sink_outputs(eng) == expected, (op_id, point, nth)
        win_writes = [b for b in eng.external.committed()
                      if isinstance(b, dict) and "inset" in b]
        assert len(win_writes) == 5, (op_id, point, nth)


@pytest.mark.parametrize("plan", [
    [("map", "post_log", 2), ("win", "pre_log", 1)],
    [("src", "source_post_log", 5), ("win", "post_send", 2)],
    [("map", "pre_state_update", 1), ("map", "post_ack_log", 4),
     ("sink", "pre_write", 2)],
    [("win", "recovery_post_resend", 1), ("win", "pre_log", 1)],  # crash DURING recovery
])
def test_multiple_failures(plan):
    build, expected = linear_pipeline()
    eng = Engine(build(), mode="step", injector=FailureInjector(plan))
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected


REPLAY_POINTS = ["pre_filter", "pre_state_update", "post_ack_log", "pre_log",
                 "post_log", "post_send"]


@pytest.mark.parametrize("op_id", ["map", "win"])
@pytest.mark.parametrize("point", REPLAY_POINTS)
def test_replay_mode_exactly_once(op_id, point):
    """map runs as a replay operator (no payload logging; lineage on all
    ports): its own failures regenerate outputs from Input Sets; consumer
    failures cascade a 'replay'-state restart of map (Algorithms 10-11)."""
    build, expected = linear_pipeline()
    scopes = [LineageScope(("src", "out"), ("map", "out"))]
    for nth in (1, 2, 3):
        inj = FailureInjector([(op_id, point, nth)])
        eng = Engine(build(), mode="step", lineage_scopes=scopes,
                     replay_ops={"map"}, injector=inj)
        assert eng.run_to_completion(), (op_id, point, nth)
        assert sink_outputs(eng) == expected, (op_id, point, nth)


def test_replay_mode_logs_no_payloads():
    build, expected = linear_pipeline()
    scopes = [LineageScope(("src", "out"), ("map", "out"))]
    eng = Engine(build(), mode="step", lineage_scopes=scopes,
                 replay_ops={"map"})
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected
    assert sum(1 for k in eng.store.event_data if k[0] == "map") == 0
