"""Subprocess target for the true ``kill -9`` crash tests: run the linear
pipeline in process mode on a durable sqlite-family store until the parent
test SIGKILLs this whole process tree mid-run.

Usage: python tests/kill9_runner.py <store_spec> <db_path> <external_path>
                                    [transport] [ctx]
(The parent sets PYTHONPATH so ``repro`` and ``tests`` import.)
"""
import sys

from repro.core import Engine
from tests.helpers import FileExternalSystem, linear_pipeline, mk_store


def main():
    spec, db_path, ext_path = sys.argv[1], sys.argv[2], sys.argv[3]
    transport = sys.argv[4] if len(sys.argv) > 4 else "routed"
    ctx = sys.argv[5] if len(sys.argv) > 5 else None
    build, _expected = linear_pipeline(writes=1, rate=0.01)
    # no time-based flushing: whatever the watermark has not flushed when
    # the SIGKILL lands is a genuinely unflushed (or uncommitted) epoch.
    # mk_store gives segment-family specs live checkpoint compaction, so
    # the SIGKILL can land mid-compaction too.
    store = mk_store(spec, path=db_path, shards=3, batch_size=4,
                     interval=60.0)
    eng = Engine(build(), mode="process", store=store,
                 external=FileExternalSystem(ext_path),
                 transport=transport, ctx=ctx, restart_delay=0.01)
    eng.start()
    print("READY", flush=True)
    eng.wait(60)
    print("DONE", flush=True)
    # stay alive (holding the unflushed tail) until the parent kills us
    import time
    time.sleep(60)


if __name__ == "__main__":
    main()
