"""Adaptive hybrid recovery controller (repro.core.controller):

  * scripted-snapshot decision tests — hysteresis on mode switching and
    SLO scaling, driven by hand-built MetricsSnapshots (no live engine)
  * per-group recovery-mode plumbing — epoch crash recovery is
    exactly-once in thread AND process mode, the persisted mode record is
    authoritative across a SIGKILL landing mid-switch
  * end-to-end controller runs — an injected straggler makes the
    controller switch an epoch group back to log recovery; a burst makes
    it scale replicas up and (after the burst) down, all exactly-once
  * BatchGovernor.stats() copy safety
"""
import time

import pytest

from repro.core import (ControllerConfig, Engine, FailureInjector,
                        GeneratorSource, MapOperator, MetricsSnapshot,
                        OpMetrics, Pipeline, ReadSource, TerminalSink)
from repro.core.controller import RecoveryController
from repro.core.scaling import Controller, DispatcherOperator, MergerOperator
from tests.helpers import linear_pipeline, mk_store, sink_outputs


# ---------------------------------------------------------------------------
# scripted snapshots: deterministic decision tests without a live engine
# ---------------------------------------------------------------------------

class _StubEngine:
    def __init__(self):
        self.modes = {}
        self.switches = []

    def recovery_mode_of(self, group):
        return self.modes.get(group, "log")

    def set_recovery_mode(self, group, mode):
        self.modes[group] = mode
        self.switches.append((group, mode))

    def metrics(self):
        raise AssertionError("scripted tests must pass snapshots to tick()")


class _StubScaler:
    def __init__(self):
        self.calls = []

    def scale_up(self, rid):
        self.calls.append(("up", rid))

    def scale_down(self, rid):
        self.calls.append(("down", rid))


def _snap(ts, *, ev_in=0, commit_us=0, stall_us=0, qdepth=0):
    ops = {"op": OpMetrics(op_id="op", group="g", events_in=ev_in,
                           commit_us=commit_us, send_stall_us=stall_us,
                           queue_depth=qdepth)}
    return MetricsSnapshot(ts=ts, mode="thread", protocol="logio", ops=ops)


def test_mode_switch_hysteresis_scripted():
    eng = _StubEngine()
    cfg = ControllerConfig(switch_hysteresis=2, high_rate_eps=1000.0)
    ctl = RecoveryController(eng, cfg, mode_groups=("g",))
    # high-rate regime: 2000 ev/s, commit path 20% of wall, no stalls
    ctl.tick(_snap(0.0))
    ctl.tick(_snap(1.0, ev_in=2000, commit_us=200_000))
    assert eng.switches == []          # one agreeing sample < hysteresis
    ctl.tick(_snap(2.0, ev_in=4000, commit_us=400_000))
    assert eng.switches == [("g", "epoch")]
    # straggler regime: deep queue, rate collapses — vote back to log
    ctl.tick(_snap(3.0, ev_in=4050, commit_us=405_000, qdepth=500))
    assert eng.switches == [("g", "epoch")]   # hysteresis holds again
    ctl.tick(_snap(4.0, ev_in=4100, commit_us=410_000, qdepth=500))
    assert eng.switches == [("g", "epoch"), ("g", "log")]
    kinds = [d[1] for d in ctl.decisions]
    assert kinds.count("mode") == 2


def test_mode_votes_reset_on_disagreement():
    eng = _StubEngine()
    cfg = ControllerConfig(switch_hysteresis=2, high_rate_eps=1000.0)
    ctl = RecoveryController(eng, cfg, mode_groups=("g",))
    ctl.tick(_snap(0.0))
    ctl.tick(_snap(1.0, ev_in=2000, commit_us=200_000))     # high
    ctl.tick(_snap(2.0, ev_in=2010, commit_us=201_000))     # calm: reset
    ctl.tick(_snap(3.0, ev_in=4010, commit_us=401_000))     # high again
    assert eng.switches == []          # never two CONSECUTIVE high samples


def test_stalled_downstream_does_not_vote_epoch():
    """Send-stall time (back-pressure) means the bottleneck is downstream:
    snapshotting this group harder would not help, so it stays on log."""
    eng = _StubEngine()
    cfg = ControllerConfig(switch_hysteresis=1, high_rate_eps=1000.0)
    ctl = RecoveryController(eng, cfg, mode_groups=("g",))
    ctl.tick(_snap(0.0))
    ctl.tick(_snap(1.0, ev_in=2000, commit_us=200_000, stall_us=700_000))
    assert eng.switches == []


def test_scaling_hysteresis_and_cooldown_scripted():
    eng = _StubEngine()
    scaler = _StubScaler()
    cfg = ControllerConfig(slo_ms=100.0, switch_hysteresis=2,
                           scale_cooldown=0.0, max_replicas=2)
    ctl = RecoveryController(eng, cfg, mode_groups=(), scaler=scaler,
                             initial_replicas=["r0"])
    # hot: 1000 queued, serving 100 ev/s -> residence ~10s >> 100ms SLO
    ctl.tick(_snap(0.0))
    ctl.tick(_snap(1.0, ev_in=100, qdepth=1000))
    assert scaler.calls == []                      # 1 hot sample < 2
    ctl.tick(_snap(2.0, ev_in=200, qdepth=1000))
    assert scaler.calls == [("up", "r1")]
    assert ctl.replicas == ["r0", "r1"]
    # still hot, but now at max_replicas (r0 + r1 = 2)
    ctl.tick(_snap(3.0, ev_in=300, qdepth=1000))
    ctl.tick(_snap(4.0, ev_in=400, qdepth=1000))
    assert scaler.calls == [("up", "r1")]
    # cold: queue drained -> residence 0; scale-down needs 2x hysteresis
    for i in range(3):
        ctl.tick(_snap(5.0 + i, ev_in=500 + i))
    assert scaler.calls == [("up", "r1")]
    ctl.tick(_snap(9.0, ev_in=600))
    assert scaler.calls == [("up", "r1"), ("down", "r1")]
    assert ctl.replicas == ["r0"]
    kinds = [d[1] for d in ctl.decisions]
    assert kinds == ["scale_up", "scale_down"]


def test_controller_loop_survives_sensing_errors():
    ctl = RecoveryController(_StubEngine(),
                             ControllerConfig(sample_interval=0.005))
    ctl.start()
    try:
        deadline = time.time() + 2.0
        while not ctl.decisions and time.time() < deadline:
            time.sleep(0.005)
    finally:
        ctl.stop()
    assert ctl.decisions and ctl.decisions[0][1] == "error"


def test_controller_accepts_spec_string():
    ctl = RecoveryController(_StubEngine(), "slo_ms=42,switch_hysteresis=5")
    assert ctl.config.slo_ms == 42.0
    assert ctl.config.switch_hysteresis == 5


# ---------------------------------------------------------------------------
# per-group recovery-mode plumbing: exactly-once under crashes + SIGKILL
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("point", ["pre_log", "post_log", "post_ack_log"])
def test_epoch_mode_crash_recovery_exactly_once_thread(point):
    build, expected = linear_pipeline(n_events=40, window=4, sink_target=10)
    inj = FailureInjector(plan=[("map", point, 3)])
    eng = Engine(build(), mode="thread", store=mk_store("memory"),
                 injector=inj, restart_delay=0.01,
                 recovery_modes={"map": "epoch", "win": "epoch"},
                 epoch_interval=5)
    eng.start()
    assert eng.wait(60)
    eng.stop()
    assert sink_outputs(eng) == expected
    assert eng.failures == 1
    assert eng.metrics().recovery_modes["map"] == "epoch"


def test_live_switch_with_crash_thread_exactly_once():
    """log -> epoch mid-run, crash inside the epoch regime, then
    epoch -> log: no event lost or duplicated across both switches."""
    build, expected = linear_pipeline(n_events=60, window=4, sink_target=15)
    inj = FailureInjector(plan=[("map", "post_log", 20)])
    eng = Engine(build(), mode="thread", store=mk_store("memory"),
                 injector=inj, restart_delay=0.01, epoch_interval=4)
    eng.start()
    eng.set_recovery_mode("map", "epoch")
    assert eng.recovery_mode_of("map") == "epoch"
    assert eng.wait(60)
    eng.set_recovery_mode("map", "log")
    assert eng.recovery_mode_of("map") == "log"
    eng.stop()
    assert sink_outputs(eng) == expected
    assert eng.failures == 1


def test_mode_record_is_authoritative_across_restart(tmp_path):
    """The persisted mode record wins over the constructor argument on a
    resumed engine — a controller decision survives a full engine loss
    (the process-mode SIGKILL-mid-switch guarantee, distilled: whatever
    the log says at recovery time is the mode the group recovers under)."""
    db = str(tmp_path / "log.db")
    build, expected = linear_pipeline(n_events=20, window=4, sink_target=5)
    store = mk_store("sqlite", path=db)
    eng = Engine(build(), mode="thread", store=store, epoch_interval=4)
    eng.start()
    eng.set_recovery_mode("map", "epoch")
    assert eng.wait(30)
    eng.stop()
    assert sink_outputs(eng) == expected
    store.close()
    # fresh engine, same log, CONFLICTING constructor request: the log wins
    store2 = mk_store("sqlite", path=db)
    eng2 = Engine(build(), mode="thread", store=store2, resume=True,
                  epoch_interval=4)
    assert eng2.recovery_mode_of("map") == "epoch"
    store2.close()


def test_epoch_mode_sigkill_process_exactly_once(proc_ctx):
    build, expected = linear_pipeline(n_events=40, window=4, sink_target=10,
                                      rate=0.03)
    eng = Engine(build(), mode="process", store=mk_store("memory"),
                 ctx=proc_ctx, restart_delay=0.01,
                 recovery_modes={"map": "epoch"}, epoch_interval=5)
    eng.start()
    time.sleep(0.5)
    eng.kill_group("map")
    ok = eng.wait(90)
    eng.stop()
    assert ok
    assert sink_outputs(eng) == expected
    assert eng.failures >= 1


def test_switch_then_sigkill_process_exactly_once(proc_ctx):
    """Switch log->epoch live, SIGKILL the group while it runs under the
    new mode, switch back after recovery: exactly-once throughout, and
    the group recovers under the mode recorded in the log."""
    build, expected = linear_pipeline(n_events=60, window=4, sink_target=15,
                                      rate=0.02)
    eng = Engine(build(), mode="process", store=mk_store("memory"),
                 ctx=proc_ctx, restart_delay=0.01, epoch_interval=4)
    eng.start()
    time.sleep(0.3)
    eng.set_recovery_mode("map", "epoch")
    assert eng.recovery_mode_of("map") == "epoch"
    time.sleep(0.4)
    eng.kill_group("map")
    time.sleep(0.3)
    eng.set_recovery_mode("map", "log")
    ok = eng.wait(90)
    eng.stop()
    assert ok
    assert sink_outputs(eng) == expected
    assert eng.failures >= 1
    assert eng.recovery_mode_of("map") == "log"


def test_recovery_modes_rejects_bad_args():
    build, _ = linear_pipeline()
    with pytest.raises(ValueError, match="unknown group"):
        Engine(build(), recovery_modes={"nope": "epoch"})
    with pytest.raises(ValueError, match="unknown recovery mode"):
        Engine(build(), recovery_modes={"map": "turbo"})
    with pytest.raises(ValueError, match="epoch_interval"):
        Engine(build(), epoch_interval=1)
    eng = Engine(build(), mode="step")
    with pytest.raises(ValueError, match="unknown group"):
        eng.set_recovery_mode("nope", "epoch")
    with pytest.raises(ValueError, match="unknown recovery mode"):
        eng.set_recovery_mode("map", "turbo")


def test_abs_protocol_pins_every_group_to_epoch():
    build, _ = linear_pipeline()
    with pytest.raises(ValueError, match="cannot be mixed"):
        Engine(build(), protocol="abs", recovery_modes={"map": "log"})
    eng = Engine(build(), protocol="abs")
    assert eng.recovery_mode_of("map") == "epoch"
    with pytest.raises(ValueError, match="fixed under protocol"):
        eng.set_recovery_mode("map", "log")


# ---------------------------------------------------------------------------
# end-to-end controller runs
# ---------------------------------------------------------------------------

def test_controller_switches_straggler_group_back_to_log():
    """A group running in epoch mode develops a straggler (stall window on
    its commit path): the controller must switch it back to per-event
    logging, with exactly-once output end to end."""
    build, expected = linear_pipeline(n_events=120, window=4, sink_target=30,
                                      rate=0.001)
    inj = FailureInjector(stalls=[("map", "post_log", 10, 90, 0.02)])
    eng = Engine(build(), mode="thread", store=mk_store("memory"),
                 injector=inj, recovery_modes={"map": "epoch"},
                 epoch_interval=8)
    ctl = RecoveryController(
        eng, ControllerConfig(sample_interval=0.02, switch_hysteresis=2,
                              high_rate_eps=100_000.0),
        mode_groups=("map",))
    eng.start()
    ctl.start()
    try:
        assert eng.wait(60)
    finally:
        ctl.stop()
        eng.stop()
    assert sink_outputs(eng) == expected
    assert eng.recovery_mode_of("map") == "log"
    mode_decisions = [d for d in ctl.decisions if d[1] == "mode"]
    assert mode_decisions and mode_decisions[0][2] == "map"
    assert mode_decisions[0][3].startswith("log")


def _burst_rate(off):
    # events 20..59 arrive 20x faster than the rest (the burst)
    return 0.002 if 20 <= off < 60 else 0.04


def _burst_pipeline(n):
    def build():
        p = Pipeline()
        p.add(lambda: GeneratorSource(
            "src", ReadSource([{"v": i} for i in range(n)]),
            rate_fn=_burst_rate))
        p.add(lambda: DispatcherOperator("disp", ["r0"]))
        p.add(lambda: MapOperator("r0", fn=lambda b: {"v": b["v"] * 2},
                                  processing_time=0.01))
        p.add(lambda: MergerOperator("mrg", ["r0"]))
        p.add(lambda: TerminalSink("sink", target=n))
        p.connect("src", "out", "disp", "in")
        p.connect("disp", "to_r0", "r0", "in")
        p.connect("r0", "out", "mrg", "from_r0")
        p.connect("mrg", "out", "sink", "in")
        return p
    return build


def test_controller_scales_replicas_through_burst():
    """Diurnal-with-burst arrivals against a slow replica: the controller
    must add at least one replica during the burst (SLO defence) and give
    it back once the burst drains — exactly-once end to end."""
    n = 90
    eng = Engine(_burst_pipeline(n)(), mode="thread",
                 store=mk_store("memory"), restart_delay=0.01)
    scaler = Controller(
        eng, "disp", "mrg",
        replica_factory=lambda rid: (lambda: MapOperator(
            rid, fn=lambda b: {"v": b["v"] * 2}, processing_time=0.01)))
    ctl = RecoveryController(
        eng, ControllerConfig(slo_ms=60.0, sample_interval=0.03,
                              switch_hysteresis=2, scale_cooldown=0.2,
                              max_replicas=3),
        mode_groups=(), scaler=scaler, replica_prefix="x",
        initial_replicas=["r0"])
    eng.start()
    ctl.start()
    try:
        assert eng.wait(90)
    finally:
        ctl.stop()
        eng.stop()
    assert sorted(b["v"] for b in eng.external.committed()) == \
        sorted(2 * i for i in range(n))
    kinds = [d[1] for d in ctl.decisions]
    assert "scale_up" in kinds, ctl.decisions
    assert "scale_down" in kinds, ctl.decisions
    up = kinds.index("scale_up")
    assert "scale_down" in kinds[up:]          # gave the replica back


# ---------------------------------------------------------------------------
# BatchGovernor.stats() copy safety
# ---------------------------------------------------------------------------

def test_batch_governor_stats_is_a_safe_copy():
    from repro.core.batching import BatchGovernor
    gov = BatchGovernor("adaptive")
    gov.observe(8, 0.004)
    s = gov.stats()
    s["runs"] = 999
    s["events"] = -1
    s.clear()
    fresh = gov.stats()
    assert fresh["runs"] == 1 and fresh["events"] == 8
    assert fresh["max_run"] == 8
    assert gov.runs == 1 and gov.events == 8
