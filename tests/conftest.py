import os
import sys

# tests must see exactly ONE device (the dry-run forces 512 in its own
# process only); make sure nothing leaks XLA_FLAGS into the test run
os.environ.pop("XLA_FLAGS", None)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import pytest  # noqa: E402  (sys.path fix must precede imports)

# ---------------------------------------------------------------------------
# Log-backend matrix: the recovery matrix (and everything else using the
# ``store_spec`` fixture) runs against a set of backend stacks selected by
# the LOGIO_STORE_SPEC env var — the CI matrix axis:
#
#   unset / "memory"  -> the four memory-family stacks (fast local default)
#   "sqlite"          -> durable sqlite stacks
#   "sharded+group"   -> the epoch-flushing (2PC) sharded stacks
#   "all"             -> the union (nightly)
#   anything else     -> comma list of literal build_store specs
# ---------------------------------------------------------------------------

_SPEC_SETS = {
    "memory": ["memory", "memory+sharded", "memory+group",
               "memory+sharded+group"],
    "sqlite": ["sqlite", "sqlite+group"],
    "sharded+group": ["memory+sharded+group", "sqlite+sharded+group"],
}
_SPEC_SETS["all"] = (_SPEC_SETS["memory"] + _SPEC_SETS["sqlite"]
                     + ["sqlite+sharded+group"])


def active_store_specs():
    sel = os.environ.get("LOGIO_STORE_SPEC", "").strip()
    if not sel:
        return _SPEC_SETS["memory"]
    if sel in _SPEC_SETS:
        return _SPEC_SETS[sel]
    return [s.strip() for s in sel.split(",") if s.strip()]


@pytest.fixture(params=active_store_specs())
def store_spec(request):
    """Backend-stack spec string for ``tests.helpers.mk_store`` — the
    protocol must be oblivious to the storage stack behind LogBackend."""
    return request.param


# ---------------------------------------------------------------------------
# Process-transport matrix: process-mode tests run against the transports
# selected by the LOGIO_TRANSPORT env var — the CI matrix axis, mirroring
# LOGIO_STORE_SPEC:
#
#   unset / "all"     -> routed AND socket (full local default)
#   "routed"          -> the supervisor-pumped pipe transport only
#   "socket"          -> the direct worker<->worker socket transport only
#   anything else     -> comma list of literal transport names
# ---------------------------------------------------------------------------

_TRANSPORT_SETS = {
    "routed": ["routed"],
    "socket": ["socket"],
    "all": ["routed", "socket"],
}


def active_transports():
    sel = os.environ.get("LOGIO_TRANSPORT", "").strip()
    if not sel:
        return _TRANSPORT_SETS["all"]
    if sel in _TRANSPORT_SETS:
        return _TRANSPORT_SETS[sel]
    return [t.strip() for t in sel.split(",") if t.strip()]


@pytest.fixture(params=active_transports())
def proc_transport(request):
    """Process-mode transport name — the recovery guarantees must be
    oblivious to how events move between workers."""
    return request.param
