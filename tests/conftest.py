import os
import sys

# tests must see exactly ONE device (the dry-run forces 512 in its own
# process only); make sure nothing leaks XLA_FLAGS into the test run
os.environ.pop("XLA_FLAGS", None)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import pytest  # noqa: E402  (sys.path fix must precede imports)

# ---------------------------------------------------------------------------
# Log-backend matrix: the recovery matrix (and everything else using the
# ``store_spec`` fixture) runs against a set of backend stacks selected by
# the LOGIO_STORE_SPEC env var — the CI matrix axis:
#
#   unset / "memory"  -> the four memory-family stacks (fast local default)
#   "sqlite"          -> durable sqlite stacks
#   "segment"         -> durable append-only segment stacks (checkpoint
#                        compaction runs live: tests/helpers.mk_store gives
#                        them a small checkpoint interval + segment size)
#   "sharded+group"   -> the epoch-flushing (2PC) sharded stacks
#   "all"             -> the union (nightly)
#   anything else     -> comma list of literal build_store specs
# ---------------------------------------------------------------------------

_SPEC_SETS = {
    "memory": ["memory", "memory+sharded", "memory+group",
               "memory+sharded+group"],
    "sqlite": ["sqlite", "sqlite+group"],
    "segment": ["segment", "segment+group"],
    "sharded+group": ["memory+sharded+group", "sqlite+sharded+group"],
}
_SPEC_SETS["all"] = (_SPEC_SETS["memory"] + _SPEC_SETS["sqlite"]
                     + _SPEC_SETS["segment"]
                     + ["sqlite+sharded+group", "segment+sharded+group"])


def active_store_specs():
    sel = os.environ.get("LOGIO_STORE_SPEC", "").strip()
    if not sel:
        return _SPEC_SETS["memory"]
    if sel in _SPEC_SETS:
        return _SPEC_SETS[sel]
    return [s.strip() for s in sel.split(",") if s.strip()]


@pytest.fixture(params=active_store_specs())
def store_spec(request):
    """Backend-stack spec string for ``tests.helpers.mk_store`` — the
    protocol must be oblivious to the storage stack behind LogBackend."""
    return request.param


# ---------------------------------------------------------------------------
# Process-transport matrix: process-mode tests run against the transports
# selected by the LOGIO_TRANSPORT env var — the CI matrix axis, mirroring
# LOGIO_STORE_SPEC:
#
#   unset             -> routed AND socket (fast local default; tcp-family
#                        coverage always runs via tests/test_multihost.py)
#   "all"             -> routed, socket, tcp AND shm (nightly cross)
#   "routed"          -> the supervisor-pumped pipe transport only
#   "socket"          -> the direct worker<->worker AF_UNIX transport only
#   "tcp"             -> the socket transport over AF_INET (host, port)
#   "shm"             -> shared-memory rings for co-located pairs (socket
#                        fallback across nodes)
#   anything else     -> comma list of literal transport names
# ---------------------------------------------------------------------------

_TRANSPORT_SETS = {
    "routed": ["routed"],
    "socket": ["socket"],
    "tcp": ["tcp"],
    "shm": ["shm"],
    "all": ["routed", "socket", "tcp", "shm"],
}


def active_transports():
    sel = os.environ.get("LOGIO_TRANSPORT", "").strip()
    if not sel:
        return ["routed", "socket"]
    if sel in _TRANSPORT_SETS:
        return _TRANSPORT_SETS[sel]
    return [t.strip() for t in sel.split(",") if t.strip()]


@pytest.fixture(params=active_transports())
def proc_transport(request):
    """Process-mode transport name — the recovery guarantees must be
    oblivious to how events move between workers."""
    return request.param


# ---------------------------------------------------------------------------
# Process-context matrix: process-mode tests run under the worker start
# methods selected by the LOGIO_PROC_CTX env var, mirroring LOGIO_TRANSPORT:
#
#   unset             -> fork where available, else spawn (fast local
#                        default; spawn coverage always runs via
#                        tests/test_multihost.py)
#   "all"             -> fork AND spawn (nightly runs the full
#                        fork x spawn x routed/socket/tcp cross)
#   "fork" / "spawn"  -> that start method only
#   anything else     -> comma list of literal start-method names
#
# spawn workers are rebuilt purely from the picklable WorkerBootstrap
# payload + the log — no fork inheritance — so this axis proves the
# recovery guarantees hold for workers started from durable state alone.
# ---------------------------------------------------------------------------

_CTX_SETS = {
    "fork": ["fork"],
    "spawn": ["spawn"],
    "all": ["fork", "spawn"],
}


def active_ctxs():
    import multiprocessing
    avail = multiprocessing.get_all_start_methods()
    sel = os.environ.get("LOGIO_PROC_CTX", "").strip()
    if not sel:
        return ["fork"] if "fork" in avail else ["spawn"]
    if sel == "all":
        # "whatever this platform has" — filtering is correct here
        return [c for c in _CTX_SETS["all"] if c in avail] or ["spawn"]
    if sel in _CTX_SETS:
        return _CTX_SETS[sel]
    return [c.strip() for c in sel.split(",") if c.strip()]


@pytest.fixture(params=active_ctxs())
def proc_ctx(request):
    """Process-mode worker start method (fork/spawn).  An explicitly
    requested method that this platform lacks skips loudly — a cell
    labeled fork must never silently go green by running spawn."""
    import multiprocessing
    if request.param not in multiprocessing.get_all_start_methods():
        pytest.skip(f"start method {request.param!r} unavailable here")
    return request.param
