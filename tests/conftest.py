import os
import sys

# tests must see exactly ONE device (the dry-run forces 512 in its own
# process only); make sure nothing leaks XLA_FLAGS into the test run
os.environ.pop("XLA_FLAGS", None)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
