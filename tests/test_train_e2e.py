"""End-to-end training fault tolerance: trainer crash + pipeline-worker crash
must replay to a BIT-IDENTICAL trajectory and final state (exactly-once batch
consumption at checkpoint granularity)."""
import numpy as np
import pytest

import jax

from repro.launch.train import run_training


@pytest.mark.slow
@pytest.mark.timeout(600)      # jax compile + two full training runs
def test_bit_identical_resume(tmp_path):
    a = run_training(steps=10, ckpt_every=3, seq_len=64, batch_size=4,
                     ckpt_dir=str(tmp_path / "a"), d_model=64, n_layers=2,
                     verbose=False, seed=3)
    b = run_training(steps=10, ckpt_every=3, seq_len=64, batch_size=4,
                     ckpt_dir=str(tmp_path / "b"), d_model=64, n_layers=2,
                     verbose=False, seed=3, kill_trainer_at=7,
                     kill_worker_at=2)
    # pre-crash prefix identical
    assert b["losses"][:7] == a["losses"][:7]
    # post-crash: replays from the last checkpoint (step 6) onward
    assert b["losses"][7:] == a["losses"][6:]
    assert b["engine"].failures >= 2     # worker + feed-group kill
    same = all(np.allclose(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a["final_state"]),
                               jax.tree.leaves(b["final_state"])))
    assert same


@pytest.mark.slow
@pytest.mark.timeout(600)      # jax compile + a full training run
def test_worker_crash_nonblocking(tmp_path):
    out = run_training(steps=8, ckpt_every=4, seq_len=64, batch_size=4,
                       ckpt_dir=str(tmp_path / "w"), d_model=64, n_layers=2,
                       verbose=False, seed=1, kill_worker_at=2)
    assert out["steps"] == 8
    assert out["engine"].failures == 1
    assert out["engine"].restarts == 1
