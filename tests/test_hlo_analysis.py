"""Unit tests for the loop-aware HLO analyzer (roofline source of truth)."""
import pytest

import jax
import jax.numpy as jnp

from repro.parallel import hlo_analysis


def _compile(fn, *shapes):
    return jax.jit(fn).lower(*shapes).compile()


def test_scan_trip_count_multiplies_flops():
    N, D = 12, 64

    def f(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    compiled = _compile(f, jax.ShapeDtypeStruct((N, D, D), jnp.float32),
                        jax.ShapeDtypeStruct((8, D), jnp.float32))
    m = hlo_analysis.HloModule(compiled.as_text())
    # one dot per iteration: 2 * 8 * D * D * N
    expect = 2 * 8 * D * D * N
    assert m.dot_flops() == pytest.approx(expect, rel=0.01)
    assert any(w["trip"] == N for w in m.whiles)


def test_nested_scan_multiplier():
    A, B, D = 3, 5, 32

    def f(w, x):
        def outer(c, _):
            def inner(ci, wl):
                return ci @ wl, None
            y, _ = jax.lax.scan(inner, c, w)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=A)
        return y

    compiled = _compile(f, jax.ShapeDtypeStruct((B, D, D), jnp.float32),
                        jax.ShapeDtypeStruct((4, D), jnp.float32))
    m = hlo_analysis.HloModule(compiled.as_text())
    assert m.dot_flops() == pytest.approx(2 * 4 * D * D * A * B, rel=0.01)


def test_memory_bytes_fusion_aware():
    def f(x):
        return jnp.sum(jnp.tanh(x) * 2.0 + 1.0)

    compiled = _compile(f, jax.ShapeDtypeStruct((1024, 1024), jnp.float32))
    m = hlo_analysis.HloModule(compiled.as_text())
    nbytes = 1024 * 1024 * 4
    # fused elementwise chain: ~1 read of x (+tiny output), NOT 4 round trips
    assert m.memory_bytes() < 2.5 * nbytes


def test_shape_parser():
    assert hlo_analysis._bytes_of_type("f32[128,256]{1,0}") == 128 * 256 * 4
    assert hlo_analysis._bytes_of_type("bf16[8]{0}") == 16
    assert hlo_analysis._bytes_of_type(
        "(s32[], f32[4,4]{1,0}, /*index=5*/pred[2]{0})") == 4 + 64 + 2
