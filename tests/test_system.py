"""End-to-end behaviour tests for the LOG.io system (step + thread modes)."""

from repro.core import Engine, FailureInjector, LineageQuery, LineageScope
from tests.helpers import diamond_pipeline, linear_pipeline, sink_outputs


def test_happy_path_exactly_once():
    build, expected = linear_pipeline()
    eng = Engine(build(), mode="step")
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected


def test_happy_path_with_writer_ops():
    build, expected = linear_pipeline(writes=1)
    eng = Engine(build(), mode="step")
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected
    win_writes = [b for b in eng.external.committed()
                  if isinstance(b, dict) and "inset" in b]
    assert len(win_writes) == 5


def test_diamond_topology():
    build, expected = diamond_pipeline()
    eng = Engine(build(), mode="step")
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected


def test_diamond_with_failures():
    build, expected = diamond_pipeline()
    inj = FailureInjector([("join", "pre_log", 1), ("fast", "post_log", 3),
                           ("src", "source_post_log", 7)])
    eng = Engine(build(), mode="step", injector=inj)
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected
    assert eng.failures == 3


def test_thread_mode_with_failure():
    build, expected = linear_pipeline()
    inj = FailureInjector([("win", "post_log", 2)])
    eng = Engine(build(), mode="thread", injector=inj, restart_delay=0.01)
    eng.start()
    assert eng.wait(30)
    assert sink_outputs(eng) == expected
    assert eng.failures == 1


def test_non_blocking_recovery_only_failed_group_restarts():
    build, expected = linear_pipeline()
    inj = FailureInjector([("win", "pre_log", 1)])
    eng = Engine(build(), mode="thread", injector=inj, restart_delay=0.05)
    eng.start()
    assert eng.wait(30)
    # only the failed group restarted (LOG.io is non-blocking)
    assert eng.restarts == 1
    assert sink_outputs(eng) == expected


def test_lineage_backward_forward():
    build, expected = linear_pipeline()
    scopes = [LineageScope(("src", "out"), ("win", "out"))]
    eng = Engine(build(), mode="step", lineage_scopes=scopes)
    assert eng.run_to_completion()
    q = LineageQuery(eng.store)
    back = q.backward(("win", "out", 0)).keys()
    assert ("src", "out", 0) in back and ("src", "out", 3) in back
    assert ("src", "out", 4) not in back     # no false contributors
    fwd = q.forward(("src", "out", 2), "map").keys()
    assert ("win", "out", 0) in fwd
    assert ("win", "out", 1) not in fwd


def test_lineage_correct_under_failure():
    build, expected = linear_pipeline()
    scopes = [LineageScope(("src", "out"), ("win", "out"))]
    inj = FailureInjector([("win", "post_log", 1), ("map", "pre_log", 5)])
    eng = Engine(build(), mode="step", lineage_scopes=scopes, injector=inj)
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected
    q = LineageQuery(eng.store)
    for i in range(5):
        back = q.backward(("win", "out", i))
        srcs = sorted(k.ssn for k in back if k.op == "src")
        assert srcs == list(range(i * 4, (i + 1) * 4))


def test_nondeterministic_operator_recovers():
    """Operators may be non-deterministic (general programming model):
    recovery must still deliver exactly one output per window, and every
    output must be valid for some failure-free execution."""
    import random

    from repro.core import (CountWindowOperator, GeneratorSource, Pipeline,
                            ReadSource, TerminalSink)

    def build():
        rng = random.Random()   # unseeded: non-deterministic payload salt

        p = Pipeline()
        p.add(lambda: GeneratorSource(
            "src", ReadSource([{"v": i} for i in range(12)])))
        p.add(lambda: CountWindowOperator(
            "win", 3, agg=lambda bs: {"s": sum(b["v"] for b in bs),
                                      "salt": rng.random()}))
        p.add(lambda: TerminalSink("sink", target=4))
        p.connect("src", "out", "win", "in")
        p.connect("win", "out", "sink", "in")
        return p

    inj = FailureInjector([("win", "post_ack_log", 5)])
    eng = Engine(build(), mode="step", injector=inj)
    assert eng.run_to_completion()
    outs = sink_outputs(eng)
    assert [o["s"] for o in outs] == [0 + 1 + 2, 3 + 4 + 5, 6 + 7 + 8,
                                      9 + 10 + 11]


def test_non_replayable_source():
    """Non-replayable read actions: effect stored first (Alg 1 step 2),
    failures replay from the store, exactly-once output preserved."""
    from repro.core import (GeneratorSource, Pipeline, ReadSource,
                            TerminalSink)

    class OneShotSource(ReadSource):
        """Returns different data on re-execution (non-replayable)."""
        def __init__(self, n):
            super().__init__([], replayable=False)
            self.n = n
            self.executions = 0

        def effect(self, desc, from_offset=0):
            self.executions += 1
            base = self.executions * 1000
            return [{"v": base + i} for i in range(self.n)]

    src_sys = OneShotSource(8)

    def build():
        p = Pipeline()
        p.add(lambda: GeneratorSource("src", src_sys))
        p.add(lambda: TerminalSink("sink", target=8))
        p.connect("src", "out", "sink", "in")
        return p

    inj = FailureInjector([("src", "source_post_log", 3)])
    eng = Engine(build(), mode="step", injector=inj)
    assert eng.run_to_completion()
    outs = sink_outputs(eng)
    # the stored effect was used across the failure: all from ONE execution
    bases = {o["v"] // 1000 for o in outs}
    assert len(bases) == 1
    assert sorted(o["v"] % 1000 for o in outs) == list(range(8))


def test_slow_generate_on_final_events_not_lost():
    """A generate slower than the idle double-check window must not race
    the shutdown: the queued trigger (input already acked and out of the
    channel) is live work, and its outputs must still reach a consumer
    whose thread would otherwise have exited."""
    import time

    from repro.core import (CountWindowOperator, GeneratorSource,
                            MapOperator, Pipeline, ReadSource, TerminalSink)

    n, window = 64, 4

    def slow_tail(b):
        if b["v"] >= n - 24:            # stall the tail, incl. the final event
            time.sleep(0.012)
        return {"v": b["v"] * 2}

    def build():
        p = Pipeline()
        p.add(lambda: GeneratorSource(
            "src", ReadSource([{"v": i} for i in range(n)])))
        p.add(lambda: MapOperator("map", fn=slow_tail))
        p.add(lambda: CountWindowOperator(
            "win", window, agg=lambda bs: {"s": sum(b["v"] for b in bs)}))
        p.add(lambda: TerminalSink("sink", target=n // window))
        p.connect("src", "out", "map", "in")
        p.connect("map", "out", "win", "in")
        p.connect("win", "out", "sink", "in")
        return p

    eng = Engine(build(), mode="thread")
    eng.start()
    assert eng.wait(30)
    assert [o["s"] for o in sink_outputs(eng)] == [
        sum(2 * j for j in range(i * window, (i + 1) * window))
        for i in range(n // window)]
