"""Shared pipeline builders + expectations for the test suite.

Every factory here is built from module-level callables and
``functools.partial`` — no closures — so the pipelines are picklable and
work under ``Engine(mode="process", ctx="spawn")`` and on cluster node
agents, where the worker bootstrap payload crosses process boundaries by
pickle instead of fork inheritance.
"""
from __future__ import annotations

import os
import tempfile
from functools import partial

from repro.core import (CountWindowOperator, Engine, GeneratorSource,
                        MapOperator, Pipeline, ReadSource, SyncJoinOperator,
                        TerminalSink)
from repro.core.logstore import StoreConfig, build_store


# -- picklable operator functions (spawn-safe: no lambdas/closures) ---------

def double_v(b):
    return {"v": b["v"] * 2}


def win_sum(bs):
    return {"s": sum(b["v"] for b in bs)}


def _fast_fn(b):
    return {"v": b["v"] + 1}


def _slow_fn(b):
    return {"v": b["v"] * 10}


def _join_agg(a, b):
    return {"sa": sum(x["v"] for x in a),
            "sb": sum(x["v"] for x in b)}


def mk_store(spec: str, **kw):
    """build_store with a fresh temp path for durable-family specs, so each
    test case gets its own durable files. Segment-family specs run with a
    small segment size and checkpoint interval so rotation + checkpoint
    compaction exercise live under the whole protocol matrix."""
    if spec.startswith("sqlite") and "path" not in kw:
        d = tempfile.mkdtemp(prefix="logio-db-")
        kw["path"] = os.path.join(d, "log.db")
    if spec.startswith("segment"):
        if "path" not in kw:
            d = tempfile.mkdtemp(prefix="logio-segs-")
            kw["path"] = os.path.join(d, "log.segs")
        kw.setdefault("segment_bytes", 32 * 1024)
        kw.setdefault("checkpoint_interval", 25)
        return build_store(StoreConfig.parse(spec, **kw))
    return build_store(spec, **kw)


class FileExternalSystem:
    """Durable, checkable external system backed by an append-only file —
    survives a ``kill -9`` of the whole engine process (the paper's
    external destination is a durable third party). A torn final record
    (killed mid-append) is ignored, like a real system dropping a partial
    request."""

    def __init__(self, path: str):
        import pickle
        import threading
        self.path = path
        self._pickle = pickle
        self._lock = threading.Lock()   # RPC threads of different workers
        self.writes = {}
        self.order = []
        if os.path.exists(path):
            with open(path, "rb") as f:
                while True:
                    try:
                        k, body = self._pickle.load(f)
                    except (EOFError, self._pickle.UnpicklingError):
                        break
                    if k not in self.writes:
                        self.writes[k] = body
                        self.order.append(k)

    def execute(self, op_id, conn_id, event_id, body) -> bool:
        k = (op_id, conn_id, event_id)
        with self._lock:
            if k not in self.writes:
                with open(self.path, "ab") as f:
                    self._pickle.dump((k, body), f)
                    f.flush()
                    os.fsync(f.fileno())
                self.writes[k] = body
                self.order.append(k)
        return True

    def status(self, op_id, conn_id, event_id) -> str:
        with self._lock:
            return "success" if (op_id, conn_id, event_id) in self.writes \
                else "unknown"

    def committed(self):
        with self._lock:
            return [self.writes[k] for k in self.order]


def linear_pipeline(n_events: int = 20, window: int = 4,
                    sink_target: int = 5, writes: int = 0,
                    rate: float = 0.0):
    """src -> map(x2) -> win(sum of window) -> sink."""
    def build():
        p = Pipeline()
        p.add(partial(GeneratorSource, "src",
                      ReadSource([{"v": i} for i in range(n_events)]),
                      rate=rate))
        p.add(partial(MapOperator, "map", fn=double_v))
        p.add(partial(CountWindowOperator, "win", window, agg=win_sum,
                      writes_per_output=writes))
        p.add(partial(TerminalSink, "sink", target=sink_target))
        p.connect("src", "out", "map", "in")
        p.connect("map", "out", "win", "in")
        p.connect("win", "out", "sink", "in")
        return p
    expected = [{"s": sum(2 * j for j in range(i * window, (i + 1) * window))}
                for i in range(sink_target)]
    return build, expected


def diamond_pipeline(n_events: int = 30, n1: int = 6, n2: int = 3,
                     sink_target: int = 5):
    """src fans out to fast/slow branches joined by a synchronized operator
    (UC2 topology)."""
    def build():
        p = Pipeline()
        p.add(partial(GeneratorSource, "src",
                      ReadSource([{"v": i} for i in range(n_events)])))
        p.add(partial(MapOperator, "fast", fn=_fast_fn))
        p.add(partial(MapOperator, "slow", fn=_slow_fn))
        p.add(partial(SyncJoinOperator, "join", n1, n2, agg=_join_agg))
        p.add(partial(TerminalSink, "sink", target=sink_target))
        p.connect("src", "out", "fast", "in")
        p.connect("src", "out", "slow", "in")
        p.connect("fast", "out", "join", "in1")
        p.connect("slow", "out", "join", "in2")
        p.connect("join", "out", "sink", "in")
        return p
    expected = [
        {"sa": sum(j + 1 for j in range(i * n1, (i + 1) * n1)),
         "sb": sum(j * 10 for j in range(i * n2, (i + 1) * n2))}
        for i in range(sink_target)]
    return build, expected


def sink_outputs(engine: Engine):
    return [b for b in engine.external.committed()
            if not (isinstance(b, dict) and "inset" in b)]
