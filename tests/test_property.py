"""Hypothesis property tests: the system's core invariants under randomized
workloads, topologies, and failure injection.

Invariants:
  P1  exactly-once: the sink's externally committed sequence equals the
      failure-free expectation regardless of injected failures.
  P2  LOG.io and ABS commit the same external effects for deterministic
      pipelines.
  P3  captured lineage == ground-truth contributor sets.
  P4  the batched wire protocol is a lossless, order-preserving codec for
      arbitrary event/ack interleavings under arbitrary chunking.
"""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import HealthCheck, given, settings

from repro.core import (CountWindowOperator, Engine, FailureInjector,
                        GeneratorSource, LineageScope, MapOperator, Pipeline,
                        ReadSource, TerminalSink, backward)
from tests.helpers import sink_outputs

POINTS = ["pre_filter", "pre_state_update", "post_ack_log", "pre_log",
          "post_log", "post_send", "source_post_log"]

OPS = ["src", "map", "win", "sink"]


def _build(n_events, window, mult):
    n_windows = n_events // window

    def build():
        p = Pipeline()
        p.add(lambda: GeneratorSource(
            "src", ReadSource([{"v": i} for i in range(n_events)])))
        p.add(lambda: MapOperator("map", fn=lambda b: {"v": b["v"] * mult}))
        p.add(lambda: CountWindowOperator(
            "win", window, agg=lambda bs: {"s": sum(b["v"] for b in bs)}))
        p.add(lambda: TerminalSink("sink", target=n_windows))
        p.connect("src", "out", "map", "in")
        p.connect("map", "out", "win", "in")
        p.connect("win", "out", "sink", "in")
        return p

    expected = [{"s": sum(mult * j for j in range(i * window,
                                                  (i + 1) * window))}
                for i in range(n_windows)]
    return build, expected


failure_plan = st.lists(
    st.tuples(st.sampled_from(OPS), st.sampled_from(POINTS),
              st.integers(1, 12)),
    min_size=0, max_size=3)


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n_windows=st.integers(2, 6), window=st.integers(1, 5),
       mult=st.integers(1, 7), plan=failure_plan)
def test_exactly_once_under_random_failures(n_windows, window, mult, plan):
    build, expected = _build(n_windows * window, window, mult)
    eng = Engine(build(), mode="step", injector=FailureInjector(plan))
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n_windows=st.integers(2, 5), window=st.integers(1, 4),
       mult=st.integers(1, 5), plan=failure_plan)
def test_replay_mode_random_failures(n_windows, window, mult, plan):
    build, expected = _build(n_windows * window, window, mult)
    scopes = [LineageScope(("src", "out"), ("map", "out"))]
    eng = Engine(build(), mode="step", lineage_scopes=scopes,
                 replay_ops={"map"}, injector=FailureInjector(plan))
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n_windows=st.integers(2, 5), window=st.integers(1, 4),
       mult=st.integers(1, 5))
def test_logio_equals_abs_effects(n_windows, window, mult):
    build, expected = _build(n_windows * window, window, mult)
    eng1 = Engine(build(), mode="step")
    assert eng1.run_to_completion()
    eng2 = Engine(build(), mode="thread", protocol="abs",
                  abs_options={"epoch_events": max(2, window)})
    eng2.start()
    assert eng2.wait(30)
    assert sink_outputs(eng1) == expected
    assert sink_outputs(eng2) == expected


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n_windows=st.integers(2, 5), window=st.integers(1, 5),
       plan=failure_plan)
def test_lineage_matches_ground_truth(n_windows, window, plan):
    build, expected = _build(n_windows * window, window, 2)
    scopes = [LineageScope(("src", "out"), ("win", "out"))]
    eng = Engine(build(), mode="step", lineage_scopes=scopes,
                 injector=FailureInjector(plan))
    assert eng.run_to_completion()
    assert sink_outputs(eng) == expected
    for i in range(n_windows):
        back = backward(eng.store, ("win", "out", i))
        srcs = sorted(k[2] for k in back if k[0] == "src")
        assert srcs == list(range(i * window, (i + 1) * window)), (i, srcs)


# ---------------------------------------------------------------------------
# P4: superframe codec (the byte transports' wire format)
# ---------------------------------------------------------------------------

_names = st.text(
    alphabet=st.characters(min_codepoint=33, max_codepoint=0x2FA0),
    min_size=1, max_size=40)
_bodies = st.recursive(
    st.none() | st.booleans() | st.integers() | st.binary(max_size=64)
    | st.text(max_size=32),
    lambda children: st.lists(children, max_size=4)
    | st.dictionaries(st.text(max_size=8), children, max_size=4),
    max_leaves=8)

_wire_entries = st.lists(
    st.one_of(
        st.tuples(st.just("ev"), _names, st.integers(-2**62, 2**62),
                  st.tuples(st.dictionaries(st.text(max_size=6),
                                            st.integers(), max_size=3),
                            _bodies)),
        st.tuples(st.sampled_from(["ack", "defer", "release"]), _names,
                  st.integers(-2**62, 2**62)),
    ),
    min_size=0, max_size=30)


@settings(max_examples=120, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(entries=_wire_entries, data=st.data())
def test_superframe_roundtrip_any_interleaving(entries, data):
    from repro.core.transport import wire

    encoded = []
    for e in entries:
        if e[0] == "ev":
            header, body = e[3]
            encoded.append(("ev", e[1], e[2],
                            wire.encode_payload(header, body)))
        else:
            encoded.append(e)
    bufs, total, n_ev, n_ctrl = wire.encode_superframe(encoded)
    assert n_ev + n_ctrl == len(entries)
    stream = b"".join(bytes(b) for b in bufs)
    assert len(stream) == total

    # feed the frame in arbitrary chunk sizes
    dec = wire.SuperframeDecoder()
    out = []
    pos = 0
    while pos < len(stream):
        k = data.draw(st.integers(1, len(stream) - pos))
        out.extend(dec.feed(stream[pos:pos + k]))
        pos += k
    out.extend(dec.feed(b""))
    assert dec.pending() == 0

    assert len(out) == len(entries)
    for orig, got in zip(entries, out):
        assert got[0] == orig[0] and got[1] == orig[1] and got[2] == orig[2]
        if orig[0] == "ev":
            header, body = orig[3]
            assert got[3] == header and got[4] == body
