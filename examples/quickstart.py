"""Quickstart: LOG.io in 60 lines — build a pipeline, crash it twice,
recover exactly-once, and ask lineage questions.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (CountWindowOperator, Engine, FailureInjector,
                        GeneratorSource, LineageScope, MapOperator, Pipeline,
                        ReadSource, TerminalSink, backward, forward)


def build():
    p = Pipeline()
    # source: replayable read action over 40 sales batches
    p.add(lambda: GeneratorSource(
        "sales", ReadSource([{"amount": 10 * i} for i in range(40)])))
    # stateless enrichment
    p.add(lambda: MapOperator("fx", fn=lambda b: {"eur": b["amount"] * 0.9}))
    # stateful window aggregate (the paper's OP2 pattern)
    p.add(lambda: CountWindowOperator(
        "agg", 8, agg=lambda bs: {"total": round(sum(b["eur"] for b in bs))}))
    # sink writes durable, checkable write actions
    p.add(lambda: TerminalSink("report", target=5))
    p.connect("sales", "out", "fx", "in")
    p.connect("fx", "out", "agg", "in")
    p.connect("agg", "out", "report", "in")
    return p


def main():
    # crash the aggregate mid-generation AND the enricher mid-stream
    injector = FailureInjector([("agg", "post_log", 2), ("fx", "pre_log", 17)])
    scopes = [LineageScope(("sales", "out"), ("agg", "out"))]
    eng = Engine(build(), mode="thread", injector=injector,
                 lineage_scopes=scopes, restart_delay=0.05)
    eng.start()
    assert eng.wait(30), "pipeline did not finish"

    print(f"failures injected: {eng.failures}, groups restarted: {eng.restarts}")
    print("reports committed exactly once:")
    for r in eng.external.committed():
        print("   ", r)

    # backward lineage: which sales batches made report window #2?
    contributors = backward(eng.store, ("agg", "out", 2))
    src = sorted(k[2] for k in contributors if k[0] == "sales")
    print(f"report #2 was computed from sales batches {src}")

    # forward lineage: where did sales batch #11 end up?
    outputs = forward(eng.store, ("sales", "out", 11), "fx")
    print(f"sales batch #11 flowed into {[k for k in outputs if k[0]=='agg']}")


if __name__ == "__main__":
    main()
