"""End-to-end fault-tolerant training (deliverable (b) driver).

Trains an internlm2-family model on a LOG.io-protected data pipeline with
checkpoint write actions, kills a pipeline worker AND the trainer mid-run,
and verifies the run resumes bit-identically from the last checkpoint.

CPU demo (reduced model, ~2 min):
    PYTHONPATH=src python examples/train_e2e.py
Larger (~100M params — slow on CPU, sized for a real accelerator):
    PYTHONPATH=src python examples/train_e2e.py --big --steps 300
"""
import argparse
import shutil
import tempfile

import numpy as np

import jax

from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--big", action="store_true",
                    help="~100M params (d_model=768, 12 layers)")
    args = ap.parse_args()
    dim, layers = (768, 12) if args.big else (128, 2)

    dir_a = tempfile.mkdtemp(prefix="logio_ta_")
    dir_b = tempfile.mkdtemp(prefix="logio_tb_")
    try:
        print("== run A: failure-free ==")
        a = run_training(steps=args.steps, ckpt_every=6, seq_len=64,
                         batch_size=4, ckpt_dir=dir_a, d_model=dim,
                         n_layers=layers, seed=7, log_every=6)
        print("\n== run B: kill a pipeline worker at ~batch 4 and the "
              "trainer at step {} ==".format(args.steps * 2 // 3))
        b = run_training(steps=args.steps, ckpt_every=6, seq_len=64,
                         batch_size=4, ckpt_dir=dir_b, d_model=dim,
                         n_layers=layers, seed=7, log_every=6,
                         kill_worker_at=4,
                         kill_trainer_at=args.steps * 2 // 3)
        same = all(np.allclose(np.asarray(x), np.asarray(y))
                   for x, y in zip(jax.tree.leaves(a["final_state"]),
                                   jax.tree.leaves(b["final_state"])))
        print(f"\npipeline failures in B: {b['engine'].failures}; "
              f"final states identical: {same}")
        assert same, "resume was not bit-identical!"
        print("OK: crash-recovery resumed the exact trajectory.")
    finally:
        shutil.rmtree(dir_a, ignore_errors=True)
        shutil.rmtree(dir_b, ignore_errors=True)


if __name__ == "__main__":
    main()
