"""Batched serving demo: slot-based continuous batching over decode_step.

    PYTHONPATH=src python examples/serve_batched.py [--arch gemma2-9b]

Uses a reduced config of the chosen architecture (CPU); the identical
serve_step is what the decode_32k / long_500k dry-run cells lower for the
production mesh.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.serving import SlotServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    full = get_config(args.arch)
    cfg = reduced(full, d_model=128,
                  n_layers=2 * len(full.block) if len(full.block) == 1
                  else len(full.block))
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rt = M.Runtime(q_chunk=16, cross_len=16)
    server = SlotServer(params, cfg, rt, n_slots=4, max_len=64)

    t0 = time.time()
    pending = list(range(args.requests))
    active = {}
    done = {}
    while pending or active:
        while pending and len(active) < server.n_slots:
            req = pending.pop(0)
            rid = server.submit(prompt_token=req + 2)
            active[rid] = req
        server.step()
        for rid in list(active):
            if len(server.outputs.get(rid, [])) >= args.tokens:
                toks = server.finish(rid)
                done[active.pop(rid)] = toks
    dt = time.time() - t0
    for req in sorted(done):
        print(f"request {req}: {done[req]}")
    total = args.requests * args.tokens
    print(f"{total} tokens across {args.requests} requests in {dt:.2f}s "
          f"({total/dt:.1f} tok/s batched, arch={args.arch} reduced)")


if __name__ == "__main__":
    main()
