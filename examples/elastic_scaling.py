"""Elastic scaling demo (Sec. 7.2, Algorithms 12-13): scale a bottleneck
operator from 2 replicas to 3 under load, then down to 1 while a replica
fails — without stopping the pipeline and without losing or duplicating a
single event.

    PYTHONPATH=src python examples/elastic_scaling.py
"""
import time

from repro.core import (Engine, FailureInjector, GeneratorSource, MapOperator,
                        Pipeline, ReadSource, TerminalSink)
from repro.core.scaling import Controller, DispatcherOperator, MergerOperator

N = 120


def build():
    p = Pipeline()
    p.add(lambda: GeneratorSource(
        "src", ReadSource([{"v": i} for i in range(N)]), rate=0.002))
    p.add(lambda: DispatcherOperator("disp", ["r0", "r1"]))
    for rid in ("r0", "r1"):
        p.add(lambda rid=rid: MapOperator(
            rid, fn=lambda b: {"v": b["v"] * 2}, processing_time=0.006))
    p.add(lambda: MergerOperator("mrg", ["r0", "r1"]))
    p.add(lambda: TerminalSink("sink", target=N))
    p.connect("src", "out", "disp", "in")
    p.connect("disp", "to_r0", "r0", "in")
    p.connect("disp", "to_r1", "r1", "in")
    p.connect("r0", "out", "mrg", "from_r0")
    p.connect("r1", "out", "mrg", "from_r1")
    p.connect("mrg", "out", "sink", "in")
    return p


def main():
    inj = FailureInjector([("r0", "post_log", 25)])   # r0 dies mid-run
    eng = Engine(build(), mode="thread", injector=inj, restart_delay=0.02)
    ctrl = Controller(eng, "disp", "mrg",
                      replica_factory=lambda rid: (lambda: MapOperator(
                          rid, fn=lambda b: {"v": b["v"] * 2},
                          processing_time=0.006)))
    eng.start()
    time.sleep(0.10)
    print("scaling UP: adding replica r2 (Algorithm 12)")
    ctrl.scale_up("r2")
    time.sleep(0.15)
    print("scaling DOWN: removing replica r1 (Algorithm 13 — its pending "
          "events are atomically reassigned)")
    ctrl.scale_down("r1")
    assert eng.wait(60), "did not drain"
    got = sorted(b["v"] for b in eng.external.committed())
    expect = sorted(2 * i for i in range(N))
    print(f"replica failure mid-run: {eng.failures} failure(s), "
          f"{eng.restarts} restart(s)")
    print(f"exactly-once across scale-up + scale-down + failure: "
          f"{got == expect} ({len(got)} events)")
    assert got == expect


if __name__ == "__main__":
    main()
