"""Compatibility shim — the channel implementation is now the ``local``
transport (:mod:`repro.core.transport.local`); see
:mod:`repro.core.transport.base` for the formal interface and the credit
protocol shared by all transports."""
from repro.core.transport.local import Channel, ChannelClosed

__all__ = ["Channel", "ChannelClosed"]
