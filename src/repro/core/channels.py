"""DEPRECATED compatibility shim — the channel implementation is now the
``local`` transport (:mod:`repro.core.transport.local`); see
:mod:`repro.core.transport.base` for the formal interface and the credit
protocol shared by all transports. Importing this module warns; import
from ``repro.core.transport.local`` (or the ``repro.core`` surface)
instead."""
import warnings

from repro.core.transport.local import Channel, ChannelClosed

warnings.warn(
    "repro.core.channels is deprecated; import Channel/ChannelClosed from "
    "repro.core.transport.local instead",
    DeprecationWarning, stacklevel=2)

__all__ = ["Channel", "ChannelClosed"]
