"""Adaptive hybrid recovery controller (closes the ROADMAP item).

The paper's evaluation concedes that ABS-style epoch snapshotting beats
LOG.io's per-event logging at high event rates, while LOG.io wins under
stragglers and at moderate rates — and that data parallelization is
LOG.io's scaling lever.  :class:`RecoveryController` turns that static
comparison into a closed loop: it samples ``Engine.metrics()`` on a
cadence, derives per-group signals (event rate, commit-latency share,
credit-window stall share, queue depth, batch-run length, replay-cost
estimate) from consecutive snapshot deltas, and

  (a) switches operator groups between ``"log"`` (per-event logging,
      cheap straggler recovery) and ``"epoch"`` (interval snapshotting,
      cheap high-rate steady state) via ``Engine.set_recovery_mode`` —
      with hysteresis so a noisy signal cannot flap the protocol; and
  (b) drives a ``scaling.Controller`` to add/remove replicas so the
      Little's-law residence-time estimate (queue depth / service rate)
      stays under the configured latency SLO.

Config is the typed :class:`ControllerConfig`, a sibling of
``StoreConfig``/``TransportConfig`` (spec strings round-trip through
``ControllerConfig.parse`` / ``str``).

Every decision is appended to :attr:`RecoveryController.decisions` as
``(ts, kind, target, detail)`` so tests and benchmarks can assert the
control trajectory.  ``tick(snapshot)`` is callable directly with a
hand-built :class:`~repro.core.metrics.MetricsSnapshot`, which is how the
unit tests script deterministic traffic patterns without a live engine.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .metrics import MetricsSnapshot

#: canonical spec-field order for the ControllerConfig round-trip
_SPEC_FIELDS = ("slo_ms", "sample_interval", "switch_hysteresis",
                "min_replicas", "max_replicas", "high_rate_eps",
                "epoch_interval", "scale_cooldown")


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Typed, validated controller configuration.

    Spec strings are ``key=value`` pairs joined by commas, e.g.
    ``"slo_ms=50,switch_hysteresis=2,min_replicas=1,max_replicas=4"``;
    ``ControllerConfig.parse(spec)`` and ``str(cfg)`` round-trip.
    """

    #: latency SLO the scaler defends (estimated residence time, ms)
    slo_ms: float = 100.0
    #: seconds between metric samples in the controller loop
    sample_interval: float = 0.05
    #: consecutive agreeing samples required before a mode switch or a
    #: scale-up (scale-down additionally waits out ``scale_cooldown``)
    switch_hysteresis: int = 3
    min_replicas: int = 1
    max_replicas: int = 4
    #: sustained events/sec above which a group is "high-rate" (the
    #: regime where the paper concedes the epoch protocol wins)
    high_rate_eps: float = 2000.0
    #: generate-txns between state snapshots for groups in "epoch" mode
    epoch_interval: int = 16
    #: seconds after any scaling action before the next one
    scale_cooldown: float = 1.0

    def __post_init__(self):
        if not self.slo_ms > 0:
            raise ValueError(f"slo_ms must be > 0, got {self.slo_ms!r}")
        if not self.sample_interval > 0:
            raise ValueError(f"sample_interval must be > 0, "
                             f"got {self.sample_interval!r}")
        if self.switch_hysteresis < 1:
            raise ValueError(f"switch_hysteresis must be >= 1, "
                             f"got {self.switch_hysteresis!r}")
        if self.min_replicas < 1:
            raise ValueError(f"min_replicas must be >= 1, "
                             f"got {self.min_replicas!r}")
        if self.max_replicas < self.min_replicas:
            raise ValueError(
                f"max_replicas must be >= min_replicas "
                f"({self.min_replicas}), got {self.max_replicas!r}")
        if not self.high_rate_eps > 0:
            raise ValueError(f"high_rate_eps must be > 0, "
                             f"got {self.high_rate_eps!r}")
        if self.epoch_interval < 2:
            raise ValueError(f"epoch_interval must be >= 2, "
                             f"got {self.epoch_interval!r}")
        if self.scale_cooldown < 0:
            raise ValueError(f"scale_cooldown must be >= 0, "
                             f"got {self.scale_cooldown!r}")

    @classmethod
    def parse(cls, spec: str, **overrides) -> "ControllerConfig":
        """Parse a ``key=value,key=value`` spec string.

        Unknown keys, duplicate keys and malformed pairs raise
        ``ValueError``; keyword ``overrides`` win over the spec.
        """
        if not isinstance(spec, str) or not spec.strip():
            raise ValueError(
                f"controller spec must be a non-empty string, got {spec!r}")
        kw: Dict[str, object] = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(
                    f"malformed controller spec entry {part!r} "
                    f"(expected key=value)")
            key, _, raw = part.partition("=")
            key = key.strip()
            if key not in _SPEC_FIELDS:
                raise ValueError(
                    f"unknown controller spec key {key!r} "
                    f"(expected one of {', '.join(_SPEC_FIELDS)})")
            if key in kw:
                raise ValueError(f"duplicate controller spec key {key!r}")
            caster = cls.__dataclass_fields__[key].type
            try:
                kw[key] = (int(raw) if caster == "int" else float(raw))
            except ValueError:
                raise ValueError(
                    f"bad value for controller spec key {key!r}: {raw!r}")
        kw.update(overrides)
        return cls(**kw)

    def __str__(self) -> str:
        parts = []
        for key in _SPEC_FIELDS:
            v = getattr(self, key)
            parts.append(f"{key}={v:g}" if isinstance(v, float)
                         else f"{key}={v}")
        return ",".join(parts)


@dataclasses.dataclass
class _GroupState:
    """Per-group hysteresis bookkeeping."""
    epoch_votes: int = 0
    log_votes: int = 0
    last_events_in: int = 0
    last_commit_us: int = 0
    last_stall_us: int = 0


class RecoveryController:
    """Closed-loop recovery-mode + replica-count controller.

    Parameters
    ----------
    engine:
        the :class:`~repro.core.engine.Engine` to sense and actuate.
    config:
        a :class:`ControllerConfig` (or spec string for ``parse``).
    mode_groups:
        operator groups whose recovery mode the controller may switch;
        defaults to every non-source group with at least one stateful
        runtime.  Pass ``()`` to disable mode switching.
    scaler:
        an optional ``scaling.Controller``; when given, the controller
        holds the SLO by calling ``scale_up``/``scale_down`` with
        replica ids ``<replica_prefix>0..N``.
    """

    def __init__(self, engine, config: Optional[ControllerConfig] = None,
                 *, mode_groups: Optional[Sequence[str]] = None,
                 scaler=None, replica_prefix: str = "r",
                 initial_replicas: Optional[Sequence[str]] = None):
        if isinstance(config, str):
            config = ControllerConfig.parse(config)
        self.engine = engine
        self.config = config or ControllerConfig()
        self.scaler = scaler
        self.replica_prefix = replica_prefix
        self.replicas: List[str] = list(initial_replicas or [])
        self._replica_seq = len(self.replicas)
        self.mode_groups: Optional[Tuple[str, ...]] = (
            tuple(mode_groups) if mode_groups is not None else None)
        self.decisions: List[Tuple[float, str, str, str]] = []
        self._groups: Dict[str, _GroupState] = {}
        self._prev: Optional[MetricsSnapshot] = None
        self._slo_hot = 0          # consecutive over-SLO samples
        self._slo_cold = 0         # consecutive well-under-SLO samples
        self._last_scale_ts = 0.0
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self):
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="recovery-controller",
                                        daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=5.0)

    def _loop(self):
        while not self._stop.wait(self.config.sample_interval):
            try:
                self.tick()
            except Exception as e:    # sensing must never kill the pipeline
                self._decide("error", "-", f"{type(e).__name__}: {e}")

    # ------------------------------------------------------------------
    # the control step
    # ------------------------------------------------------------------
    def tick(self, snapshot: Optional[MetricsSnapshot] = None):
        """One control step.  Pass a scripted ``snapshot`` for
        deterministic tests; defaults to a live ``engine.metrics()``."""
        with self._lock:
            snap = snapshot if snapshot is not None else self.engine.metrics()
            prev, self._prev = self._prev, snap
            if prev is None or snap.ts <= prev.ts:
                return
            dt = snap.ts - prev.ts
            self._mode_step(prev, snap, dt)
            self._scale_step(prev, snap, dt)

    def _decide(self, kind: str, target: str, detail: str):
        self.decisions.append((time.monotonic(), kind, target, detail))

    def _managed_groups(self, snap: MetricsSnapshot) -> Sequence[str]:
        if self.mode_groups is not None:
            return self.mode_groups
        return sorted({m.group for m in snap.ops.values() if m.group})

    # -- (a) per-group recovery-mode switching --------------------------
    def _mode_step(self, prev: MetricsSnapshot, snap: MetricsSnapshot,
                   dt: float):
        cfg = self.config
        for group in self._managed_groups(snap):
            gs = self._groups.setdefault(group, _GroupState())
            ev = snap.group_total("events_in", group)
            d_ev = ev - prev.group_total("events_in", group)
            d_commit = (snap.group_total("commit_us", group)
                        - prev.group_total("commit_us", group))
            d_stall = (snap.group_total("send_stall_us", group)
                       - prev.group_total("send_stall_us", group))
            rate = d_ev / dt
            wall_us = dt * 1e6
            commit_share = d_commit / wall_us if wall_us else 0.0
            stall_share = d_stall / wall_us if wall_us else 0.0
            qdepth = snap.group_total("queue_depth", group)
            # high-rate regime: sustained arrivals above the threshold and
            # the log commit path is a real share of the wall clock — the
            # case the paper concedes to the epoch protocol.  Stall time
            # (back-pressure from a slow *downstream*) and a deep queue
            # with a LOW rate (a straggler: service-bound, not log-bound)
            # both vote for per-event logging, whose recovery replays only
            # the failed operator instead of globally restarting.
            straggler = qdepth > 0 and rate < cfg.high_rate_eps / 4
            high = (rate >= cfg.high_rate_eps and commit_share > 0.05
                    and stall_share < 0.5 and not straggler)
            if high:
                gs.epoch_votes += 1
                gs.log_votes = 0
            else:
                gs.log_votes += 1
                gs.epoch_votes = 0
            current = self.engine.recovery_mode_of(group)
            if (current != "epoch"
                    and gs.epoch_votes >= cfg.switch_hysteresis):
                self.engine.set_recovery_mode(group, "epoch")
                gs.epoch_votes = 0
                self._decide("mode", group,
                             f"epoch (rate={rate:.0f}ev/s "
                             f"commit={commit_share:.2f})")
            elif (current != "log"
                    and gs.log_votes >= cfg.switch_hysteresis):
                self.engine.set_recovery_mode(group, "log")
                gs.log_votes = 0
                self._decide("mode", group,
                             f"log (rate={rate:.0f}ev/s "
                             f"straggler={straggler} qdepth={qdepth})")

    # -- (b) SLO-driven replica scaling ---------------------------------
    def _scale_step(self, prev: MetricsSnapshot, snap: MetricsSnapshot,
                    dt: float):
        if self.scaler is None:
            return
        cfg = self.config
        est_ms = self.residence_ms(prev, snap)
        if est_ms > cfg.slo_ms:
            self._slo_hot += 1
            self._slo_cold = 0
        elif est_ms < cfg.slo_ms * 0.3:
            self._slo_cold += 1
            self._slo_hot = 0
        else:
            self._slo_hot = self._slo_cold = 0
        now = time.monotonic()
        if now - self._last_scale_ts < cfg.scale_cooldown:
            return
        n = len(self.replicas)
        if self._slo_hot >= cfg.switch_hysteresis and n < cfg.max_replicas:
            rid = f"{self.replica_prefix}{self._replica_seq}"
            self._replica_seq += 1
            self.scaler.scale_up(rid)
            self.replicas.append(rid)
            self._last_scale_ts = now
            self._slo_hot = 0
            self._decide("scale_up", rid, f"est={est_ms:.1f}ms "
                                          f"slo={cfg.slo_ms:g}ms n={n + 1}")
        elif (self._slo_cold >= cfg.switch_hysteresis * 2
                and n > cfg.min_replicas):
            rid = self.replicas.pop()
            self.scaler.scale_down(rid)
            self._last_scale_ts = now
            self._slo_cold = 0
            self._decide("scale_down", rid, f"est={est_ms:.1f}ms n={n - 1}")

    def residence_ms(self, prev: MetricsSnapshot,
                     snap: MetricsSnapshot) -> float:
        """Little's-law residence-time estimate: total queued events over
        the service rate observed between the two snapshots."""
        dt = snap.ts - prev.ts
        if dt <= 0:
            return 0.0
        served = (snap.group_total("events_in")
                  - prev.group_total("events_in"))
        qdepth = snap.group_total("queue_depth")
        if qdepth == 0:
            return 0.0
        if served <= 0:
            return float("inf")
        return qdepth / (served / dt) * 1e3
