# Transport layer: reliable FIFO channels with credit-based back-pressure
# behind the formal interfaces in `base` (Sec. 2.1's channel contract).
from repro.core.transport.base import (ChannelEndpoint, SupervisorTransport,
                                       WorkerTransport,
                                       make_supervisor_transport,
                                       make_worker_transport,
                                       register_transport, transport_names)
from repro.core.transport.local import Channel, ChannelClosed
