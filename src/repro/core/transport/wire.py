"""Batched binary wire format for worker-to-worker transports.

One **superframe** coalesces every event, ack, defer and release queued
for a peer into a single length-prefixed binary frame, written with one
vectored write (``os.writev``) per flusher wakeup — the per-event
``multiprocessing.connection`` object protocol (one pickle + one
``send()`` syscall per event, one more per ack) amortizes to a few
syscalls per *batch*.  Event payloads are pickled **once** at ``put()``
time (the same encode the log's ``put_event_blob`` op persists) and
carried here as buffer slices; the encoder never copies or re-pickles
them — reconnect-replay re-transmits the cached blob bytes verbatim.

Frame layout (little-endian)::

    u32 body_len                      # bytes after this word
    entry*                            # back to back until body_len

    event entry:
      u8  kind = 0
      u16 name_len                    # channel name (utf-8)
      i64 event_id
      u32 payload_len
      name bytes
      payload bytes                   # pickle((header, body))

    control entry (ack=1 / defer=2 / release=3):
      u8  kind
      u16 name_len
      i64 event_id
      name bytes

Channel identity rides as the channel *name* only: the receiver rebuilds
the :class:`~repro.core.events.Event` routing fields from its own
channel spec, so the wire never carries pickled Event objects — just the
(header, body) payload blob both the transport and the log share.

The decoder is stateful (``feed`` accepts arbitrary byte chunks) and
yields fully-decoded entries: payloads are unpickled immediately from a
view over the receive buffer, so the buffer can compact without keeping
exported memoryviews alive.
"""
from __future__ import annotations

import os
import pickle
import struct
from typing import Any, Iterable, List, Tuple

EV = 0
ACK = 1
DEFER = 2
RELEASE = 3

_CTRL_KINDS = {"ack": ACK, "defer": DEFER, "release": RELEASE}
_CTRL_NAMES = {ACK: "ack", DEFER: "defer", RELEASE: "release"}

_EV_HDR = struct.Struct("<BHqI")      # kind, name_len, event_id, payload_len
_CTRL_HDR = struct.Struct("<BHq")     # kind, name_len, event_id
_LEN = struct.Struct("<I")

#: cap on buffers per writev call (POSIX guarantees IOV_MAX >= 16; linux
#: has 1024 — stay under it and loop)
_IOV_MAX = 512


def encode_payload(header: dict, body: Any) -> bytes:
    """The shared event-payload encode: what the transport ships and what
    ``put_event_blob`` persists (also ``MemoryLogStore._make_blob``'s
    eager format, so log reads decode it unchanged)."""
    return pickle.dumps((header, body))


def entry_size(entry: Tuple) -> int:
    """Encoded size of one wire entry (shm framing uses it to split
    batches into frames that fit the ring)."""
    if entry[0] == "ev":
        return _EV_HDR.size + len(entry[1].encode("utf-8")) + len(entry[3])
    return _CTRL_HDR.size + len(entry[1].encode("utf-8"))


def encode_superframe(entries: Iterable[Tuple]) -> Tuple[List, int, int, int]:
    """Encode entries into writev-ready buffers.

    ``entries`` are ``("ev", name, event_id, payload_bytes)`` or
    ``("ack"|"defer"|"release", name, event_id)``.  Returns
    ``(buffers, total_bytes, n_events, n_ctrl)`` — payload bytes appear
    in ``buffers`` as-is (zero copy); everything else accumulates into
    shared header chunks.
    """
    head = bytearray(_LEN.size)           # body_len patched at the end
    bufs: List = [head]
    cur = head
    n_ev = n_ctrl = 0
    total = _LEN.size
    for entry in entries:
        name = entry[1].encode("utf-8")
        if entry[0] == "ev":
            payload = entry[3]
            cur += _EV_HDR.pack(EV, len(name), entry[2], len(payload))
            cur += name
            total += _EV_HDR.size + len(name) + len(payload)
            bufs.append(payload)
            cur = bytearray()             # next header chunk after payload
            bufs.append(cur)
            n_ev += 1
        else:
            cur += _CTRL_HDR.pack(_CTRL_KINDS[entry[0]], len(name), entry[2])
            cur += name
            total += _CTRL_HDR.size + len(name)
            n_ctrl += 1
    if not bufs[-1]:
        bufs.pop()
    _LEN.pack_into(head, 0, total - _LEN.size)
    return bufs, total, n_ev, n_ctrl


def write_buffers(fd: int, bufs: List, total: int) -> None:
    """Vectored write of ``bufs`` to a blocking fd, handling partial
    writes and the IOV_MAX cap."""
    bufs = [b for b in bufs if len(b)]
    i = 0
    offset = 0                        # into bufs[i]
    remaining = total
    while remaining > 0:
        batch = bufs[i:i + _IOV_MAX]
        if offset:
            batch[0] = memoryview(batch[0])[offset:]
        n = os.writev(fd, batch)
        remaining -= n
        # advance (i, offset) past the n bytes written
        n += offset
        while i < len(bufs) and n >= len(bufs[i]):
            n -= len(bufs[i])
            i += 1
        offset = n


class SuperframeDecoder:
    """Incremental superframe decoder: ``feed`` arbitrary chunks, get
    back fully-decoded entries — ``("ev", name, event_id, header, body)``
    (payload already unpickled) or ``("ack"|"defer"|"release", name,
    event_id)``."""

    def __init__(self):
        self._buf = bytearray()

    def pending(self) -> int:
        return len(self._buf)

    def feed(self, data) -> List[Tuple]:
        self._buf += data
        out: List[Tuple] = []
        pos = 0
        buf = self._buf
        while True:
            if len(buf) - pos < _LEN.size:
                break
            (body_len,) = _LEN.unpack_from(buf, pos)
            if len(buf) - pos - _LEN.size < body_len:
                break
            view = memoryview(buf)
            try:
                self._decode_body(view, pos + _LEN.size, body_len, out)
            finally:
                view.release()        # else the compaction below raises
            pos += _LEN.size + body_len
        if pos:
            del self._buf[:pos]
        return out

    @staticmethod
    def _decode_body(view, pos: int, body_len: int, out: List[Tuple]):
        end = pos + body_len
        while pos < end:
            kind = view[pos]
            if kind == EV:
                _, name_len, event_id, payload_len = _EV_HDR.unpack_from(
                    view, pos)
                pos += _EV_HDR.size
                name = bytes(view[pos:pos + name_len]).decode("utf-8")
                pos += name_len
                header, body = pickle.loads(view[pos:pos + payload_len])
                pos += payload_len
                out.append(("ev", name, event_id, header, body))
            else:
                _, name_len, event_id = _CTRL_HDR.unpack_from(view, pos)
                pos += _CTRL_HDR.size
                name = bytes(view[pos:pos + name_len]).decode("utf-8")
                pos += name_len
                out.append((_CTRL_NAMES[kind], name, event_id))
