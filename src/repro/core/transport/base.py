"""Formal transport interfaces (Sec. 2.1's reliable FIFO channel contract).

The paper's correctness argument assumes a transport that is

  * **reliable** — an event put on a channel is never lost while any party
    that logged it as sent can still need it re-delivered;
  * **FIFO per channel** — events arrive at the receiver in put order;
  * **capacity back-pressured** — a sender blocks (abortably) when the
    receiver's credit window is exhausted, so no component buffers an
    unbounded number of in-flight events.

Three implementations satisfy the contract:

``local``   (:mod:`repro.core.transport.local`) — the in-thread/in-process
            :class:`Channel`: one shared buffer is both endpoints, capacity
            blocking *is* the credit window (used by thread and step mode,
            and for intra-group edges inside process-mode workers).
``routed``  (:mod:`repro.core.transport.routed`) — the supervisor-pumped
            pipe transport of process mode: the authoritative buffer lives
            in the supervisor, workers hold replicas, and senders spend
            explicit credits granted by the supervisor (returned when an
            event leaves the authoritative buffer at ack/release time).
``socket``  (:mod:`repro.core.transport.socketmode`) — direct worker-to-
            worker socket channels: the *sender-side worker* holds the
            reliable buffer (bounded at the credit window; acks returning
            over the socket are the credit grants) and event payloads
            bypass the supervisor entirely.  The supervisor retains only
            the authoritative *recovery* view: buffer contents are
            re-derivable from the log on restart, so a lost buffer is
            repaired by the protocol's resend path (Alg 6/7).

Credit protocol (all transports)
--------------------------------
Every channel has a credit window ``W`` (= its configured capacity).  The
invariant is ``buffered + credits_held_by_sender <= W`` where *buffered*
counts every event not yet released (deferred acks keep occupying their
credit until ``release_ack`` — the durability-watermark rule).  A sender
out of credits blocks FIFO and abortably: engine stop, channel close, or a
``stop_flag`` wake it with ``put() == False``.  On a warm restart the
window is recomputed from the surviving buffer (routed: the supervisor
re-grants ``W - len(buffer)`` to the fresh sender incarnation; socket: the
fresh sender's buffer is rebuilt from the log resend, implicitly resetting
the window), so a SIGKILL'd receiver never strands a sender.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple


class ChannelEndpoint(abc.ABC):
    """The channel verbs the operator runtime and the engine consume.

    ``peek``/``ack`` carry the Sec. 2.1 receive contract (an event leaves
    the channel only once acknowledged); ``defer_ack``/``release_ack`` are
    the durability-watermark split used by group-commit pipelining;
    ``reset_pending`` is the receiver-restart rewind.
    """

    send_op: str
    send_port: str
    rec_op: str
    rec_port: str
    capacity: int

    @property
    def name(self) -> str:
        return f"{self.send_op}.{self.send_port}->{self.rec_op}.{self.rec_port}"

    # -- sender side -------------------------------------------------------
    @abc.abstractmethod
    def put(self, ev, stop_flag: Optional[Callable[[], bool]] = None,
            timeout: float = 0.05) -> bool:
        """Blocking, credit-gated put. False = aborted (stop/close)."""

    # -- receiver side -----------------------------------------------------
    @abc.abstractmethod
    def peek(self):
        """Head of the unprocessed suffix (skips deferred-ack events)."""

    @abc.abstractmethod
    def ack(self):
        """Immediately consume the event ``peek`` returned."""

    @abc.abstractmethod
    def defer_ack(self) -> None:
        """Mark the head processed-but-unreleased (still holds its credit)."""

    @abc.abstractmethod
    def release_ack(self):
        """Release the oldest deferred ack (FIFO); returns its credit."""

    @abc.abstractmethod
    def reset_pending(self) -> None:
        """Receiver restart: unreleased events become deliverable again."""

    # -- vectored receiver verbs (micro-batching) --------------------------
    # Defaults degrade to the scalar verbs so every endpoint is correct;
    # implementations override to amortize locks / control messages when a
    # run of events is consumed in one pass.
    def peek_run(self, n: int) -> list:
        """Up to ``n`` events from the head of the unprocessed suffix (FIFO
        snapshot; nothing is consumed until acked/deferred)."""
        ev = self.peek()
        return [ev] if n > 0 and ev is not None else []

    def ack_run(self, n: int) -> int:
        """Vectored ``ack``; returns the count actually consumed."""
        k = 0
        while k < n and self.ack() is not None:
            k += 1
        return k

    def defer_run(self, n: int) -> int:
        """Vectored ``defer_ack``; returns the count actually deferred."""
        for _ in range(n):
            self.defer_ack()
        return n

    @abc.abstractmethod
    def __len__(self) -> int:
        """Events occupying credits (buffered, including deferred)."""


# ---------------------------------------------------------------------------
# spawn-safe worker bootstrap
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChannelSpec:
    """Picklable description of one channel — what a worker transport needs
    to rebuild its endpoints without touching the live (unpicklable)
    supervisor-side :class:`~repro.core.transport.local.Channel` objects."""

    send_op: str
    send_port: str
    rec_op: str
    rec_port: str
    capacity: int

    @property
    def name(self) -> str:
        return f"{self.send_op}.{self.send_port}->{self.rec_op}.{self.rec_port}"


@dataclasses.dataclass
class WorkerBootstrap:
    """Everything a worker process needs to rebuild its operator group —
    picklable by stdlib :mod:`pickle`, so a worker can start under the
    ``spawn`` multiprocessing context (or, in principle, an ``ssh`` /
    container entrypoint) and never relies on fork-inherited parent
    memory.  Recovery state is NOT here: the worker rebuilds volatile
    operator state purely from this payload plus the shared log (over the
    store RPC).

    ``factories`` holds only this group's operator factories; under
    ``spawn`` they must be picklable (module-level callables /
    ``functools.partial`` — no closures).  ``control`` is the supervisor's
    rendezvous for workers launched by a node agent: ``((host, port),
    authkey)`` of the control hub; such workers dial back their RPC and
    transport connections instead of inheriting pipes.
    """

    group: str
    incarnation: int
    recover: bool
    transport: str
    transport_options: Dict[str, Any]
    factories: Dict[str, Callable]
    connections: List[Tuple[str, str, str, str, int]]
    groups: Dict[str, str]
    lineage_ports: Dict[str, Tuple]
    replay_ops: frozenset
    control: Optional[Tuple[Any, bytes]] = None
    #: batching-governor spec for the group's receivers ("off" | "adaptive"
    #: | int); None defers to the LOGIO_BATCH env var in the worker
    batching: Optional[Any] = None
    #: per-group recovery-mode payload: ``{"modes": {group: "epoch"},
    #: "stale": [groups], "interval": N}`` — groups in "epoch" mode run
    #: interval state snapshotting and recover with the DONE-inclusive
    #: scan; "stale" marks groups freshly switched off epoch whose next
    #: recovery must still include DONE rows.  None (old payloads) means
    #: every group is in "log" mode.
    recovery: Optional[Dict[str, Any]] = None

    @property
    def channels(self) -> List[ChannelSpec]:
        return [ChannelSpec(s, sp, d, dp, cap)
                for (s, sp, d, dp, cap) in self.connections]

    def group_ops(self) -> List[str]:
        return [o for o, g in self.groups.items() if g == self.group]


class Placement:
    """Group -> node assignment for process mode.  ``None`` means "spawn a
    direct child of the supervisor" (the single-host default); a node name
    means "launch via that node's agent" (:class:`repro.core.cluster`
    resolves names to agent processes).  Mutable so dynamic scaling can
    place new replicas (`assign`) before ``start_group`` spawns them."""

    def __init__(self, mapping: Optional[Dict[str, Optional[str]]] = None,
                 default: Optional[str] = None):
        self._map: Dict[str, Optional[str]] = dict(mapping or {})
        self._default = default

    def node_of(self, group: str) -> Optional[str]:
        return self._map.get(group, self._default)

    def assign(self, group: str, node: Optional[str]) -> None:
        self._map[group] = node

    def nodes(self):
        return sorted({n for n in list(self._map.values()) + [self._default]
                       if n is not None})


class WorkerTransport(abc.ABC):
    """Worker-process half of a process-mode transport.

    Built once per worker incarnation (in the worker process, from its
    :class:`WorkerBootstrap`); owns the worker's channel endpoints and
    whatever control plumbing the implementation needs (the routed pipe
    pump, the socket listener/reader threads).
    """

    #: channel name -> endpoint for every channel touching this group
    channels: Dict[str, ChannelEndpoint]
    #: set once the supervisor asked this worker to stop
    stopped: bool

    @abc.abstractmethod
    def pump(self, timeout: float) -> None:
        """Drain pending control/delivery messages (main-loop tick)."""

    def begin_step(self) -> None:
        """Main-loop iteration starts: effects of consumption verbs may be
        pending in-step and invisible to any buffer until ``boundary``
        publishes again (socket-mode termination needs the flag)."""

    @abc.abstractmethod
    def take_force(self) -> bool:
        """True once per supervisor force-drain request (end of stream)."""

    @abc.abstractmethod
    def boundary(self, state: dict) -> None:
        """Main-loop iteration boundary: publish a consistent snapshot of
        ``state`` (termination detection must only ever observe states
        taken between protocol steps, never mid-transaction)."""

    @abc.abstractmethod
    def report_idle(self, state: dict) -> None:
        """The loop made no progress; tell the supervisor (deduplicated)."""

    @abc.abstractmethod
    def send_stats(self, stats: dict) -> None:
        """Forward cumulative per-operator counters to the supervisor."""


class SupervisorTransport(abc.ABC):
    """Supervisor-process half of a process-mode transport.

    The :class:`~repro.core.procmode.ProcessEngineDriver` owns worker
    lifecycle (fork, death detection, restart policy) and delegates every
    transport concern here.
    """

    name: str

    def __init__(self, driver):
        self.driver = driver

    @abc.abstractmethod
    def tr_loop(self, handle) -> None:
        """Thread body draining one worker's transport pipe."""

    def on_spawn_locked(self, handle) -> list:
        """Called by the driver inside the spawn critical section (driver
        lock held, incarnation just bumped).  Return the messages that
        establish the fresh incarnation's view — e.g. its initial credit
        windows, which must be computed atomically with the incarnation
        bump so no concurrent per-event grant double-counts a buffer pop.
        The driver sends them (incarnation-pinned) after releasing the
        lock."""
        return []

    @abc.abstractmethod
    def on_spawned(self, handle) -> None:
        """A worker (re)spawned (spawn critical section released): start
        delivery — pump the undelivered suffix / broker addresses."""

    @abc.abstractmethod
    def before_respawn(self, handle) -> None:
        """A worker died: rewind delivery cursors / drop stale peer state
        so the fresh incarnation re-derives its view (called before the
        new fork, with the driver's restart locks held)."""

    @abc.abstractmethod
    def check_done(self) -> bool:
        """Sound termination detection across all workers + buffers."""

    @abc.abstractmethod
    def wait_group_drained(self, group: str, timeout: float) -> bool:
        """Block until no event involving ``group`` is buffered/in flight
        (dynamic scaling must not delete a channel that still carries a
        logged-and-sent event)."""

    @abc.abstractmethod
    def after_rewire(self) -> None:
        """Topology changed (Algs 12-13): refresh routing, re-deliver."""

    @abc.abstractmethod
    def reinject(self, ev) -> None:
        """Supervisor-side re-send of a reassigned event (Alg 13 step
        1.d).  Routed appends to the authoritative buffer; socket is a
        no-op — the restarted dispatcher's recovery resends from the log."""

    def sync_channels(self) -> None:
        """The driver re-indexed the engine's channels (start / scaling);
        refresh any per-channel transport state."""

    def request_stop(self) -> None:
        """Engine stop: release any transport-held resources."""


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

#: transport name -> (supervisor factory, worker factory); ``local`` has no
#: process halves — thread/step mode use :class:`Channel` directly.
_REGISTRY: Dict[str, Any] = {}


def register_transport(name: str, supervisor_factory, worker_factory):
    _REGISTRY[name] = (supervisor_factory, worker_factory)


def transport_names():
    _load()
    return sorted(_REGISTRY) + ["local"]


def process_transport_names():
    """Names valid for ``Engine(mode="process", transport=...)`` — every
    registered process transport (``local`` has no process halves)."""
    _load()
    return sorted(_REGISTRY)


def _load():
    # import side-effect registration; lazy so local-only users never pay
    # (socketmode registers both "socket" and "tcp" — the AF_INET family;
    # shmring registers "shm" — rings for co-located pairs, socket across)
    if "routed" not in _REGISTRY:
        from repro.core.transport import (routed, shmring,  # noqa: F401
                                          socketmode)


def make_supervisor_transport(name: str, driver) -> SupervisorTransport:
    _load()
    if name not in _REGISTRY:
        raise ValueError(f"unknown process transport {name!r} "
                         f"(have {transport_names()})")
    return _REGISTRY[name][0](driver)


def make_worker_transport(name: str, bootstrap: "WorkerBootstrap",
                          group: str, tr_conn) -> WorkerTransport:
    _load()
    if name not in _REGISTRY:
        raise ValueError(f"unknown process transport {name!r} "
                         f"(have {transport_names()})")
    return _REGISTRY[name][1](bootstrap, group, tr_conn)
