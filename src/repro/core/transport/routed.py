"""The ``routed`` transport: supervisor-pumped pipe channels (process mode).

Every channel's authoritative buffer lives in the supervisor (the reliable
piece — it survives any worker death); the supervisor streams each
channel's unprocessed suffix to the receiving worker's replica and the
replica forwards ``ack``/``defer_ack``/``release_ack`` back.  Kept next to
the newer ``socket`` transport for debuggability: every event crosses the
supervisor, so one process sees all traffic.

Credit-based back-pressure (replaces the old unbounded ``force_put``
absorption): the supervisor grants each *sender* worker a per-channel
credit window ``W = capacity - len(buffer)`` at spawn; a worker spends one
credit per put and blocks (FIFO, abortable on stop) at zero; the
supervisor returns one credit whenever an event leaves the authoritative
buffer — at ``ack`` and at ``release_ack`` (durability-watermark release),
*not* at ``defer_ack`` (deferred events still occupy capacity).  The
supervisor's buffer therefore never exceeds ``W``, and a slow consumer
back-pressures its senders instead of growing supervisor memory.  On a
sender restart the window is recomputed from the surviving buffer; on a
receiver restart occupancy is unchanged, so sender credits stay valid and
flow resumes as the fresh receiver acks (no stranded senders).

Intra-group edges (both operators in one worker) use a plain local
:class:`Channel` inside the worker: routing them through the supervisor
would deadlock a single-threaded worker blocked on its own consumer, and
the group loop drains them every iteration anyway.  Their reliability
story is the log: a group death loses both endpoints and the sender's
recovery resends the undone suffix (Alg 6/7).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.transport.base import (SupervisorTransport, WorkerTransport,
                                       register_transport)
from repro.core.transport.local import Channel, ChannelClosed


# ---------------------------------------------------------------------------
# worker side
# ---------------------------------------------------------------------------

class RoutedWorkerChannel(Channel):
    """Worker-local replica of one authoritative supervisor channel. The
    supervisor streams deliveries into ``deliver``; consumption verbs
    forward so the authoritative buffer (which survives this process)
    tracks the replica exactly; ``put`` spends supervisor-granted credits."""

    def __init__(self, wt: "RoutedWorker", send_op, send_port, rec_op,
                 rec_port):
        # replica capacity is nominal: deliveries are bounded by the
        # authoritative buffer, itself bounded by the credit window
        super().__init__(send_op, send_port, rec_op, rec_port,
                         capacity=1_000_000)
        self._wt = wt

    def deliver(self, ev):
        with self._cv:
            self._buf.append(ev)

    def put(self, ev, stop_flag=None, timeout: float = 0.05) -> bool:
        return self._wt.credit_put(self.name, ev, stop_flag)

    def ack(self):
        ev = super().ack()
        if ev is not None:
            self._wt.conn.send(("ack", self.name))
        return ev

    def defer_ack(self):
        with self._cv:
            if len(self._buf) > self._pending:
                self._pending += 1
                self._wt.conn.send(("defer", self.name))

    def release_ack(self):
        ev = super().release_ack()
        if ev is not None:
            self._wt.conn.send(("release", self.name))
        return ev

    # vectored verbs: one control message per run instead of one per event
    def ack_run(self, n: int) -> int:
        k = Channel.ack_run(self, n)
        if k:
            self._wt.conn.send(("ackn", self.name, k))
        return k

    def defer_run(self, n: int) -> int:
        k = Channel.defer_run(self, n)
        if k:
            self._wt.conn.send(("defern", self.name, k))
        return k


class RoutedWorker(WorkerTransport):
    """Worker half: replica channels + the credit ledger + the pipe pump.
    The worker is single-threaded, so the pump doubles as the wait loop of
    a credit-blocked put (deliveries and credit grants keep flowing while
    the sender waits — no self-deadlock)."""

    def __init__(self, bootstrap, group: str, tr_conn):
        self.group = group
        self.conn = tr_conn
        self.stopped = False
        self._force = False
        self.n_received = 0
        self.credits: Dict[str, int] = {}
        self._last_idle: Optional[dict] = None
        self.channels: Dict[str, Channel] = {}
        groups = bootstrap.groups
        for ch in bootstrap.channels:
            send_in = groups.get(ch.send_op) == group
            rec_in = groups.get(ch.rec_op) == group
            if send_in and rec_in:
                # intra-group: pure local channel (see module docstring)
                self.channels[ch.name] = Channel(
                    ch.send_op, ch.send_port, ch.rec_op, ch.rec_port,
                    capacity=1_000_000)
            elif send_in or rec_in:
                self.channels[ch.name] = RoutedWorkerChannel(
                    self, ch.send_op, ch.send_port, ch.rec_op, ch.rec_port)

    # -- pump --------------------------------------------------------------
    def pump(self, timeout: float) -> None:
        conn = self.conn
        if not conn.poll(timeout):
            return
        while True:
            msg = conn.recv()
            kind = msg[0]
            if kind == "ev":
                ch = self.channels.get(msg[1])
                if isinstance(ch, RoutedWorkerChannel):
                    ch.deliver(msg[2])
                self.n_received += 1
            elif kind == "credit":
                self.credits[msg[1]] = self.credits.get(msg[1], 0) + msg[2]
            elif kind == "force":
                self._force = True
            elif kind == "stop":
                self.stopped = True
            if not conn.poll(0):
                return

    def credit_put(self, name: str, ev, stop_flag) -> bool:
        """Spend one credit and forward the event; block while the window
        is exhausted (the supervisor returns credits at ack/release)."""
        while self.credits.get(name, 0) <= 0:
            if self.stopped or (stop_flag is not None and stop_flag()):
                return False
            self.pump(0.02)
        self.credits[name] -= 1
        self.conn.send(("put", name, ev))
        return True

    # -- reporting ---------------------------------------------------------
    def take_force(self) -> bool:
        f, self._force = self._force, False
        return f

    def boundary(self, state: dict) -> None:
        pass            # the supervisor's own delivery counters are the
        # consistent view in routed mode (pipe FIFO makes put-before-idle
        # ordering visible to the router)

    def report_idle(self, state: dict) -> None:
        state = dict(state, n_received=self.n_received)
        if state != self._last_idle:
            self.conn.send(("idle", state))
            self._last_idle = state

    def send_stats(self, stats: dict) -> None:
        self.conn.send(("stats", stats))


# ---------------------------------------------------------------------------
# supervisor side
# ---------------------------------------------------------------------------

class RoutedSupervisor(SupervisorTransport):
    name = "routed"

    def __init__(self, driver):
        super().__init__(driver)
        # channel -> events delivered to the receiver, not yet consumed
        self.inflight: Dict[str, int] = {}
        self.sync_channels()

    # -- channel registry --------------------------------------------------
    def sync_channels(self):
        d = self.driver
        with d.lock:
            for name in d.ch_by_name:
                self.inflight.setdefault(name, 0)
            for name in list(self.inflight):
                if name not in d.ch_by_name:
                    del self.inflight[name]

    def _intra(self, ch) -> bool:
        g = self.driver.e.pipeline.groups
        return g.get(ch.send_op) == g.get(ch.rec_op)

    # -- delivery pump -----------------------------------------------------
    def _pump(self, name: str):
        """Stream the channel's undelivered suffix to its receiving
        worker. Cursor reads/updates happen under ``driver.lock``; the
        (possibly blocking) pipe send happens OUTSIDE it, under the
        worker's ``pump_lock``, so one slow worker's full pipe never
        stalls routing for the other workers or the supervisor."""
        d = self.driver
        with d.lock:
            ch = d.ch_by_name.get(name)
            if ch is None or self._intra(ch):
                return
            h = d.workers.get(d.e.pipeline.groups.get(ch.rec_op))
        if h is None:
            return
        with h.pump_lock:
            while True:
                with d.lock:
                    if d.ch_by_name.get(name) is not ch or not h.alive:
                        return
                    ev = ch.peek_index(self.inflight.get(name, 0))
                if ev is None:
                    return
                if not h.send(("ev", name, ev)):
                    return
                with d.lock:
                    self.inflight[name] += 1
                    h.sent += 1

    def _pump_group(self, group: str):
        d = self.driver
        with d.lock:
            names = [name for name, ch in d.ch_by_name.items()
                     if d.e.pipeline.groups.get(ch.rec_op) == group]
        for name in names:
            self._pump(name)

    def after_rewire(self):
        """Deliver any undelivered suffix on every channel (used after
        dynamic-scaling rewires put events in from the parent side)."""
        self.sync_channels()
        d = self.driver
        with d.lock:
            names = list(d.ch_by_name)
        for name in names:
            self._pump(name)

    def reinject(self, ev):
        """Alg 13 step 1.d re-send into the authoritative buffer. The
        event is already logged as sent, so the buffer must absorb it
        (the set is bounded by the reassignment, not by the stream)."""
        d = self.driver
        with d.lock:
            chans = list(d.ch_by_name.values())
        for ch in chans:
            if ch.send_op == ev.send_op and ch.send_port == ev.send_port \
                    and ch.rec_op == ev.rec_op and ch.rec_port == ev.rec_port:
                ch.force_put(ev)

    # -- credit ledger -----------------------------------------------------
    def _sender_of_locked(self, ch):
        """(handle, incarnation) of the channel's sender worker — captured
        under the driver lock at buffer-pop time, so the grant can be
        pinned to the incarnation whose window the pop belongs to."""
        h = self.driver.workers.get(
            self.driver.e.pipeline.groups.get(ch.send_op))
        return (h, h.incarnation if h is not None else 0)

    def on_spawn_locked(self, h) -> List:
        """Fresh incarnation: (re)compute its send windows from surviving
        buffer occupancy — a restart never strands a sender, and because
        this runs in the spawn critical section (same lock hold as the
        incarnation bump) no concurrent ack-grant can double-count a pop
        this window already reflects."""
        d = self.driver
        msgs: List = []
        for name, ch in d.ch_by_name.items():
            if self._intra(ch):
                continue
            if d.e.pipeline.groups.get(ch.send_op) == h.group:
                n = max(0, ch.capacity - len(ch))
                if n:
                    msgs.append(("credit", name, n))
        return msgs

    def on_spawned(self, h):
        self._pump_group(h.group)

    def before_respawn(self, h):
        """Receiver-side rewind: unreleased deliveries become deliverable
        again; the restarted group's obsolete filters drop what recovery
        already covered. Holds the pump lock so a stale pump of the dead
        incarnation finishes or fails before the cursors move."""
        d = self.driver
        with h.pump_lock:
            with d.lock:
                for name, ch in d.ch_by_name.items():
                    if d.e.pipeline.groups.get(ch.rec_op) == h.group \
                            and not self._intra(ch):
                        ch.reset_pending()
                        self.inflight[name] = 0

    # -- router thread -----------------------------------------------------
    def tr_loop(self, h):
        d = self.driver
        conn = h.tr_conn
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                return
            kind = msg[0]
            pump = grant = None
            with d.lock:
                if kind == "put":
                    _, name, ev = msg
                    ch = d.ch_by_name.get(name)
                    if ch is not None:
                        # the sender spent a credit, so occupancy stays
                        # within the window; absorb (the event is logged
                        # as sent — dropping it would strand UNDONE rows)
                        try:
                            ch.force_put(ev)
                        except ChannelClosed:
                            pass           # engine stopping
                        pump = name
                elif kind == "ack":
                    ch = d.ch_by_name.get(msg[1])
                    if ch is not None and ch.ack() is not None:
                        self.inflight[msg[1]] -= 1
                        grant = (msg[1],) + self._sender_of_locked(ch) + (1,)
                elif kind == "ackn":
                    # vectored ack: k events leave the authoritative buffer
                    # under one lock hold, one credit grant of k returns
                    ch = d.ch_by_name.get(msg[1])
                    if ch is not None:
                        k = ch.ack_run(msg[2])
                        if k:
                            self.inflight[msg[1]] -= k
                            grant = (msg[1],) + self._sender_of_locked(ch) \
                                + (k,)
                elif kind == "defer":
                    ch = d.ch_by_name.get(msg[1])
                    if ch is not None:
                        ch.defer_ack()
                        self.inflight[msg[1]] -= 1
                        # no grant: deferred events still hold their credit
                elif kind == "defern":
                    ch = d.ch_by_name.get(msg[1])
                    if ch is not None:
                        k = ch.defer_run(msg[2])
                        self.inflight[msg[1]] -= k
                elif kind == "release":
                    ch = d.ch_by_name.get(msg[1])
                    if ch is not None and ch.release_ack() is not None:
                        grant = (msg[1],) + self._sender_of_locked(ch) + (1,)
                elif kind == "idle":
                    h.last_idle = msg[1]
                elif kind == "stats":
                    d.record_stats(h.group, msg[1])
            # pipe sends outside driver.lock: a full pipe toward a slow
            # worker must not stall this router thread's peers. The grant
            # is pinned to the sender incarnation captured at pop time —
            # a fresh incarnation's initial window already reflects the
            # pop, so landing it there would double-grant.
            if grant is not None:
                name, gh, inc, k = grant
                if gh is not None:
                    gh.send(("credit", name, k), incarnation=inc)
            if pump is not None:
                self._pump(pump)

    # -- termination / drain ----------------------------------------------
    def check_done(self) -> bool:
        d = self.driver
        to_force: List = []
        with d.lock:
            deferred = 0
            for h in d.workers.values():
                if d.e.group_state.get(h.group) == "removed":
                    continue
                st = h.last_idle
                if not h.alive or st is None \
                        or st["n_received"] != h.sent \
                        or not st["exhausted"] or st["pending"]:
                    return False
                deferred += st["deferred"]
            if any(self.inflight.get(n, 0) for n in d.ch_by_name):
                return False
            if deferred == 0 and \
                    all(len(ch) == 0 for ch in d.ch_by_name.values()):
                return True
            # quiescent but effects still gated on the durability
            # watermark: force-drain (end of stream — batches cannot grow)
            for h in d.workers.values():
                if h.alive and (h.last_idle or {}).get("deferred"):
                    h.last_idle = None
                    to_force.append(h)
        for h in to_force:       # pipe sends outside the driver lock
            h.send(("force",))
        return False

    def wait_group_drained(self, group: str, timeout: float = 5.0) -> bool:
        import time
        d = self.driver
        group_ops = set(d.e.group_ops(group))
        deadline = time.time() + timeout
        while time.time() < deadline:
            with d.lock:
                h = d.workers.get(group)
                chans = [ch for ch in d.ch_by_name.values()
                         if ch.rec_op in group_ops or ch.send_op in group_ops]
                st = h.last_idle if h is not None else None
                if h is not None and h.alive and st is not None \
                        and st["n_received"] == h.sent \
                        and st["deferred"] == 0 \
                        and all(len(c) == 0 for c in chans):
                    return True
            time.sleep(0.005)
        return False


register_transport("routed", RoutedSupervisor,
                   lambda bootstrap, group, conn: RoutedWorker(
                       bootstrap, group, conn))
