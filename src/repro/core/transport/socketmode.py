"""The ``socket`` / ``tcp`` transports: direct worker-to-worker channels.

Event payloads travel on point-to-point sockets between worker processes
(`multiprocessing.connection`, one duplex connection per sender-group ->
receiver-group pair, channels multiplexed by name); the supervisor never
touches an event.  The listener **family is per-engine configuration**
(``transport_options={"family": "unix" | "inet"}``), not an import-time
constant: ``socket`` defaults to ``AF_UNIX`` where available, and the
registered ``tcp`` transport is the same implementation pinned to
``AF_INET`` — ``(host, port)`` listener addresses brokered through the
supervisor, so workers need not share a filesystem (the multi-host
prerequisite).  Every connection — worker listener accept and peer dial —
is authenticated with the engine's per-run ``authkey`` (the
``multiprocessing.connection`` HMAC challenge), because a TCP listener is
reachable by anything on the network, unlike a mode-0600 unix socket.

The supervisor retains only the authoritative *recovery* view: the log.
The **sender-side worker holds the reliable
buffer** for each of its channels, bounded at the credit window (= the
channel capacity): ``put`` appends + transmits and blocks while the buffer
is full; the receiver's ``ack``/``release`` frames returning over the
socket are the credit grants that free a slot.  Deferred acks advance a
pending cursor on the sender's buffer and keep holding their credit until
``release`` (the durability-watermark rule), exactly like the local
transport.

Ack frames carry the event id and the sender matches them against its
FIFO head, so a stale ack (a duplicate the receiver obsolete-filtered
after a reconnect) can never pop the wrong event.

Crash anatomy (why a lost buffer is safe):

* **receiver dies** — the sender's buffer still holds every unreleased
  event.  The supervisor respawns the receiver, which reports a fresh
  listener address; the supervisor brokers it to the senders, which
  reconnect, ``reset_pending`` and re-transmit the whole buffer suffix.
  The receiver's obsolete filter (rebuilt from the log by Alg 9) drops
  the already-recovered prefix.  Blocked puts wake as the fresh receiver
  acks — a SIGKILL'd receiver never strands a sender.
* **sender dies** — its buffer is gone, but every buffered event was
  logged before send (Alg 3 step 4 precedes step 5), so the respawned
  worker's recovery resends the undone + unacknowledged suffix from the
  log (Alg 6/7) into a fresh buffer; receivers drop duplicates.  Events
  the receiver had already processed are acknowledged *in the log*
  (their InSet assignment) and are not resent.
* **whole tree dies** — both cases at once, per group, on restart.

Termination detection: with no central router the supervisor cannot count
deliveries, so it runs a two-wave probe (Mattern-style).  Workers publish
a snapshot only at main-loop iteration boundaries (never mid-transaction):
monotonic activity counter, send-buffer occupancy, unprocessed receive
backlog, deferred effects, exhaustion.  The run is complete when two
consecutive probe waves return all-empty snapshots with unchanged
activity counters from unchanged incarnations.  An event in flight always
occupies its sender's buffer (it leaves only on an ack), so "all send
buffers empty" covers the wire.
"""
from __future__ import annotations

import os
import socket as _socket
import threading
import time
from multiprocessing import AuthenticationError
from multiprocessing import connection as mpc
from typing import Dict, List, Optional, Tuple

from repro.core.transport.base import (SupervisorTransport, WorkerBootstrap,
                                       WorkerTransport, register_transport)
from repro.core.transport.local import Channel


def default_family() -> str:
    """Platform default for the ``socket`` transport (``tcp`` always
    resolves to ``inet``)."""
    return "unix" if hasattr(_socket, "AF_UNIX") else "inet"


def _listener_for(options: Dict) -> mpc.Listener:
    """A fresh worker listener per the engine's transport options —
    family is per-engine config (testable AF_INET on hosts that also have
    AF_UNIX), never an import-time constant."""
    family = options.get("family") or default_family()
    authkey = options.get("authkey")
    if family == "inet":
        host = options.get("host", "127.0.0.1")
        return mpc.Listener((host, 0), family="AF_INET", authkey=authkey)
    if family == "unix":
        return mpc.Listener(family="AF_UNIX", authkey=authkey)
    raise ValueError(f"unknown socket family {family!r} "
                     "(expected 'unix' or 'inet')")


class _Conn:
    """A peer connection + send lock + liveness flag. Frames are sent
    best-effort: a dead peer's frames are dropped (the log, not the wire,
    is the recovery authority)."""

    def __init__(self, conn):
        self.conn = conn
        self.lock = threading.Lock()
        self.alive = True

    def send(self, frame) -> bool:
        with self.lock:
            if not self.alive:
                return False
            try:
                self.conn.send(frame)
                return True
            except (OSError, ValueError):
                self.alive = False
                return False

    def close(self):
        self.alive = False
        try:
            self.conn.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# worker-side channels
# ---------------------------------------------------------------------------

class SocketSendChannel(Channel):
    """Sender-held reliable buffer, bounded at the credit window.  Only the
    worker's main thread puts; reader threads apply remote acks.

    FIFO discipline on reconnect: every frame for this channel is sent
    under the buffer lock, and ``_entry`` (the live connection) becomes
    visible only once ``resend_all`` has replayed the buffer on it.  A
    put racing a reconnect therefore either lands before the replay
    (covered by it, in order) or transmits after it — a fresh frame can
    never overtake the re-transmission of older buffered events, which
    would ratchet the receiver's obsolete filter past unprocessed ids
    and silently drop them."""

    def __init__(self, wt: "SocketWorker", send_op, send_port, rec_op,
                 rec_port, capacity: int):
        super().__init__(send_op, send_port, rec_op, rec_port,
                         capacity=capacity)
        self._wt = wt
        self._entry: Optional[_Conn] = None

    def put(self, ev, stop_flag=None, timeout: float = 0.05) -> bool:
        wt = self._wt
        with self._cv:
            while len(self._buf) >= self.capacity:
                if wt.stopped or (stop_flag is not None and stop_flag()):
                    return False
                self._cv.wait(timeout)
            if wt.stopped:
                return False
            self._buf.append(ev)
            self.total_put += 1
            entry = self._entry
            if entry is not None and entry.alive:
                entry.send(("ev", self.name, ev))
        wt.bump()
        return True

    def resend_all(self, entry: _Conn):
        """Fresh connection to a (possibly restarted) receiver: rewind the
        deferred cursor, re-transmit the full buffer suffix in order, and
        only then adopt the connection for subsequent puts."""
        with self._cv:
            self._pending = 0
            for ev in self._buf:
                entry.send(("ev", self.name, ev))
            self._entry = entry

    # -- remote consumption verbs (applied by reader threads) --------------
    def remote_ack(self, event_id) -> None:
        with self._cv:
            if len(self._buf) > self._pending \
                    and self._buf[self._pending].event_id == event_id:
                self._buf.pop(self._pending)
                self._cv.notify_all()
        self._wt.bump()

    def remote_defer(self, event_id) -> None:
        with self._cv:
            if len(self._buf) > self._pending \
                    and self._buf[self._pending].event_id == event_id:
                self._pending += 1
        self._wt.bump()

    def remote_release(self, event_id) -> None:
        with self._cv:
            if self._pending > 0 and self._buf \
                    and self._buf[0].event_id == event_id:
                self._pending -= 1
                self._buf.pop(0)
                self._cv.notify_all()
        self._wt.bump()


class SocketRecvChannel(Channel):
    """Receiver-side replica: reader threads deliver, the main loop
    consumes, and each consumption verb returns a credit to the sender as
    an id-matched ack frame."""

    def __init__(self, wt: "SocketWorker", send_op, send_port, rec_op,
                 rec_port):
        super().__init__(send_op, send_port, rec_op, rec_port,
                         capacity=1_000_000)
        self._wt = wt

    def deliver(self, ev):
        with self._cv:
            self._buf.append(ev)
        self._wt.bump()

    def put(self, ev, stop_flag=None, timeout: float = 0.05) -> bool:
        raise RuntimeError(f"{self.name}: put on the receiving endpoint")

    def _frame(self, kind: str, ev):
        entry = self._wt.conn_in_for(self.name)
        if entry is not None:
            entry.send((kind, self.name, ev.event_id))

    def ack(self):
        ev = super().ack()
        if ev is not None:
            self._frame("ack", ev)
            self._wt.bump()
        return ev

    def defer_ack(self):
        with self._cv:
            if len(self._buf) > self._pending:
                ev = self._buf[self._pending]
                self._pending += 1
            else:
                ev = None
        if ev is not None:
            self._frame("defer", ev)
            self._wt.bump()

    def release_ack(self):
        ev = super().release_ack()
        if ev is not None:
            self._frame("release", ev)
            self._wt.bump()
        return ev


# ---------------------------------------------------------------------------
# worker transport
# ---------------------------------------------------------------------------

class SocketWorker(WorkerTransport):
    def __init__(self, bootstrap: WorkerBootstrap, group: str, tr_conn):
        self.group = group
        self.conn = tr_conn
        self.options = dict(bootstrap.transport_options)
        self.authkey = self.options.get("authkey")
        self.stopped = False
        self._force = False
        self._reg = threading.Lock()       # conn registries + peer addrs
        self._tr_send_lock = threading.Lock()
        self._act_lock = threading.Lock()
        self.activity = 0
        self._snap_lock = threading.Lock()
        # True while the main loop is inside an iteration (or still in
        # recovery): consumption verbs may have run with their effects
        # (generation, write actions) still pending in-step, invisible to
        # any buffer — probes must treat the worker as busy
        self._stepping = True
        # until the first boundary the worker counts as busy (recovery)
        self._snap = {"exhausted": False, "pending": True, "deferred": 0}
        self.channels: Dict[str, Channel] = {}
        self._send_chs: Dict[str, SocketSendChannel] = {}
        self._recv_chs: Dict[str, SocketRecvChannel] = {}
        self._local_chs: Dict[str, Channel] = {}
        self._peer_of: Dict[str, str] = {}         # channel -> peer group
        groups = bootstrap.groups
        for ch in bootstrap.channels:
            send_in = groups.get(ch.send_op) == group
            rec_in = groups.get(ch.rec_op) == group
            if send_in and rec_in:
                c = Channel(ch.send_op, ch.send_port, ch.rec_op, ch.rec_port,
                            capacity=1_000_000)
                self._local_chs[ch.name] = c
            elif send_in:
                c = SocketSendChannel(self, ch.send_op, ch.send_port,
                                      ch.rec_op, ch.rec_port, ch.capacity)
                self._send_chs[ch.name] = c
                self._peer_of[ch.name] = groups.get(ch.rec_op)
            elif rec_in:
                c = SocketRecvChannel(self, ch.send_op, ch.send_port,
                                      ch.rec_op, ch.rec_port)
                self._recv_chs[ch.name] = c
                self._peer_of[ch.name] = groups.get(ch.send_op)
            else:
                continue
            self.channels[ch.name] = c
        self._out: Dict[str, _Conn] = {}           # peer group -> conn
        self._in: Dict[str, _Conn] = {}
        self._peer_addr: Dict[str, Tuple] = {}     # peer -> (addr, gen)
        self.listener = _listener_for(self.options)
        threading.Thread(target=self._accept_loop, daemon=True,
                         name=f"sock-accept-{group}").start()
        threading.Thread(target=self._control_loop, daemon=True,
                         name=f"sock-ctl-{group}").start()
        self._tr_send(("addr", self.listener.address))

    # -- plumbing ----------------------------------------------------------
    def bump(self):
        with self._act_lock:
            self.activity += 1

    def _tr_send(self, msg):
        with self._tr_send_lock:
            try:
                self.conn.send(msg)
            except (OSError, ValueError):
                pass                      # supervisor gone: we exit soon

    def conn_in_for(self, ch_name: str) -> Optional[_Conn]:
        with self._reg:
            e = self._in.get(self._peer_of.get(ch_name))
        return e if e is not None and e.alive else None

    # -- threads -----------------------------------------------------------
    def _accept_loop(self):
        while not self.stopped:
            try:
                c = self.listener.accept()
                hello = c.recv()
            except AuthenticationError:
                continue                  # wrong/missing authkey: reject
            except (OSError, EOFError):
                if self.stopped:
                    return                # listener closed (stop)
                # a peer was SIGKILLed mid-handshake (the authkey
                # challenge adds blocking round-trips inside accept());
                # the listener itself is fine — a dead accept loop would
                # leave this worker unreachable and strand the next
                # connector inside its answer_challenge forever.  The
                # brief sleep bounds the spin if accept() itself fails
                # persistently (EMFILE, broken listener)
                time.sleep(0.01)
                continue
            if not (isinstance(hello, tuple) and hello[0] == "hello"):
                c.close()
                continue
            entry = _Conn(c)
            with self._reg:
                self._in[hello[1]] = entry
            threading.Thread(target=self._reader, args=(entry,),
                             daemon=True).start()

    def _reader(self, entry: _Conn):
        while True:
            try:
                frame = entry.conn.recv()
            except (EOFError, OSError):
                entry.alive = False
                return
            kind = frame[0]
            if kind == "ev":
                ch = self._recv_chs.get(frame[1])
                if ch is not None:
                    ch.deliver(frame[2])
            elif kind == "ack":
                ch = self._send_chs.get(frame[1])
                if ch is not None:
                    ch.remote_ack(frame[2])
            elif kind == "defer":
                ch = self._send_chs.get(frame[1])
                if ch is not None:
                    ch.remote_defer(frame[2])
            elif kind == "release":
                ch = self._send_chs.get(frame[1])
                if ch is not None:
                    ch.remote_release(frame[2])

    def _control_loop(self):
        while True:
            try:
                msg = self.conn.recv()
            except (EOFError, OSError):
                self.stopped = True
                return
            kind = msg[0]
            if kind == "peer":
                self._connect(msg[1], msg[2], msg[3])
            elif kind == "probe":
                self._tr_send(("snap", msg[1], self._probe_snapshot()))
            elif kind == "force":
                self._force = True
            elif kind == "stop":
                self.stopped = True
                try:
                    self.listener.close()
                except OSError:
                    pass
                return

    def _connect(self, peer: str, addr, gen: int):
        """(Re)connect to a peer's fresh listener and re-transmit the
        reliable buffers of every channel toward it."""
        with self._reg:
            cur = self._peer_addr.get(peer)
            e = self._out.get(peer)
            if cur == (addr, gen) and e is not None and e.alive:
                return                     # duplicate broadcast
            self._peer_addr[peer] = (addr, gen)
        try:
            c = mpc.Client(addr, authkey=self.authkey)
            c.send(("hello", self.group))
        except (OSError, EOFError, AuthenticationError):
            return      # peer died again; a newer broadcast will follow
        entry = _Conn(c)
        with self._reg:
            old, self._out[peer] = self._out.get(peer), entry
        if old is not None:
            old.alive = False
        threading.Thread(target=self._reader, args=(entry,),
                         daemon=True).start()
        for name, ch in self._send_chs.items():
            if self._peer_of.get(name) == peer:
                ch.resend_all(entry)

    def _probe_snapshot(self) -> dict:
        """A probe reply. Buffer occupancy and the activity counter are
        read LIVE (a cached boundary snapshot could make two probe waves
        agree while work is in flight); ``exhausted``/``pending``/
        ``deferred`` come from the last boundary — their transitions only
        happen inside a step, and a step in progress is flagged by
        ``stepping`` while a completed one bumped ``activity``."""
        with self._snap_lock:
            snap = dict(self._snap)
        snap["outbuf"] = sum(len(c) for c in self._send_chs.values())
        # deferred-ack events held in the send buffers: they keep outbuf
        # non-zero until the durability watermark releases them, so the
        # supervisor must distinguish them from genuinely in-flight work
        # (quiescent-except-deferral triggers the force-drain)
        snap["outheld"] = sum(c.held() for c in self._send_chs.values())
        snap["inbuf"] = (
            sum(c.unprocessed() for c in self._recv_chs.values())
            + sum(c.unprocessed() for c in self._local_chs.values()))
        with self._act_lock:
            snap["activity"] = self.activity
        snap["stepping"] = self._stepping
        snap["pid"] = os.getpid()
        return snap

    # -- WorkerTransport ---------------------------------------------------
    def pump(self, timeout: float) -> None:
        if self.stopped:
            return
        if timeout:
            time.sleep(timeout)        # deliveries/acks arrive on threads

    def begin_step(self) -> None:
        self._stepping = True

    def take_force(self) -> bool:
        f, self._force = self._force, False
        return f

    def boundary(self, state: dict) -> None:
        snap = {
            "exhausted": state["exhausted"],
            "pending": state["pending"],
            "deferred": state["deferred"],
        }
        with self._snap_lock:
            self._snap = snap
        self._stepping = False

    def report_idle(self, state: dict) -> None:
        self.boundary(state)

    def send_stats(self, stats: dict) -> None:
        self._tr_send(("stats", stats))


# ---------------------------------------------------------------------------
# supervisor side
# ---------------------------------------------------------------------------

class SocketSupervisor(SupervisorTransport):
    name = "socket"

    def __init__(self, driver):
        super().__init__(driver)
        self.addr: Dict[str, Tuple] = {}    # group -> (address, gen)
        self._gen = 0
        self._round = 0
        self._sig: Optional[Dict[str, Tuple[int, int]]] = None

    # -- address brokering -------------------------------------------------
    def _peer_msgs_locked(self, group: str) -> List[Tuple]:
        """(handle, msg) peer broadcasts involving ``group``'s channels:
        tell ``group`` where its receivers listen, and tell the workers
        that send into ``group`` about its (fresh) address."""
        d = self.driver
        groups = d.e.pipeline.groups
        out = {}
        for ch in d.ch_by_name.values():
            sg, rg = groups.get(ch.send_op), groups.get(ch.rec_op)
            if sg == rg:
                continue
            if sg == group and rg in self.addr:
                out[(group, rg)] = (d.workers.get(group),
                                    ("peer", rg) + self.addr[rg])
            if rg == group and group in self.addr:
                out[(sg, group)] = (d.workers.get(sg),
                                    ("peer", group) + self.addr[group])
        return [(h, m) for h, m in out.values() if h is not None]

    def tr_loop(self, h):
        d = self.driver
        conn = h.tr_conn
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                return
            kind = msg[0]
            sends: List[Tuple] = []
            with d.lock:
                if kind == "addr":
                    self._gen += 1
                    self.addr[h.group] = (msg[1], self._gen)
                    sends = self._peer_msgs_locked(h.group)
                elif kind == "snap":
                    h.probe = (msg[1], msg[2])
                elif kind == "stats":
                    d.record_stats(h.group, msg[1])
            for ph, pm in sends:           # pipe sends outside driver.lock
                ph.send(pm)

    def on_spawned(self, h):
        h.probe = None              # wait for the fresh incarnation

    def before_respawn(self, h):
        d = self.driver
        with d.lock:
            self.addr.pop(h.group, None)   # stale listener died with it
            h.probe = None
            self._sig = None

    def after_rewire(self):
        """Topology changed: re-broadcast every known address (workers
        ignore duplicates; restarted parties re-enter via the addr flow)."""
        d = self.driver
        sends: List[Tuple] = []
        with d.lock:
            for g in list(self.addr):
                sends.extend(self._peer_msgs_locked(g))
        seen = set()
        for ph, pm in sends:
            key = (id(ph), pm[1])
            if key not in seen:
                seen.add(key)
                ph.send(pm)

    def reinject(self, ev):
        """Alg 13 step 1.d: nothing to do — the dispatcher is restarted
        with ``recover=True`` right after the reassignment transaction and
        its log recovery resends every undone + unacknowledged output
        (including the reassigned ones) through its fresh buffers."""

    # -- termination (two-wave probe) --------------------------------------
    def _quiescent_sig(self, handles) -> Optional[Dict]:
        """None unless every worker's current-round snapshot is quiescent
        (at most deferral effects outstanding); else the
        {group: (pid, activity)} wave signature, or a ``__force__`` marker
        when the only outstanding work is gated on the durability
        watermark.  Deferred acks keep their events in the *sender's*
        buffer (``outheld``), so 'all send buffers empty' would deadlock
        against the end-of-stream force-drain — in-flight work is
        ``outbuf - outheld``."""
        sig = {}
        gated = False
        for h in handles:
            p = getattr(h, "probe", None)
            if p is None or p[0] != self._round:
                return None                       # wave incomplete
            s = p[1]
            if h.proc is None or s["pid"] != h.proc.pid:
                return None                       # stale incarnation
            if not s["exhausted"] or s["pending"] or s["inbuf"] \
                    or s["stepping"] or s["outbuf"] - s["outheld"]:
                return None
            if s["deferred"] or s["outheld"]:
                gated = True
            sig[h.group] = (s["pid"], s["activity"])
        if gated:
            # quiescent but effects still held by the durability
            # watermark: force-drain every worker (end of stream —
            # batches cannot grow, Alg 3 step 6 effects must release)
            return {"__force__": list(handles)}
        return sig

    def check_done(self) -> bool:
        d = self.driver
        to_force: List = []
        probes: List = []
        done = False
        with d.lock:
            handles = [h for h in d.workers.values()
                       if d.e.group_state.get(h.group) != "removed"]
            if not handles or not all(h.alive for h in handles):
                self._sig = None
            else:
                sig = self._quiescent_sig(handles)
                if isinstance(sig, dict) and "__force__" in sig:
                    to_force = sig["__force__"]
                    self._sig = None
                elif sig is not None:
                    if self._sig == sig:
                        done = True
                    self._sig = sig
                elif all(getattr(h, "probe", None) is not None
                         and h.probe[0] == self._round for h in handles):
                    self._sig = None              # wave complete, busy
                if not done:
                    # open (or repeat) a wave; repeats re-probe laggards
                    incomplete = [h for h in handles
                                  if getattr(h, "probe", None) is None
                                  or h.probe[0] != self._round]
                    if not incomplete:
                        self._round += 1
                        probes = list(handles)
                    else:
                        probes = incomplete
        for h in to_force:
            h.send(("force",))
        r = self._round
        for h in probes:
            h.send(("probe", r))
        return done

    def wait_group_drained(self, group: str, timeout: float = 5.0) -> bool:
        """Two stable all-empty snapshots from the group's worker: its
        send buffers acked empty (outputs reached their receivers' logs),
        no unprocessed backlog, no deferred effects."""
        d = self.driver
        deadline = time.time() + timeout
        prev = None
        while time.time() < deadline:
            with d.lock:
                h = d.workers.get(group)
                if h is None or not h.alive:
                    return False
                self._round += 1
                r = self._round
            h.send(("probe", r))
            t0 = time.time()
            snap = None
            while time.time() - t0 < 0.5:
                with d.lock:
                    p = getattr(h, "probe", None)
                    if p is not None and p[0] == r:
                        snap = p[1]
                        break
                time.sleep(0.002)
            if snap is not None and not snap["outbuf"] and not snap["inbuf"] \
                    and not snap["deferred"] and not snap["pending"] \
                    and not snap["stepping"]:
                if prev is not None and prev == snap["activity"]:
                    return True
                prev = snap["activity"]
            else:
                prev = None
            time.sleep(0.005)
        return False


class TcpSupervisor(SocketSupervisor):
    """``transport="tcp"``: the socket transport pinned to the ``AF_INET``
    listener family — ``(host, port)`` addresses brokered between workers
    that need not share a filesystem or a parent process.  The supervisor
    half is address-family-agnostic (addresses are opaque to the broker);
    only the name differs so CI matrices and engine config can select the
    family explicitly."""

    name = "tcp"


register_transport("socket", SocketSupervisor,
                   lambda bootstrap, group, conn: SocketWorker(
                       bootstrap, group, conn))
register_transport("tcp", TcpSupervisor,
                   lambda bootstrap, group, conn: SocketWorker(
                       bootstrap, group, conn))
