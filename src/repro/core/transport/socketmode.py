"""The ``socket`` / ``tcp`` transports: direct worker-to-worker channels.

Event payloads travel on point-to-point sockets between worker processes
(one duplex connection per sender-group -> receiver-group pair, channels
multiplexed by name); the supervisor never touches an event.  The
connection handshake still speaks `multiprocessing.connection` (the
per-run ``authkey`` HMAC challenge + a ``hello`` frame), but once a pair
is introduced both sides drop to a **batched binary wire protocol**
(:mod:`repro.core.transport.wire`): every event, ack, defer and release
queued for a peer since the last flusher wakeup coalesces into one
length-prefixed superframe written with a single vectored write.  Event
payloads are pickled exactly once (``Event.cache_blob`` — the same bytes
the log persists via ``put_event_blob``) and travel as buffer slices;
reconnect-replay re-transmits the cached blob without re-pickling.  Acks
are *delayed*: a flush that would carry only control entries lingers for
a small ``ack_flush`` window (default 2ms) so credit grants piggyback on
each other (and on any event heading the other way is not possible —
acks flow opposite to events — so they batch among themselves); any
queued event flushes immediately.

The listener **family is per-engine configuration**
(``transport_options={"family": "unix" | "inet"}``), not an import-time
constant: ``socket`` defaults to ``AF_UNIX`` where available, and the
registered ``tcp`` transport is the same implementation pinned to
``AF_INET`` — ``(host, port)`` listener addresses brokered through the
supervisor, so workers need not share a filesystem (the multi-host
prerequisite).

The supervisor retains only the authoritative *recovery* view: the log.
The **sender-side worker holds the reliable buffer** for each of its
channels, bounded at the credit window (= the channel capacity): ``put``
appends + enqueues for the wire and blocks while the buffer is full; the
receiver's ``ack``/``release`` entries returning over the socket are the
credit grants that free a slot.  Deferred acks advance a pending cursor
on the sender's buffer and keep holding their credit until ``release``
(the durability-watermark rule), exactly like the local transport.

Ack entries carry the event id and the sender matches them against its
FIFO head, so a stale ack (a duplicate the receiver obsolete-filtered
after a reconnect) can never pop the wrong event.

Crash anatomy (why a lost buffer is safe):

* **receiver dies** — the sender's buffer still holds every unreleased
  event.  The supervisor respawns the receiver, which reports a fresh
  listener address; the supervisor brokers it to the senders, which
  reconnect, ``reset_pending`` and re-transmit the whole buffer suffix
  (cached blobs, no re-pickle).  The receiver's obsolete filter (rebuilt
  from the log by Alg 9) drops the already-recovered prefix.  Blocked
  puts wake as the fresh receiver acks — a SIGKILL'd receiver never
  strands a sender.
* **sender dies** — its buffer is gone, but every buffered event was
  logged before send (Alg 3 step 4 precedes step 5), so the respawned
  worker's recovery resends the undone + unacknowledged suffix from the
  log (Alg 6/7) into a fresh buffer; receivers drop duplicates.  Events
  the receiver had already processed are acknowledged *in the log*
  (their InSet assignment) and are not resent.
* **whole tree dies** — both cases at once, per group, on restart.

A queued-but-unwritten entry is covered by the same invariant that
covers the wire: the event still occupies its sender channel's buffer
(it leaves only on an ack), so "all send buffers empty" subsumes the
flusher queues.  Delayed acks merely postpone quiescence by at most the
``ack_flush`` window.

Termination detection: with no central router the supervisor cannot
count deliveries, so it runs a two-wave probe (Mattern-style).  Workers
publish a snapshot only at main-loop iteration boundaries (never
mid-transaction): monotonic activity counter, send-buffer occupancy,
unprocessed receive backlog, deferred effects, exhaustion.  The run is
complete when two consecutive probe waves return all-empty snapshots
with unchanged activity counters from unchanged incarnations.  An event
in flight always occupies its sender's buffer (it leaves only on an
ack), so "all send buffers empty" covers the wire.
"""
from __future__ import annotations

import os
import socket as _socket
import threading
import time
from multiprocessing import AuthenticationError
from multiprocessing import connection as mpc
from typing import Dict, List, Optional, Tuple

from repro.core.events import Event
from repro.core.transport import wire
from repro.core.transport.base import (SupervisorTransport, WorkerBootstrap,
                                       WorkerTransport, register_transport)
from repro.core.transport.local import Channel

#: default linger before flushing an ack-only wire queue (seconds) —
#: long enough to coalesce the ack burst a processing loop emits,
#: short enough to be invisible next to the credit window
DEFAULT_ACK_FLUSH = 0.002


def default_family() -> str:
    """Platform default for the ``socket`` transport (``tcp`` always
    resolves to ``inet``)."""
    return "unix" if hasattr(_socket, "AF_UNIX") else "inet"


def _listener_for(options: Dict) -> mpc.Listener:
    """A fresh worker listener per the engine's transport options —
    family is per-engine config (testable AF_INET on hosts that also have
    AF_UNIX), never an import-time constant."""
    family = options.get("family") or default_family()
    authkey = options.get("authkey")
    if family == "inet":
        host = options.get("host", "127.0.0.1")
        return mpc.Listener((host, 0), family="AF_INET", authkey=authkey)
    if family == "unix":
        return mpc.Listener(family="AF_UNIX", authkey=authkey)
    raise ValueError(f"unknown socket family {family!r} "
                     "(expected 'unix' or 'inet')")


# ---------------------------------------------------------------------------
# batched peer connections
# ---------------------------------------------------------------------------

class BatchedConn:
    """A peer connection with a wire queue and a flusher thread.

    ``send_event``/``send_ctrl`` only append to the queue (cheap, called
    under channel locks); the flusher drains the queue into superframes.
    Entries for a dead peer are dropped best-effort — the log, not the
    wire, is the recovery authority.  Subclasses supply the byte I/O
    (socket fd here, shared-memory ring in ``shmring``).
    """

    def __init__(self, ack_flush: float = DEFAULT_ACK_FLUSH):
        self.alive = True
        self._q: List[Tuple] = []
        self._cv = threading.Condition()
        self._urgent = False        # an event entry is queued: flush now
        self._wt: Optional["SocketWorker"] = None
        self._ack_flush = ack_flush

    # -- producer side (channel locks held) --------------------------------
    def send_event(self, name: str, event_id: int, blob: bytes) -> bool:
        with self._cv:
            if not self.alive:
                return False
            self._q.append(("ev", name, event_id, blob))
            self._urgent = True
            self._cv.notify()
            return True

    def send_ctrl(self, kind: str, name: str, event_id: int) -> bool:
        with self._cv:
            if not self.alive:
                return False
            self._q.append((kind, name, event_id))
            self._cv.notify()
            return True

    def send_ctrl_many(self, kind: str, name: str, event_ids) -> bool:
        """A run of same-kind control entries under one queue lock — the
        batched consumption verbs emit one credit per event (id-matched
        FIFO on the sender), but need not pay the lock per entry."""
        with self._cv:
            if not self.alive:
                return False
            self._q.extend((kind, name, eid) for eid in event_ids)
            self._cv.notify()
            return True

    # -- threads -----------------------------------------------------------
    def start(self, wt: "SocketWorker", tag: str) -> None:
        self._wt = wt
        threading.Thread(target=self._flush_loop, daemon=True,
                         name=f"wire-flush-{tag}").start()
        threading.Thread(target=self._read_loop, daemon=True,
                         name=f"wire-read-{tag}").start()

    def _flush_loop(self):
        while True:
            with self._cv:
                while self.alive and not self._q:
                    self._cv.wait()
                if not self.alive:
                    return
                if not self._urgent and self._ack_flush > 0:
                    # ack-only queue: linger so credit grants coalesce;
                    # any event arriving during the linger flushes now
                    deadline = time.monotonic() + self._ack_flush
                    while self.alive and not self._urgent:
                        left = deadline - time.monotonic()
                        if left <= 0:
                            break
                        self._cv.wait(left)
                    if not self.alive:
                        return
                batch, self._q = self._q, []
                self._urgent = False
            try:
                self._write_batch(batch)
            except (OSError, ValueError):
                self.alive = False
                return

    # -- I/O (subclass responsibility) -------------------------------------
    def _write_batch(self, batch: List[Tuple]) -> None:
        raise NotImplementedError

    def _read_loop(self) -> None:
        raise NotImplementedError

    def close(self) -> None:
        with self._cv:
            self.alive = False
            self._cv.notify_all()


class _WireConn(BatchedConn):
    """Socket-backed peer connection.  The `multiprocessing.connection`
    object performed the authkey challenge + hello handshake and now only
    owns the fd: all subsequent traffic is raw superframes (safe to mix —
    mpc reads are unbuffered exact-length reads, so nothing of the byte
    stream is sitting in a library buffer when we take over)."""

    def __init__(self, conn, ack_flush: float = DEFAULT_ACK_FLUSH):
        super().__init__(ack_flush)
        self.conn = conn
        self.fd = conn.fileno()

    def _write_batch(self, batch):
        bufs, total, n_ev, n_ctrl = wire.encode_superframe(batch)
        wire.write_buffers(self.fd, bufs, total)
        wt = self._wt
        if wt is not None:
            wt.wire_note(total, n_ev, n_ctrl)

    def _read_loop(self):
        dec = wire.SuperframeDecoder()
        wt = self._wt
        while True:
            try:
                data = os.read(self.fd, 1 << 16)
            except (OSError, ValueError):
                self.alive = False
                return
            if not data:
                self.alive = False
                return
            entries = list(dec.feed(data))
            if entries:
                wt.dispatch_many(entries)

    def close(self):
        super().close()
        try:
            self.conn.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# worker-side channels
# ---------------------------------------------------------------------------

class SocketSendChannel(Channel):
    """Sender-held reliable buffer, bounded at the credit window.  Only the
    worker's main thread puts; reader threads apply remote acks.

    FIFO discipline on reconnect: every wire entry for this channel is
    queued under the buffer lock, and ``_entry`` (the live connection)
    becomes visible only once ``resend_all`` has replayed the buffer on
    it.  A put racing a reconnect therefore either lands before the
    replay (covered by it, in order) or queues after it — a fresh entry
    can never overtake the re-transmission of older buffered events,
    which would ratchet the receiver's obsolete filter past unprocessed
    ids and silently drop them.  Each connection's queue drains FIFO into
    its superframes, preserving the order entries were enqueued."""

    #: tells the operator hot path to pre-pickle (``Event.cache_blob``)
    #: before logging, so the log and the wire share one encode
    prefer_blob = True

    def __init__(self, wt: "SocketWorker", send_op, send_port, rec_op,
                 rec_port, capacity: int):
        super().__init__(send_op, send_port, rec_op, rec_port,
                         capacity=capacity)
        self._wt = wt
        self._entry: Optional[BatchedConn] = None

    def put(self, ev, stop_flag=None, timeout: float = 0.05) -> bool:
        wt = self._wt
        blob = ev.cache_blob()          # pickle once, outside the lock
        with self._cv:
            while len(self._buf) >= self.capacity:
                if wt.stopped or (stop_flag is not None and stop_flag()):
                    return False
                self._cv.wait(timeout)
            if wt.stopped:
                return False
            self._buf.append(ev)
            self.total_put += 1
            entry = self._entry
            if entry is not None and entry.alive:
                entry.send_event(self.name, ev.event_id, blob)
        wt.bump()
        return True

    def resend_all(self, entry: BatchedConn):
        """Fresh connection to a (possibly restarted) receiver: rewind the
        deferred cursor, re-queue the full buffer suffix in order (cached
        blobs — no re-pickle), and only then adopt the connection for
        subsequent puts."""
        with self._cv:
            self._pending = 0
            for ev in self._buf:
                entry.send_event(self.name, ev.event_id, ev.cache_blob())
            self._entry = entry

    # -- remote consumption verbs (applied by reader threads) --------------
    def remote_ack(self, event_id) -> None:
        with self._cv:
            if len(self._buf) > self._pending \
                    and self._buf[self._pending].event_id == event_id:
                self._buf.pop(self._pending)
                self._cv.notify_all()
        self._wt.bump()

    def remote_defer(self, event_id) -> None:
        with self._cv:
            if len(self._buf) > self._pending \
                    and self._buf[self._pending].event_id == event_id:
                self._pending += 1
        self._wt.bump()

    def remote_release(self, event_id) -> None:
        with self._cv:
            if self._pending > 0 and self._buf \
                    and self._buf[0].event_id == event_id:
                self._pending -= 1
                self._buf.pop(0)
                self._cv.notify_all()
        self._wt.bump()


class SocketRecvChannel(Channel):
    """Receiver-side replica: reader threads deliver, the main loop
    consumes, and each consumption verb returns a credit to the sender as
    an id-matched ack entry (coalesced into the next superframe toward
    the sender)."""

    def __init__(self, wt: "SocketWorker", send_op, send_port, rec_op,
                 rec_port):
        super().__init__(send_op, send_port, rec_op, rec_port,
                         capacity=1_000_000)
        self._wt = wt

    def deliver_wire(self, event_id: int, header: dict, body) -> None:
        """Rebuild the event from this channel's identity + the wire
        payload — routing fields never travel, only (header, body)."""
        ev = Event(event_id, self.send_op, self.send_port,
                   self.rec_op, self.rec_port, body=body, header=header)
        with self._cv:
            self._buf.append(ev)
        self._wt.bump()

    def deliver_wire_many(self, payloads) -> None:
        """A decoded run of events for this channel: rebuild outside the
        lock, append under one acquisition, bump once."""
        evs = [Event(eid, self.send_op, self.send_port,
                     self.rec_op, self.rec_port, body=body, header=header)
               for (eid, header, body) in payloads]
        with self._cv:
            self._buf.extend(evs)
        self._wt.bump()

    def put(self, ev, stop_flag=None, timeout: float = 0.05) -> bool:
        raise RuntimeError(f"{self.name}: put on the receiving endpoint")

    def _ctrl(self, kind: str, ev):
        entry = self._wt.conn_in_for(self.name)
        if entry is not None:
            entry.send_ctrl(kind, self.name, ev.event_id)

    def ack(self):
        ev = super().ack()
        if ev is not None:
            self._ctrl("ack", ev)
            self._wt.bump()
        return ev

    def defer_ack(self):
        with self._cv:
            if len(self._buf) > self._pending:
                ev = self._buf[self._pending]
                self._pending += 1
            else:
                ev = None
        if ev is not None:
            self._ctrl("defer", ev)
            self._wt.bump()

    def release_ack(self):
        ev = super().release_ack()
        if ev is not None:
            self._ctrl("release", ev)
            self._wt.bump()
        return ev

    # -- batched consumption verbs -----------------------------------------
    # The inherited Channel.ack_run/defer_run mutate only the local replica;
    # here every consumed event must also return its credit to the sender,
    # so the vectored verbs collect the run under one lock and enqueue the
    # whole credit burst with one queue acquisition.

    def ack_run(self, n: int) -> int:
        with self._cv:
            k = min(n, len(self._buf) - self._pending)
            evs = self._buf[self._pending:self._pending + k]
            if k > 0:
                del self._buf[self._pending:self._pending + k]
                self._cv.notify_all()
        if evs:
            entry = self._wt.conn_in_for(self.name)
            if entry is not None:
                entry.send_ctrl_many("ack", self.name,
                                     [ev.event_id for ev in evs])
            self._wt.bump()
        return k

    def defer_run(self, n: int) -> int:
        with self._cv:
            k = min(n, len(self._buf) - self._pending)
            evs = self._buf[self._pending:self._pending + k]
            self._pending += k
        if evs:
            entry = self._wt.conn_in_for(self.name)
            if entry is not None:
                entry.send_ctrl_many("defer", self.name,
                                     [ev.event_id for ev in evs])
            self._wt.bump()
        return k


# ---------------------------------------------------------------------------
# worker transport
# ---------------------------------------------------------------------------

class SocketWorker(WorkerTransport):
    def __init__(self, bootstrap: WorkerBootstrap, group: str, tr_conn):
        self.group = group
        self.conn = tr_conn
        self.options = dict(bootstrap.transport_options)
        self.authkey = self.options.get("authkey")
        self.ack_flush = float(self.options.get("ack_flush",
                                                DEFAULT_ACK_FLUSH))
        self.stopped = False
        self._force = False
        self._reg = threading.Lock()       # conn registries + peer addrs
        self._tr_send_lock = threading.Lock()
        self._act_lock = threading.Lock()
        self.activity = 0
        self._snap_lock = threading.Lock()
        self._wire_lock = threading.Lock()
        self._wire = {"frames": 0, "bytes": 0, "events": 0,
                      "ctrl": 0, "ctrl_frames": 0}
        # True while the main loop is inside an iteration (or still in
        # recovery): consumption verbs may have run with their effects
        # (generation, write actions) still pending in-step, invisible to
        # any buffer — probes must treat the worker as busy
        self._stepping = True
        # until the first boundary the worker counts as busy (recovery)
        self._snap = {"exhausted": False, "pending": True, "deferred": 0}
        self.channels: Dict[str, Channel] = {}
        self._send_chs: Dict[str, SocketSendChannel] = {}
        self._recv_chs: Dict[str, SocketRecvChannel] = {}
        self._local_chs: Dict[str, Channel] = {}
        self._peer_of: Dict[str, str] = {}         # channel -> peer group
        groups = bootstrap.groups
        for ch in bootstrap.channels:
            send_in = groups.get(ch.send_op) == group
            rec_in = groups.get(ch.rec_op) == group
            if send_in and rec_in:
                c = Channel(ch.send_op, ch.send_port, ch.rec_op, ch.rec_port,
                            capacity=1_000_000)
                self._local_chs[ch.name] = c
            elif send_in:
                c = SocketSendChannel(self, ch.send_op, ch.send_port,
                                      ch.rec_op, ch.rec_port, ch.capacity)
                self._send_chs[ch.name] = c
                self._peer_of[ch.name] = groups.get(ch.rec_op)
            elif rec_in:
                c = SocketRecvChannel(self, ch.send_op, ch.send_port,
                                      ch.rec_op, ch.rec_port)
                self._recv_chs[ch.name] = c
                self._peer_of[ch.name] = groups.get(ch.send_op)
            else:
                continue
            self.channels[ch.name] = c
        self._out: Dict[str, BatchedConn] = {}     # peer group -> conn
        self._in: Dict[str, BatchedConn] = {}
        self._peer_addr: Dict[str, Tuple] = {}     # peer -> (addr, gen)
        self.listener = _listener_for(self.options)
        self._setup(bootstrap)
        threading.Thread(target=self._accept_loop, daemon=True,
                         name=f"sock-accept-{group}").start()
        threading.Thread(target=self._control_loop, daemon=True,
                         name=f"sock-ctl-{group}").start()
        self._tr_send(("addr", self._addr_payload()))

    # -- subclass hooks ----------------------------------------------------
    def _setup(self, bootstrap: WorkerBootstrap) -> None:
        """Extra transport state created before the address broadcast
        (the shm transport allocates its rings here)."""

    def _addr_payload(self):
        """What the supervisor brokers to peers as this worker's address."""
        return self.listener.address

    def _dial(self, peer: str, addr) -> Optional[BatchedConn]:
        """Open a fresh outbound connection to ``peer`` at ``addr`` (not
        yet started).  None if the peer is unreachable — a newer address
        broadcast will retry."""
        try:
            c = mpc.Client(self._sock_addr(addr), authkey=self.authkey)
            c.send(("hello", self.group))
        except (OSError, EOFError, AuthenticationError):
            return None
        return _WireConn(c, self.ack_flush)

    def _sock_addr(self, addr):
        """The socket address inside a brokered address payload."""
        return addr

    def _on_stop(self) -> None:
        """Clean-stop resource teardown (shm rings unlink here)."""

    # -- plumbing ----------------------------------------------------------
    def bump(self):
        with self._act_lock:
            self.activity += 1

    def wire_note(self, nbytes: int, n_ev: int, n_ctrl: int) -> None:
        with self._wire_lock:
            w = self._wire
            w["frames"] += 1
            w["bytes"] += nbytes
            w["events"] += n_ev
            w["ctrl"] += n_ctrl
            if n_ctrl:
                w["ctrl_frames"] += 1

    def _tr_send(self, msg):
        with self._tr_send_lock:
            try:
                self.conn.send(msg)
            except (OSError, ValueError):
                pass                      # supervisor gone: we exit soon

    def conn_in_for(self, ch_name: str) -> Optional[BatchedConn]:
        with self._reg:
            e = self._in.get(self._peer_of.get(ch_name))
        return e if e is not None and e.alive else None

    def dispatch(self, entry: Tuple) -> None:
        """Apply one decoded wire entry (called from reader threads)."""
        kind = entry[0]
        if kind == "ev":
            ch = self._recv_chs.get(entry[1])
            if ch is not None:
                ch.deliver_wire(entry[2], entry[3], entry[4])
        else:
            ch = self._send_chs.get(entry[1])
            if ch is not None:
                if kind == "ack":
                    ch.remote_ack(entry[2])
                elif kind == "defer":
                    ch.remote_defer(entry[2])
                elif kind == "release":
                    ch.remote_release(entry[2])

    def dispatch_many(self, entries: List[Tuple]) -> None:
        """Apply a decoded superframe worth of entries: consecutive event
        entries for the same channel land as one ``deliver_wire_many``
        (one lock, one activity bump); control entries keep their relative
        order against the events around them."""
        i, n = 0, len(entries)
        while i < n:
            entry = entries[i]
            if entry[0] != "ev":
                self.dispatch(entry)
                i += 1
                continue
            name = entry[1]
            j = i + 1
            while j < n and entries[j][0] == "ev" and entries[j][1] == name:
                j += 1
            ch = self._recv_chs.get(name)
            if ch is not None:
                if j - i == 1:
                    ch.deliver_wire(entry[2], entry[3], entry[4])
                else:
                    ch.deliver_wire_many(
                        [(e[2], e[3], e[4]) for e in entries[i:j]])
            i = j

    # -- threads -----------------------------------------------------------
    def _accept_loop(self):
        while not self.stopped:
            try:
                c = self.listener.accept()
                hello = c.recv()
            except AuthenticationError:
                continue                  # wrong/missing authkey: reject
            except (OSError, EOFError):
                if self.stopped:
                    return                # listener closed (stop)
                # a peer was SIGKILLed mid-handshake (the authkey
                # challenge adds blocking round-trips inside accept());
                # the listener itself is fine — a dead accept loop would
                # leave this worker unreachable and strand the next
                # connector inside its answer_challenge forever.  The
                # brief sleep bounds the spin if accept() itself fails
                # persistently (EMFILE, broken listener)
                time.sleep(0.01)
                continue
            if not (isinstance(hello, tuple) and hello[0] == "hello"):
                c.close()
                continue
            entry = _WireConn(c, self.ack_flush)
            with self._reg:
                self._in[hello[1]] = entry
            entry.start(self, f"{hello[1]}->{self.group}")

    def _control_loop(self):
        while True:
            try:
                msg = self.conn.recv()
            except (EOFError, OSError):
                self.stopped = True
                return
            kind = msg[0]
            if kind == "peer":
                self._connect(msg[1], msg[2], msg[3])
            elif kind == "probe":
                self._tr_send(("snap", msg[1], self._probe_snapshot()))
            elif kind == "force":
                self._force = True
            elif kind == "stop":
                self.stopped = True
                try:
                    self.listener.close()
                except OSError:
                    pass
                self._on_stop()
                return

    def _connect(self, peer: str, addr, gen: int):
        """(Re)connect to a peer's fresh address and re-transmit the
        reliable buffers of every channel toward it."""
        with self._reg:
            cur = self._peer_addr.get(peer)
            e = self._out.get(peer)
            if cur == (addr, gen) and e is not None and e.alive:
                return                     # duplicate broadcast
            self._peer_addr[peer] = (addr, gen)
        entry = self._dial(peer, addr)
        if entry is None:
            return      # peer died again; a newer broadcast will follow
        with self._reg:
            old, self._out[peer] = self._out.get(peer), entry
        if old is not None:
            old.close()
        entry.start(self, f"{self.group}->{peer}")
        for name, ch in self._send_chs.items():
            if self._peer_of.get(name) == peer:
                ch.resend_all(entry)

    def _probe_snapshot(self) -> dict:
        """A probe reply. Buffer occupancy and the activity counter are
        read LIVE (a cached boundary snapshot could make two probe waves
        agree while work is in flight); ``exhausted``/``pending``/
        ``deferred`` come from the last boundary — their transitions only
        happen inside a step, and a step in progress is flagged by
        ``stepping`` while a completed one bumped ``activity``."""
        with self._snap_lock:
            snap = dict(self._snap)
        snap["outbuf"] = sum(len(c) for c in self._send_chs.values())
        # deferred-ack events held in the send buffers: they keep outbuf
        # non-zero until the durability watermark releases them, so the
        # supervisor must distinguish them from genuinely in-flight work
        # (quiescent-except-deferral triggers the force-drain)
        snap["outheld"] = sum(c.held() for c in self._send_chs.values())
        snap["inbuf"] = (
            sum(c.unprocessed() for c in self._recv_chs.values())
            + sum(c.unprocessed() for c in self._local_chs.values()))
        with self._act_lock:
            snap["activity"] = self.activity
        snap["stepping"] = self._stepping
        snap["pid"] = os.getpid()
        return snap

    # -- WorkerTransport ---------------------------------------------------
    def pump(self, timeout: float) -> None:
        if self.stopped:
            return
        if timeout:
            time.sleep(timeout)        # deliveries/acks arrive on threads

    def begin_step(self) -> None:
        self._stepping = True

    def take_force(self) -> bool:
        f, self._force = self._force, False
        return f

    def boundary(self, state: dict) -> None:
        snap = {
            "exhausted": state["exhausted"],
            "pending": state["pending"],
            "deferred": state["deferred"],
        }
        with self._snap_lock:
            self._snap = snap
        self._stepping = False

    def report_idle(self, state: dict) -> None:
        self.boundary(state)

    def send_stats(self, stats: dict) -> None:
        with self._wire_lock:
            wire_snap = dict(self._wire)
        stats = dict(stats)
        stats["__wire__"] = wire_snap
        self._tr_send(("stats", stats))


# ---------------------------------------------------------------------------
# supervisor side
# ---------------------------------------------------------------------------

class SocketSupervisor(SupervisorTransport):
    name = "socket"

    def __init__(self, driver):
        super().__init__(driver)
        self.addr: Dict[str, Tuple] = {}    # group -> (address, gen)
        self._gen = 0
        self._round = 0
        self._sig: Optional[Dict[str, Tuple[int, int]]] = None

    # -- address brokering -------------------------------------------------
    def _peer_msgs_locked(self, group: str) -> List[Tuple]:
        """(handle, msg) peer broadcasts involving ``group``'s channels:
        tell ``group`` where its receivers listen, and tell the workers
        that send into ``group`` about its (fresh) address."""
        d = self.driver
        groups = d.e.pipeline.groups
        out = {}
        for ch in d.ch_by_name.values():
            sg, rg = groups.get(ch.send_op), groups.get(ch.rec_op)
            if sg == rg:
                continue
            if sg == group and rg in self.addr:
                out[(group, rg)] = (d.workers.get(group),
                                    ("peer", rg) + self.addr[rg])
            if rg == group and group in self.addr:
                out[(sg, group)] = (d.workers.get(sg),
                                    ("peer", group) + self.addr[group])
        return [(h, m) for h, m in out.values() if h is not None]

    def tr_loop(self, h):
        d = self.driver
        conn = h.tr_conn
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                return
            kind = msg[0]
            sends: List[Tuple] = []
            with d.lock:
                if kind == "addr":
                    self._gen += 1
                    self.addr[h.group] = (msg[1], self._gen)
                    sends = self._peer_msgs_locked(h.group)
                elif kind == "snap":
                    h.probe = (msg[1], msg[2])
                elif kind == "stats":
                    d.record_stats(h.group, msg[1])
            for ph, pm in sends:           # pipe sends outside driver.lock
                ph.send(pm)

    def on_spawned(self, h):
        h.probe = None              # wait for the fresh incarnation

    def before_respawn(self, h):
        d = self.driver
        with d.lock:
            addr = self.addr.pop(h.group, None)  # stale listener died too
            h.probe = None
            self._sig = None
        if addr is not None:
            self._reclaim_addr(h.group, addr[0])

    def _reclaim_addr(self, group: str, addr) -> None:
        """Release any supervisor-reclaimable resources named in a dead
        group's address payload (shm rings; sockets die with the pid)."""

    def after_rewire(self):
        """Topology changed: re-broadcast every known address (workers
        ignore duplicates; restarted parties re-enter via the addr flow)."""
        d = self.driver
        sends: List[Tuple] = []
        with d.lock:
            for g in list(self.addr):
                sends.extend(self._peer_msgs_locked(g))
        seen = set()
        for ph, pm in sends:
            key = (id(ph), pm[1])
            if key not in seen:
                seen.add(key)
                ph.send(pm)

    def reinject(self, ev):
        """Alg 13 step 1.d: nothing to do — the dispatcher is restarted
        with ``recover=True`` right after the reassignment transaction and
        its log recovery resends every undone + unacknowledged output
        (including the reassigned ones) through its fresh buffers."""

    # -- termination (two-wave probe) --------------------------------------
    def _quiescent_sig(self, handles) -> Optional[Dict]:
        """None unless every worker's current-round snapshot is quiescent
        (at most deferral effects outstanding); else the
        {group: (pid, activity)} wave signature, or a ``__force__`` marker
        when the only outstanding work is gated on the durability
        watermark.  Deferred acks keep their events in the *sender's*
        buffer (``outheld``), so 'all send buffers empty' would deadlock
        against the end-of-stream force-drain — in-flight work is
        ``outbuf - outheld``."""
        sig = {}
        gated = False
        for h in handles:
            p = getattr(h, "probe", None)
            if p is None or p[0] != self._round:
                return None                       # wave incomplete
            s = p[1]
            if h.proc is None or s["pid"] != h.proc.pid:
                return None                       # stale incarnation
            if not s["exhausted"] or s["pending"] or s["inbuf"] \
                    or s["stepping"] or s["outbuf"] - s["outheld"]:
                return None
            if s["deferred"] or s["outheld"]:
                gated = True
            sig[h.group] = (s["pid"], s["activity"])
        if gated:
            # quiescent but effects still held by the durability
            # watermark: force-drain every worker (end of stream —
            # batches cannot grow, Alg 3 step 6 effects must release)
            return {"__force__": list(handles)}
        return sig

    def check_done(self) -> bool:
        d = self.driver
        to_force: List = []
        probes: List = []
        done = False
        with d.lock:
            handles = [h for h in d.workers.values()
                       if d.e.group_state.get(h.group) != "removed"]
            if not handles or not all(h.alive for h in handles):
                self._sig = None
            else:
                sig = self._quiescent_sig(handles)
                if isinstance(sig, dict) and "__force__" in sig:
                    to_force = sig["__force__"]
                    self._sig = None
                elif sig is not None:
                    if self._sig == sig:
                        done = True
                    self._sig = sig
                elif all(getattr(h, "probe", None) is not None
                         and h.probe[0] == self._round for h in handles):
                    self._sig = None              # wave complete, busy
                if not done:
                    # open (or repeat) a wave; repeats re-probe laggards
                    incomplete = [h for h in handles
                                  if getattr(h, "probe", None) is None
                                  or h.probe[0] != self._round]
                    if not incomplete:
                        self._round += 1
                        probes = list(handles)
                    else:
                        probes = incomplete
        for h in to_force:
            h.send(("force",))
        r = self._round
        for h in probes:
            h.send(("probe", r))
        return done

    def wait_group_drained(self, group: str, timeout: float = 5.0) -> bool:
        """Two stable all-empty snapshots from the group's worker: its
        send buffers acked empty (outputs reached their receivers' logs),
        no unprocessed backlog, no deferred effects."""
        d = self.driver
        deadline = time.time() + timeout
        prev = None
        while time.time() < deadline:
            with d.lock:
                h = d.workers.get(group)
                if h is None or not h.alive:
                    return False
                self._round += 1
                r = self._round
            h.send(("probe", r))
            t0 = time.time()
            snap = None
            while time.time() - t0 < 0.5:
                with d.lock:
                    p = getattr(h, "probe", None)
                    if p is not None and p[0] == r:
                        snap = p[1]
                        break
                time.sleep(0.002)
            if snap is not None and not snap["outbuf"] and not snap["inbuf"] \
                    and not snap["deferred"] and not snap["pending"] \
                    and not snap["stepping"]:
                if prev is not None and prev == snap["activity"]:
                    return True
                prev = snap["activity"]
            else:
                prev = None
            time.sleep(0.005)
        return False


class TcpSupervisor(SocketSupervisor):
    """``transport="tcp"``: the socket transport pinned to the ``AF_INET``
    listener family — ``(host, port)`` addresses brokered between workers
    that need not share a filesystem or a parent process.  The supervisor
    half is address-family-agnostic (addresses are opaque to the broker);
    only the name differs so CI matrices and engine config can select the
    family explicitly."""

    name = "tcp"


register_transport("socket", SocketSupervisor,
                   lambda bootstrap, group, conn: SocketWorker(
                       bootstrap, group, conn))
register_transport("tcp", TcpSupervisor,
                   lambda bootstrap, group, conn: SocketWorker(
                       bootstrap, group, conn))
