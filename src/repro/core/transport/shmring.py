"""The ``shm`` transport: shared-memory ring channels for co-located pairs.

Same-host worker pairs exchange superframes through a pair of fixed-size
single-producer/single-consumer byte rings over
``multiprocessing.shared_memory`` — a *data* ring (sender -> receiver)
and an *ack* ring (receiver -> sender) — instead of a socket: an event
hop is two ``memcpy``s and two cursor stores, no syscalls on the data
path.  Placement decides per pair: the engine injects its
:class:`~repro.core.transport.base.Placement` node map into the
transport options, and a sender whose peer lives on another node falls
back to the brokered socket dial unchanged (the ``shm`` transport *is*
the socket transport plus rings for co-located pairs).

Everything above the byte pipe is shared with ``socketmode``: the same
superframe format (:mod:`repro.core.transport.wire`), the same
:class:`~repro.core.transport.socketmode.BatchedConn` queue + flusher
(delayed acks included), the same sender-held reliable buffers and
credit semantics — so SIGKILL recovery and reconnect-replay hold
verbatim.  The ring is just a byte stream: partially-written superframes
are fine (the decoder is incremental), and a writer blocked on a full
ring never deadlocks because the peer's reader thread always drains.

Ring layout (64-byte header + data)::

    u64 head         # writer cursor, monotonic byte count
    u64 tail         # reader cursor, monotonic byte count
    u32 attach_gen   # bumped by the attaching (non-creator) side
    u32 sync_gen     # creator's acknowledgement of attach_gen

``head``/``tail`` never wrap (positions are ``cursor % capacity``); the
free space is ``capacity - (head - tail)``.  Cursor stores are 8-byte
aligned single stores under x86-TSO — the data ``memcpy`` is globally
visible before the cursor store that publishes it.

**Incarnation resync.**  The receiver creates both rings; the sender
attaches.  A respawned sender must not inherit the byte stream mid-frame
(the dead incarnation may have died between the chunked writes of one
superframe, or mid-read with a frame prefix swallowed into its decoder),
so each ring runs a generation dance on attach:

* data ring (attacher = writer): the fresh sender bumps ``attach_gen``
  and waits; the receiver's reader loop notices, discards unread bytes
  (``tail = head``), resets its decoder, and publishes ``sync_gen`` —
  only then does the sender write.  Discarded bytes are events the
  *dead* incarnation sent; the fresh incarnation re-sends its whole
  reliable buffer (reconnect-replay) right after the dance.
* ack ring (attacher = reader): the fresh sender bumps ``attach_gen``
  and waits; the receiver's *write path* notices before its next frame,
  discards unread acks (``tail = head``) and publishes ``sync_gen`` —
  the fresh reader then starts at a frame boundary.  Dropped acks
  belonged to the dead incarnation; the events they acknowledged are
  re-sent by recovery and the receiver's obsolete filter re-acks them.

**Lifecycle.**  This Python registers every segment with the
``resource_tracker`` on create *and* attach, which would let a dying
worker's tracker unlink rings still in use — so every handle is
unregistered immediately and unlinking is explicit: a worker unlinks its
own rings on clean stop, the supervisor unlinks a dead incarnation's
rings before respawning it (``_reclaim_addr``) and sweeps all known
rings at engine stop.  ``FileNotFoundError`` on unlink is always
tolerated (both ends may race to clean the same name).
"""
from __future__ import annotations

import os
import struct
import time
from multiprocessing import resource_tracker, shared_memory
from typing import Dict, Optional, Tuple

from repro.core.transport import wire
from repro.core.transport.base import WorkerBootstrap, register_transport
from repro.core.transport.socketmode import (BatchedConn, SocketSupervisor,
                                             SocketWorker)

#: default ring capacity (bytes) per direction; ``transport_options
#: ["ring_bytes"]`` overrides
DEFAULT_RING_BYTES = 4 * 1024 * 1024

_HDR = 64
_U64 = struct.Struct("<Q")
_U32 = struct.Struct("<I")
_OFF_HEAD = 0
_OFF_TAIL = 8
_OFF_AGEN = 16
_OFF_SGEN = 20

#: reader/writer poll interval while the ring is empty/full
_POLL = 0.0002

_name_seq = 0


def _ring_name() -> str:
    global _name_seq
    _name_seq += 1
    return f"logio-{os.getpid()}-{_name_seq}"


def _untrack(shm: shared_memory.SharedMemory) -> None:
    """This Python's ``SharedMemory`` registers with the resource tracker
    on attach as well as create; ring lifetime is managed explicitly."""
    try:
        resource_tracker.unregister(shm._name, "shared_memory")
    except Exception:
        pass


def unlink_ring(name: str) -> None:
    """Best-effort unlink of a ring segment by name (idempotent).  Goes
    straight to ``shm_unlink`` — attaching first would re-register with
    the resource tracker and the eventual double-unregister makes the
    tracker process log spurious KeyErrors."""
    try:
        _posixshmem = shared_memory._posixshmem
    except AttributeError:
        return                     # non-POSIX platform: nothing to unlink
    try:
        _posixshmem.shm_unlink("/" + name)
    except (FileNotFoundError, OSError):
        pass


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    return True


def sweep_stale_rings() -> int:
    """Unlink ring segments whose creator pid is gone — the backstop for
    a SIGKILL of the *whole* engine tree (supervisor included), after
    which no live process knows the names.  Ring names embed the creator
    pid (``logio-<pid>-<seq>``); a fresh shm supervisor sweeps on start."""
    shm_dir = "/dev/shm"
    if not os.path.isdir(shm_dir):
        return 0
    n = 0
    for fn in os.listdir(shm_dir):
        if not fn.startswith("logio-"):
            continue
        parts = fn.split("-")
        try:
            pid = int(parts[1])
        except (IndexError, ValueError):
            continue
        if _pid_alive(pid):
            continue
        unlink_ring(fn)
        n += 1
    return n


class ShmRing:
    """One SPSC byte ring. The creator zeroes the header; the attacher
    runs the generation dance before first use (see module docstring)."""

    def __init__(self, shm: shared_memory.SharedMemory, creator: bool):
        self.shm = shm
        self.creator = creator
        self.capacity = shm.size - _HDR
        self._buf = shm.buf
        self._seen_agen: Optional[int] = None   # creator-writer resync state

    # -- construction ------------------------------------------------------
    @classmethod
    def create(cls, size: int) -> "ShmRing":
        shm = shared_memory.SharedMemory(name=_ring_name(), create=True,
                                         size=_HDR + size)
        _untrack(shm)
        shm.buf[:_HDR] = bytes(_HDR)
        return cls(shm, creator=True)

    @classmethod
    def attach(cls, name: str) -> "ShmRing":
        shm = shared_memory.SharedMemory(name=name)
        _untrack(shm)
        return cls(shm, creator=False)

    @property
    def name(self) -> str:
        return self.shm.name

    # -- header accessors --------------------------------------------------
    def _u64(self, off: int) -> int:
        return _U64.unpack_from(self._buf, off)[0]

    def _set_u64(self, off: int, v: int) -> None:
        _U64.pack_into(self._buf, off, v)

    def _u32(self, off: int) -> int:
        return _U32.unpack_from(self._buf, off)[0]

    def _set_u32(self, off: int, v: int) -> None:
        _U32.pack_into(self._buf, off, v)

    # -- attach dance ------------------------------------------------------
    def attacher_handshake(self, alive) -> bool:
        """Bump ``attach_gen`` and wait for the creator's ``sync_gen`` to
        catch up.  Returns False if ``alive()`` goes false first."""
        gen = (self._u32(_OFF_AGEN) + 1) & 0xFFFFFFFF
        self._set_u32(_OFF_AGEN, gen)
        while self._u32(_OFF_SGEN) != gen:
            if not alive():
                return False
            time.sleep(_POLL)
        return True

    def reader_resync_check(self) -> bool:
        """Creator-reader duty (data ring): acknowledge a fresh attacher
        by discarding unread bytes.  True when the caller must reset its
        decoder."""
        agen = self._u32(_OFF_AGEN)
        if agen == self._u32(_OFF_SGEN):
            return False
        self._set_u64(_OFF_TAIL, self._u64(_OFF_HEAD))
        self._set_u32(_OFF_SGEN, agen)
        return True

    def _writer_resync_check(self) -> None:
        """Creator-writer duty (ack ring): acknowledge a fresh attacher
        before the next frame, discarding acks addressed to the dead
        incarnation (the stream restarts at a frame boundary)."""
        agen = self._u32(_OFF_AGEN)
        if self._seen_agen is None:
            self._seen_agen = self._u32(_OFF_SGEN)
        if agen != self._seen_agen:
            self._set_u64(_OFF_TAIL, self._u64(_OFF_HEAD))
            self._set_u32(_OFF_SGEN, agen)
            self._seen_agen = agen

    # -- byte pipe ---------------------------------------------------------
    def write_bytes(self, data, alive) -> None:
        """Blocking write of the whole buffer; raises OSError if
        ``alive()`` goes false while the ring is full."""
        mv = memoryview(data)
        if mv.format != "B":
            mv = mv.cast("B")
        n = len(mv)
        off = 0
        cap = self.capacity
        buf = self._buf
        while off < n:
            if self.creator:
                self._writer_resync_check()
            head = self._u64(_OFF_HEAD)
            space = cap - (head - self._u64(_OFF_TAIL))
            if space == 0:
                if not alive():
                    raise OSError("shm ring peer gone")
                time.sleep(_POLL)
                continue
            pos = head % cap
            k = min(space, n - off, cap - pos)
            buf[_HDR + pos:_HDR + pos + k] = mv[off:off + k]
            off += k
            self._set_u64(_OFF_HEAD, head + k)

    def read_avail(self, maxn: int = 1 << 16) -> bytes:
        """Up to ``maxn`` available bytes (empty bytes when none)."""
        tail = self._u64(_OFF_TAIL)
        avail = self._u64(_OFF_HEAD) - tail
        if avail <= 0:
            return b""
        pos = tail % self.capacity
        k = min(avail, maxn, self.capacity - pos)
        data = bytes(self._buf[_HDR + pos:_HDR + pos + k])
        self._set_u64(_OFF_TAIL, tail + k)
        return data

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        self._buf = None
        try:
            self.shm.close()
        except (OSError, BufferError):
            pass

    def unlink(self) -> None:
        # raw unlink: the handle was unregistered from the tracker at
        # construction, so SharedMemory.unlink()'s unregister would be a
        # noisy double-remove
        unlink_ring(self.shm.name)


class _ShmConn(BatchedConn):
    """A peer connection over a ring pair.  ``out_ring`` carries this
    side's superframes, ``in_ring`` the peer's.  The attacher (sender
    side) runs the generation dance on both rings before first use."""

    def __init__(self, out_ring: ShmRing, in_ring: ShmRing,
                 ack_flush: float):
        super().__init__(ack_flush)
        self.out_ring = out_ring
        self.in_ring = in_ring
        self._attached_out = False

    def _write_batch(self, batch):
        if self.out_ring.creator is False and not self._attached_out:
            # fresh attacher: resync the stream before the first frame
            if not self.out_ring.attacher_handshake(lambda: self.alive):
                raise OSError("shm ring peer gone during attach")
            self._attached_out = True
        bufs, total, n_ev, n_ctrl = wire.encode_superframe(batch)
        for b in bufs:
            self.out_ring.write_bytes(b, lambda: self.alive)
        wt = self._wt
        if wt is not None:
            wt.wire_note(total, n_ev, n_ctrl)

    def _read_loop(self):
        ring = self.in_ring
        wt = self._wt
        if not ring.creator:
            # ack-ring reader attach: wait for the peer's write path to
            # restart the stream at a frame boundary
            if not ring.attacher_handshake(lambda: self.alive):
                return
        dec = wire.SuperframeDecoder()
        idle = 0
        while self.alive:
            if ring.creator and ring.reader_resync_check():
                dec = wire.SuperframeDecoder()   # fresh sender incarnation
            data = ring.read_avail()
            if data:
                idle = 0
                entries = list(dec.feed(data))
                if entries:
                    wt.dispatch_many(entries)
            else:
                # spin briefly (a burst is usually mid-flight), then doze
                idle += 1
                if idle > 50:
                    time.sleep(_POLL)

    def close(self):
        super().close()
        self.out_ring.close()
        self.in_ring.close()


class ShmWorker(SocketWorker):
    """Socket worker + rings toward co-located peers.  Ring pairs for
    every co-located *inbound* peer are created before the address
    broadcast and travel inside the address payload; co-located senders
    attach instead of dialing.  Cross-node (or unplaced) peers use the
    brokered socket path unchanged."""

    def _setup(self, bootstrap: WorkerBootstrap) -> None:
        self.placement: Dict[str, str] = dict(
            self.options.get("placement") or {})
        self.ring_bytes = int(self.options.get("ring_bytes",
                                               DEFAULT_RING_BYTES))
        self._rings: Dict[str, Tuple[ShmRing, ShmRing]] = {}
        for name in self._recv_chs:
            peer = self._peer_of.get(name)
            if peer is None or peer in self._rings:
                continue
            if not self._colocated(peer):
                continue
            data_ring = ShmRing.create(self.ring_bytes)
            ack_ring = ShmRing.create(self.ring_bytes)
            self._rings[peer] = (data_ring, ack_ring)
            entry = _ShmConn(ack_ring, data_ring, self.ack_flush)
            with self._reg:
                self._in[peer] = entry
            entry.start(self, f"shm:{peer}->{self.group}")

    def _colocated(self, peer: str) -> bool:
        # an unplaced pair defaults to co-located (single-host runs)
        return self.placement.get(peer) == self.placement.get(self.group)

    def _addr_payload(self):
        rings = {peer: (d.name, a.name)
                 for peer, (d, a) in self._rings.items()}
        return ("shmaddr", self.listener.address, rings)

    def _sock_addr(self, addr):
        if isinstance(addr, tuple) and addr and addr[0] == "shmaddr":
            return addr[1]
        return addr

    def _dial(self, peer: str, addr) -> Optional[BatchedConn]:
        if isinstance(addr, tuple) and addr and addr[0] == "shmaddr":
            names = addr[2].get(self.group)
            if names is not None:
                try:
                    data_ring = ShmRing.attach(names[0])
                    ack_ring = ShmRing.attach(names[1])
                except (FileNotFoundError, OSError):
                    return None   # receiver died; a fresh broadcast follows
                return _ShmConn(data_ring, ack_ring, self.ack_flush)
        return super()._dial(peer, addr)

    def _on_stop(self) -> None:
        with self._reg:
            rings = list(self._rings.values())
            self._rings = {}
        for d, a in rings:
            d.unlink()
            a.unlink()


class ShmSupervisor(SocketSupervisor):
    """``transport="shm"``: socket supervisor + ring reclamation.  The
    broker is payload-agnostic; the only extra duty is unlinking the ring
    segments named in a dead incarnation's address payload (its creator
    is gone and cannot) and sweeping all known rings at engine stop."""

    name = "shm"

    def __init__(self, driver):
        super().__init__(driver)
        sweep_stale_rings()

    @staticmethod
    def _ring_names(addr) -> list:
        if isinstance(addr, tuple) and addr and addr[0] == "shmaddr":
            return [n for names in addr[2].values() for n in names]
        return []

    def _reclaim_addr(self, group: str, addr) -> None:
        for name in self._ring_names(addr):
            unlink_ring(name)

    def request_stop(self):
        super().request_stop()
        d = self.driver
        with d.lock:
            names = [n for addr, _gen in self.addr.values()
                     for n in self._ring_names(addr)]
        for name in names:
            unlink_ring(name)


register_transport("shm", ShmSupervisor,
                   lambda bootstrap, group, conn: ShmWorker(
                       bootstrap, group, conn))
