"""The ``local`` transport: reliable in-memory FIFO channels with capacity
back-pressure (Sec. 2.1) — one shared buffer is both endpoints.

Semantics:
  * ``put`` blocks while the buffer is full (the credit window: capacity
    minus buffered events is exactly the sender's credit balance); a
    blocked put aborts if the engine is stopping or the channel closed.
  * ``peek``/``ack``: the receiver *peeks* the head, runs its State-Update
    transaction, then ``ack``s to remove it — an event leaves the channel
    only once acknowledged (assigned an InSet_ID). A receiver crash between
    peek and ack leaves the event in place.
  * deferred acks (group-commit pipelining): with a batched log backend the
    ack may only be *released* once the State-Update transaction is durable
    (the durability-watermark rule). ``defer_ack`` marks the head event
    processed-but-unreleased and advances the peek cursor so the receiver
    keeps processing; ``release_ack`` later removes it FIFO. Deferred events
    still occupy capacity (their credit returns only at release) and still
    count in ``len`` (the engine's idle detection waits for the flush). On
    a receiver restart ``reset_pending`` rewinds the cursor: unreleased
    events are simply re-delivered and the obsolete filter drops the
    already-recovered ones.
  * Channel contents survive operator restarts (the transport is the
    reliable piece, like the in-house TCP messaging + buffers in SAP DI).
  * A closed channel accepts no further events: ``put``/``try_put`` report
    failure and ``force_put`` raises — an event silently absorbed after
    ``close()`` would be stranded forever (nobody drains a closed buffer).
"""
from __future__ import annotations

import threading
from typing import List, Optional

from repro.core.events import Event
from repro.core.transport.base import ChannelEndpoint


class ChannelClosed(Exception):
    pass


class Channel(ChannelEndpoint):
    def __init__(self, send_op: str, send_port: str, rec_op: str,
                 rec_port: str, capacity: int = 64):
        self.send_op, self.send_port = send_op, send_port
        self.rec_op, self.rec_port = rec_op, rec_port
        self.capacity = capacity
        self._buf: List[Event] = []
        self._pending = 0       # processed-but-unreleased events at the head
        self._cv = threading.Condition()
        self._closed = False
        self.total_put = 0

    def put(self, ev: Event, stop_flag=None, timeout: float = 0.05) -> bool:
        """Blocking put with back-pressure. Returns False if stopping."""
        with self._cv:
            while len(self._buf) >= self.capacity:
                if self._closed or (stop_flag is not None and stop_flag()):
                    return False
                self._cv.wait(timeout)
            if self._closed:
                return False
            self._buf.append(ev)
            self.total_put += 1
            self._cv.notify_all()
            return True

    def try_put(self, ev: Event) -> bool:
        with self._cv:
            if self._closed or len(self._buf) >= self.capacity:
                return False
            self._buf.append(ev)
            self.total_put += 1
            self._cv.notify_all()
            return True

    def force_put(self, ev: Event):
        """Append ignoring capacity — reserved for supervisor-side paths
        that must absorb an already-logged event (Alg 13 reassignment
        re-sends): the set is bounded by the reassignment, and dropping
        one would strand an UNDONE row forever. Raises on a closed
        channel instead of stranding the event in a buffer nobody reads."""
        with self._cv:
            if self._closed:
                raise ChannelClosed(self.name)
            self._buf.append(ev)
            self.total_put += 1
            self._cv.notify_all()

    def peek(self) -> Optional[Event]:
        """Head of the unprocessed suffix (skips deferred-ack events)."""
        with self._cv:
            return self._buf[self._pending] \
                if len(self._buf) > self._pending else None

    def peek_index(self, i: int) -> Optional[Event]:
        """i-th event of the unprocessed suffix — the routed transport's
        delivery cursor (events stay here, the reliable buffer, until the
        remote receiver acks)."""
        with self._cv:
            j = self._pending + i
            return self._buf[j] if len(self._buf) > j else None

    def peek_run(self, n: int) -> List[Event]:
        """Up to ``n`` events from the head of the unprocessed suffix, in
        FIFO order — the receiver's micro-batch. A snapshot only: events
        stay buffered until individually acked/deferred, so a crash
        mid-run re-delivers the unacked suffix."""
        with self._cv:
            j = self._pending
            return list(self._buf[j:j + n])

    def ack(self) -> Optional[Event]:
        """Immediately remove the event ``peek`` returned."""
        with self._cv:
            ev = self._buf.pop(self._pending) \
                if len(self._buf) > self._pending else None
            self._cv.notify_all()
            return ev

    def ack_run(self, n: int) -> int:
        """Vectored ``ack``: remove the first ``n`` unprocessed events in
        one lock acquisition. Returns the count actually removed."""
        with self._cv:
            k = min(n, len(self._buf) - self._pending)
            if k > 0:
                del self._buf[self._pending:self._pending + k]
                self._cv.notify_all()
            return k

    def defer_run(self, n: int) -> int:
        """Vectored ``defer_ack``: mark the first ``n`` unprocessed events
        processed-but-unreleased in one lock acquisition."""
        with self._cv:
            k = min(n, len(self._buf) - self._pending)
            self._pending += k
            return k

    def defer_ack(self):
        """Mark the event ``peek`` returned as processed; it stays buffered
        until ``release_ack`` (durability watermark reached)."""
        with self._cv:
            if len(self._buf) > self._pending:
                self._pending += 1

    def release_ack(self) -> Optional[Event]:
        """Release the oldest deferred ack (FIFO)."""
        with self._cv:
            if self._pending == 0:
                return None
            self._pending -= 1
            ev = self._buf.pop(0)
            self._cv.notify_all()
            return ev

    def reset_pending(self):
        """Receiver restart: unreleased events become deliverable again."""
        with self._cv:
            self._pending = 0

    def __len__(self):
        with self._cv:
            return len(self._buf)

    def unprocessed(self) -> int:
        """Events awaiting processing (buffered minus deferred)."""
        with self._cv:
            return len(self._buf) - self._pending

    def held(self) -> int:
        """Deferred-ack events still occupying capacity (the durability
        watermark has not released them yet)."""
        with self._cv:
            return self._pending

    def clear(self):
        """Used only by the ABS baseline (global restart discards in-flight
        events) — never by LOG.io recovery."""
        with self._cv:
            self._buf.clear()
            self._pending = 0
            self._cv.notify_all()

    def close(self):
        with self._cv:
            self._closed = True
            self._cv.notify_all()
