"""Adaptive micro-batch governor for the operator hot path (Nagle-style).

The paper's evaluation concedes that ABS beats LOG.io at high event rates
because LOG.io pays per-event logging/ack overhead, and names amortization
as the lever (Sec. 9).  The governor turns that into a *regime*, not a
structural loss: receivers drain a **run** of already-queued events from a
channel and apply it through one vectored log transaction and one coalesced
ack emission.

Design constraints:

* **Never wait for a batch to fill.**  The governor only sizes the run by
  what is *already buffered* — an idle channel yields runs of one, so at
  the paper's moderate regime (1 event / 100 ms) behavior is bit-identical
  to the per-event path: same latency, same straggler profile.  The hard
  latency bound is structural, not a timer.
* **Bounded run length.**  ``max_batch`` caps the run outright, and an
  EWMA of the observed per-event apply cost derives a second cap so one
  run never occupies an operator longer than ``latency_bound`` seconds —
  keeping warm-restart replay (≤ one run past the durability watermark)
  and credit-window turnaround bounded even under saturation.
* **Off by default.**  ``mode="off"`` (or batch size 1) short-circuits to
  the scalar path.  ``LOGIO_BATCH`` / ``Engine(batching=...)`` select
  ``"adaptive"`` or a fixed integer size.

See ``docs/batching.md`` for the knob reference.
"""
from __future__ import annotations

import os
import time
from typing import Optional, Union

#: run-length ceiling for the adaptive mode
DEFAULT_MAX_BATCH = 128
#: one run must not occupy the operator longer than this (seconds)
DEFAULT_LATENCY_BOUND = 0.010


def resolve_batching(spec: Union[None, str, int]) -> Union[str, int]:
    """Normalize a batching spec: ``None`` consults ``LOGIO_BATCH``; the
    result is ``"off"``, ``"adaptive"``, or a fixed positive int."""
    if spec is None:
        spec = os.environ.get("LOGIO_BATCH", "off")
    if isinstance(spec, bool):   # bool is an int subclass; reject early
        raise ValueError(f"invalid batching spec {spec!r}")
    if isinstance(spec, int):
        if spec < 1:
            raise ValueError(f"invalid batching spec {spec!r}")
        return 1 if spec == 1 else spec
    s = str(spec).strip().lower()
    if s in ("off", "", "0", "none", "false"):
        return "off"
    if s == "adaptive":
        return "adaptive"
    try:
        n = int(s)
    except ValueError:
        raise ValueError(f"invalid batching spec {spec!r}") from None
    if n < 1:
        raise ValueError(f"invalid batching spec {spec!r}")
    return n


class BatchGovernor:
    """Per-operator run-length governor.

    ``limit(queue_depth)`` returns how many events the receiver may drain
    this pass; ``observe(n, elapsed)`` feeds back the measured apply cost
    so the latency bound tracks the actual workload.
    """

    def __init__(self, mode: Union[None, str, int] = None,
                 max_batch: int = DEFAULT_MAX_BATCH,
                 latency_bound: float = DEFAULT_LATENCY_BOUND):
        self.mode = resolve_batching(mode)
        self.max_batch = max_batch
        self.latency_bound = latency_bound
        # EWMA of per-event apply cost; seeded pessimistically high so the
        # first runs stay short until real measurements arrive
        self._ev_cost = latency_bound / 8.0
        self.runs = 0
        self.events = 0
        self.max_run = 0

    @property
    def enabled(self) -> bool:
        return self.mode != "off" and self.mode != 1

    def limit(self, queue_depth: int) -> int:
        """Run length for this pass given the channel's buffered depth.
        Never exceeds the depth — the governor does not wait for events."""
        if not self.enabled:
            return 1
        if queue_depth <= 1:
            return 1    # moderate regime: degenerate to the scalar path
        if self.mode != "adaptive":
            return min(queue_depth, int(self.mode))
        cap = self.max_batch
        if self._ev_cost > 0:
            cap = min(cap, max(1, int(self.latency_bound / self._ev_cost)))
        return min(queue_depth, cap)

    def observe(self, n: int, elapsed: float) -> None:
        """Feed back one completed run of ``n`` events taking ``elapsed``
        seconds through the apply+commit pass."""
        self.runs += 1
        self.events += n
        if n > self.max_run:
            self.max_run = n
        if n > 0 and elapsed > 0:
            per_ev = elapsed / n
            self._ev_cost += 0.2 * (per_ev - self._ev_cost)

    def timed(self):
        """Context-free timer helper: returns ``time.monotonic``'s now."""
        return time.monotonic()

    def stats(self) -> dict:
        """Point-in-time counters as a FRESH dict each call — callers own
        the result and may mutate it freely without corrupting governor
        state (``Engine.metrics()`` folds these into ``OpMetrics``)."""
        return {"mode": str(self.mode), "runs": self.runs,
                "events": self.events, "max_run": self.max_run,
                "ev_cost": self._ev_cost}


def make_governor(spec: Union[None, str, int],
                  max_batch: int = DEFAULT_MAX_BATCH,
                  latency_bound: float = DEFAULT_LATENCY_BOUND
                  ) -> Optional[BatchGovernor]:
    """Governor for one operator, or ``None`` when batching is off (the
    scalar hot path stays byte-identical to pre-batching builds)."""
    mode = resolve_batching(spec)
    if mode == "off" or mode == 1:
        return None
    return BatchGovernor(mode, max_batch=max_batch,
                         latency_bound=latency_bound)
