"""Pipeline engine: graph wiring, group execution (threads ≈ pods),
failure injection, warm restart + recovery, lineage configuration.

Two protocols share the substrate:
  * ``protocol="logio"`` — this paper (pessimistic logging, non-blocking
    recovery; only failed groups restart).
  * ``protocol="abs"``   — the baseline (Sec. 8.1): aligned barrier
    snapshotting, global restart from the last complete epoch
    (see ``repro.core.abs``).

Three execution modes:
  * ``mode="thread"``  — one thread per group, real back-pressure and timing
    (used by the benchmarks that reproduce Sec. 9).
  * ``mode="step"``    — deterministic single-threaded round-robin (used by
    the hypothesis property tests; failures injected at exact points).
  * ``mode="process"`` — one forked OS process per group, all workers
    sharing this process's log store; crash = real ``kill -9`` and only
    the failed group warm-restarts (``repro.core.procmode``).  The event
    transport is selectable (``transport="routed"`` keeps every
    authoritative buffer in the supervisor; ``transport="socket"`` runs
    direct worker-to-worker socket channels) — see
    :mod:`repro.core.transport`.  All transports enforce credit-based
    back-pressure at the channel capacity.
"""
from __future__ import annotations

import collections
import dataclasses
import multiprocessing
import os
import pickle
import threading
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.batching import make_governor, resolve_batching
from repro.core.builtin import GeneratorSource
from repro.core.transport import Channel
from repro.core.transport.base import (Placement, WorkerBootstrap,
                                       process_transport_names)
from repro.core.lineage import LineageScope, enabled_ports
from repro.core.logstore import (LogBackend, MemoryLogStore, StoreConfig,
                                 build_store)
from repro.core.metrics import MetricsSnapshot, build_snapshot
from repro.core.operator import (ExternalSystem, Operator, OperatorRuntime,
                                 SimulatedCrash)
from repro.core.recovery import recover_operator


@dataclasses.dataclass(frozen=True)
class TransportConfig:
    """Typed description of the event transport, replacing the stringly
    ``transport=`` + ``transport_options={...}`` pair. ``name`` is
    ``"local"`` (thread/step mode) or a process transport
    (``"routed"``/``"socket"``/``"tcp"``/``"shm"``); the remaining fields
    configure the byte transports and are ignored by the others."""

    name: str = "local"
    family: Optional[str] = None        # "unix" | "inet" (socket only)
    host: Optional[str] = None          # bind host (inet only)
    authkey: Optional[bytes] = None     # peer-auth secret (per-run default)
    ack_flush: Optional[float] = None   # ack-coalescing linger (seconds)
    ring_bytes: Optional[int] = None    # shm ring capacity per direction

    def __post_init__(self):
        valid = ("local",) + tuple(process_transport_names())
        if self.name not in valid:
            raise ValueError(f"unknown transport {self.name!r} "
                             f"(expected one of {list(valid)})")
        if self.family not in (None, "unix", "inet"):
            raise ValueError(f"unknown socket family {self.family!r} "
                             "(expected 'unix' or 'inet')")
        if self.ack_flush is not None and self.ack_flush < 0:
            raise ValueError("ack_flush must be >= 0")
        if self.ring_bytes is not None and self.ring_bytes < 4096:
            raise ValueError("ring_bytes must be >= 4096")

    def options(self) -> dict:
        """The legacy ``transport_options`` dict this config describes."""
        out: dict = {}
        if self.family is not None:
            out["family"] = self.family
        if self.host is not None:
            out["host"] = self.host
        if self.authkey is not None:
            out["authkey"] = self.authkey
        if self.ack_flush is not None:
            out["ack_flush"] = self.ack_flush
        if self.ring_bytes is not None:
            out["ring_bytes"] = self.ring_bytes
        return out


class FailureInjector:
    """Crash — or stall — the pipeline at precise points.

    plan entries: (op_id, point, nth) — raise SimulatedCrash the nth time
    ``crash_point(op_id, point)`` fires (1-based). point="*" matches any.

    stall entries: (op_id, point, nth_lo, nth_hi, seconds) — sleep
    ``seconds`` at every firing whose per-point count falls in
    [nth_lo, nth_hi] (inclusive).  This is the straggler generator for the
    adaptive-controller traces: the operator stays alive but its service
    time balloons for a window of events.
    """

    def __init__(self, plan: Sequence[Tuple[str, str, int]] = (),
                 stalls: Sequence[Tuple[str, str, int, int, float]] = ()):
        self.plan = list(plan)
        self.stalls = list(stalls)
        self.counts: Dict[Tuple[str, str], int] = collections.defaultdict(int)
        self.fired: List[Tuple[str, str, int]] = []
        self.stalled: int = 0
        self.lock = threading.Lock()

    def stall_active(self, op_id: str, point: str) -> bool:
        """True while a stall window for (op_id, point) has firings left —
        the controller tests use this to know when the straggler clears."""
        with self.lock:
            n = self.counts[(op_id, point)]
            return any(o == op_id and p == point and n < hi
                       for o, p, _lo, hi, _s in self.stalls)

    def __call__(self, op_id: str, point: str):
        delay = 0.0
        with self.lock:
            # two plain counters per operator: hits of this exact point, and
            # hits of any point (what "*" plan entries count against)
            self.counts[(op_id, point)] += 1
            self.counts[(op_id, "*")] += 1
            n_point = self.counts[(op_id, point)]
            n_any = self.counts[(op_id, "*")]
            for (o, p, lo, hi, sec) in self.stalls:
                if o != op_id:
                    continue
                n = n_point if p == point else \
                    (n_any if p == "*" else None)
                if n is not None and lo <= n <= hi:
                    delay = max(delay, sec)
                    self.stalled += 1
            for i, (o, p, nth) in enumerate(self.plan):
                if o != op_id:
                    continue
                if (p == point and n_point == nth) or (p == "*" and n_any == nth):
                    self.fired.append((o, p, nth))
                    del self.plan[i]
                    raise SimulatedCrash(f"{op_id}@{point}#{nth}")
        if delay > 0:
            time.sleep(delay)


class Pipeline:
    """Declarative pipeline graph; operators given as factories so restarts
    build fresh instances (volatile state loss)."""

    def __init__(self):
        self.factories: Dict[str, Callable[[], Operator]] = {}
        self.connections: List[Tuple[str, str, str, str, int]] = []
        self.groups: Dict[str, str] = {}

    def add(self, factory: Callable[[], Operator], group: Optional[str] = None
            ) -> str:
        op = factory()
        self.factories[op.id] = factory
        self.groups[op.id] = group or op.id
        return op.id

    def connect(self, src: str, src_port: str, dst: str, dst_port: str,
                capacity: int = 256):
        self.connections.append((src, src_port, dst, dst_port, capacity))

    def successors(self, op_id: str) -> List[str]:
        return [c[2] for c in self.connections if c[0] == op_id]

    def predecessors(self, op_id: str) -> List[str]:
        return [c[0] for c in self.connections if c[2] == op_id]

    def edges(self) -> List[Tuple[Tuple[str, str], Tuple[str, str]]]:
        return [((s, sp), (d, dp)) for s, sp, d, dp, _ in self.connections]


class Engine:
    def __init__(self, pipeline: Pipeline, *,
                 store: Optional[Any] = None,
                 external: Optional[ExternalSystem] = None,
                 protocol: str = "logio",
                 lineage_scopes: Sequence[LineageScope] = (),
                 injector: Optional[FailureInjector] = None,
                 mode: str = "thread",
                 transport: Optional[Any] = None,
                 transport_options: Optional[dict] = None,
                 ctx: Optional[str] = None,
                 placement: Optional[Any] = None,
                 cluster: Optional[Any] = None,
                 restart_delay: float = 0.05,
                 replay_ops: Sequence[str] = (),
                 abs_options: Optional[dict] = None,
                 batching: Optional[Any] = None,
                 resume: bool = False,
                 recovery_modes: Optional[Dict[str, str]] = None,
                 epoch_interval: int = 16):
        """``store`` is any :class:`LogBackend`, a typed
        :class:`~repro.core.logstore.StoreConfig`, or a ``build_store``
        spec string like ``"memory+sharded+group"``. ``resume=True`` starts
        every operator in state "restarted" — warm restart of a whole
        pipeline against a recovered store (full-process crash).
        ``transport`` is a :class:`TransportConfig` or a transport name:
        it selects the process-mode channel implementation
        (``"routed"``/``"socket"``/``"tcp"``); thread and step mode always
        use the in-memory ``"local"`` transport.  The legacy
        ``transport_options`` dict configures the socket family
        (``{"family": "unix"|"inet"}``), bind host and authkey — with a
        TransportConfig those knobs live in the config instead.  ``ctx``
        selects the worker start method
        (``"fork"``/``"spawn"``): spawn workers are rebuilt purely from a
        picklable :class:`WorkerBootstrap` payload + the log, never from
        inherited parent memory — group factories must then be picklable.
        ``placement`` (a :class:`Placement` or a ``{group: node}`` dict)
        assigns groups to cluster nodes; ``cluster`` is the node-agent
        harness (e.g. :class:`repro.core.cluster.LocalCluster`) that
        launches workers on those nodes."""
        self.pipeline = pipeline
        self._resume = resume
        if isinstance(transport, TransportConfig):
            if transport_options:
                raise ValueError("pass socket options inside the "
                                 "TransportConfig, not via "
                                 "transport_options=")
            transport_options = transport.options()
            transport = transport.name
        if mode == "process":
            self.transport = transport or "routed"
            if self.transport not in process_transport_names():
                raise ValueError(
                    f"unknown process transport {self.transport!r} "
                    f"(have {process_transport_names()})")
            if ctx is None:
                ctx = ("fork" if "fork" in
                       multiprocessing.get_all_start_methods() else "spawn")
            if ctx not in multiprocessing.get_all_start_methods():
                raise ValueError(
                    f"unknown start method ctx={ctx!r} "
                    f"(have {multiprocessing.get_all_start_methods()})")
        else:
            if transport not in (None, "local"):
                raise ValueError(
                    f"transport={transport!r} requires mode='process'")
            if ctx is not None or placement is not None \
                    or cluster is not None:
                raise ValueError(
                    "ctx=/placement=/cluster= require mode='process'")
            self.transport = "local"
        self.proc_ctx = ctx
        self.transport_options = dict(transport_options or {})
        if self.transport in ("socket", "tcp", "shm"):
            if self.transport == "tcp":
                if self.transport_options.get("family", "inet") != "inet":
                    raise ValueError(
                        "transport='tcp' is pinned to family='inet'; use "
                        "transport='socket' for other families")
                self.transport_options["family"] = "inet"
            # per-run authkey: worker listeners authenticate every peer
            # connection (an AF_INET listener is reachable by anything on
            # the network, unlike a mode-0600 unix socket)
            self.transport_options.setdefault("authkey", os.urandom(20))
            fam = self.transport_options.get("family")
            if fam not in (None, "unix", "inet"):
                raise ValueError(f"unknown socket family {fam!r} "
                                 "(expected 'unix' or 'inet')")
            if fam == "unix" and not hasattr(__import__("socket"),
                                             "AF_UNIX"):
                raise ValueError("family='unix' unavailable on this host")
        if isinstance(placement, dict):
            placement = Placement(placement)
        self.placement = placement or Placement()
        self.cluster = cluster
        if cluster is None and self.placement.nodes():
            raise ValueError("placement names nodes but no cluster= given")
        if isinstance(store, (str, StoreConfig)):
            store = build_store(store)
        self.store: LogBackend = store or MemoryLogStore()
        self.external = external or ExternalSystem()
        self.protocol = protocol
        self.lineage_scopes = list(lineage_scopes)
        self.injector = injector or FailureInjector()
        self.mode = mode
        self.restart_delay = restart_delay
        self.replay_ops = set(replay_ops)
        self.abs_options = abs_options or {}
        # micro-batch governor spec: "off" (default), "adaptive", or a
        # fixed int run length; None consults LOGIO_BATCH. Resolved once
        # here so process-mode workers inherit the supervisor's decision
        # through the bootstrap payload. See docs/batching.md.
        self.batching = resolve_batching(batching)

        # per-group recovery mode: "log" (per-event LOG.io logging, the
        # default) or "epoch" (interval state snapshotting on the same
        # log — the ABS-style amortization) — the adaptive controller's
        # actuator (repro.core.controller).  The mode recorded in the log
        # is authoritative across restarts: a resumed engine overrides the
        # constructor argument with what the log says.
        self.epoch_interval = int(epoch_interval)
        if self.epoch_interval < 2:
            raise ValueError(f"epoch_interval must be >= 2, "
                             f"got {epoch_interval!r}")
        all_groups = set(pipeline.groups.values())
        self.recovery_modes: Dict[str, str] = {}   # epoch groups only
        self._mode_stale: set = set()   # groups whose snapshot may trail
        for g, m in (recovery_modes or {}).items():
            if g not in all_groups:
                raise ValueError(f"recovery_modes names unknown group {g!r} "
                                 f"(have {sorted(all_groups)})")
            if m not in ("log", "epoch"):
                raise ValueError(f"unknown recovery mode {m!r} for group "
                                 f"{g!r} (expected 'log' or 'epoch')")
            if m == "log" and protocol == "abs":
                raise ValueError(
                    "recovery_mode 'log' cannot be mixed with "
                    "protocol='abs' (the ABS barrier aligns every group)")
            if m == "epoch":
                self.recovery_modes[g] = m
        persisted = {g: self._load_mode(g) for g in all_groups}
        for g, rec in persisted.items():
            if rec is None:
                continue
            if rec["mode"] == "epoch":
                self.recovery_modes[g] = "epoch"
            else:
                self.recovery_modes.pop(g, None)
            if rec.get("stale"):
                self._mode_stale.add(g)
        if protocol != "abs":
            # record constructor-requested epoch modes up front: a crash
            # before the first switch must already recover under them
            for g in sorted(self.recovery_modes):
                if persisted.get(g) is None:
                    self._persist_mode(g, "epoch", stale=False)

        self._stop = threading.Event()
        self._done = threading.Event()
        self.ops: Dict[str, Operator] = {}
        self.runtimes: Dict[str, OperatorRuntime] = {}
        self.channels: List[Channel] = []
        self.threads: Dict[str, threading.Thread] = {}
        self.group_state: Dict[str, str] = {}
        self.failures = 0
        self.restarts = 0
        self._kill_requests: set = set()
        self._proc = None               # ProcessEngineDriver (mode="process")
        self._restart_lock = threading.Lock()
        self._lineage_ports = enabled_ports(pipeline, self.lineage_scopes)
        if self.replay_ops:
            # replay flips (Sec. 5) can turn done inputs of a replay
            # operator back into needed ones, so checkpoint compaction must
            # never GC the payloads feeding replay ops
            self.store.set_gc_protect(
                self.replay_ops |
                {s for s, _sp, d, _dp, _ in pipeline.connections
                 if d in self.replay_ops})
        self._build(first=True, restarted=resume)

    # ------------------------------------------------------------------
    # per-group recovery mode (the adaptive controller's actuator)
    # ------------------------------------------------------------------
    _MODE_KEY = "__mode__:{}"

    def _load_mode(self, group: str) -> Optional[dict]:
        blob = self.store.get_state(self._MODE_KEY.format(group))
        return None if blob is None else pickle.loads(blob)

    def _persist_mode(self, group: str, mode: str, *, stale: bool):
        txn = self.store.begin()
        txn.put_state(self._MODE_KEY.format(group), 0,
                      pickle.dumps({"mode": mode, "stale": bool(stale)}))
        txn.commit()

    def recovery_mode_of(self, group: str) -> str:
        if self.protocol == "abs":
            return "epoch"   # the ABS barrier epoch-snapshots every group
        return self.recovery_modes.get(group, "log")

    def set_recovery_mode(self, group: str, mode: str):
        """Switch ``group`` between ``"log"`` (per-event logging) and
        ``"epoch"`` (interval snapshotting) at runtime.

        The new mode is recorded in the log *before* it takes effect, so a
        crash anywhere mid-switch recovers under the mode the log holds.
        Leaving "epoch" persists fresh state snapshots (thread/step mode)
        or marks the group's snapshots stale (process mode — the restarted
        worker then recovers with the DONE-inclusive scan and re-bounds
        itself).  Process-mode groups warm-restart to apply the switch;
        thread-mode groups switch live under the operator locks."""
        if mode not in ("log", "epoch"):
            raise ValueError(f"unknown recovery mode {mode!r} "
                             "(expected 'log' or 'epoch')")
        if group not in set(self.pipeline.groups.values()):
            raise ValueError(f"unknown group {group!r}")
        if self.protocol == "abs":
            raise ValueError("recovery modes are fixed under protocol='abs' "
                             "(the ABS barrier aligns every group)")
        with self._restart_lock:
            cur = self.recovery_mode_of(group)
            if cur == mode:
                return
            if self._proc is not None:
                # persist first (authoritative across SIGKILL), then
                # warm-restart the group so the worker rebuilds under it
                self._persist_mode(group, mode, stale=(cur == "epoch"))
                if mode == "epoch":
                    self.recovery_modes[group] = "epoch"
                else:
                    self.recovery_modes.pop(group, None)
                    self._mode_stale.add(group)
                self._proc.stop_group(group)
                self._proc.start_group(group, recover=True)
                return
            if mode == "log":
                # leaving epoch: persist a fresh snapshot per op under its
                # lock, so interval-1 recovery is re-bounded before the
                # mode record flips
                for op_id in self.group_ops(group):
                    rt = self.runtimes.get(op_id)
                    if rt is None:
                        continue
                    with rt.op_lock:
                        txn = self.store.begin()
                        txn.put_state(op_id, rt.new_state_id(),
                                      rt._state_blob(),
                                      keep_history=rt.keep_state_history)
                        txn.commit()
                        rt.state_interval = 1
                        rt._since_state = 0
                self._persist_mode(group, "log", stale=False)
                self.recovery_modes.pop(group, None)
                self._mode_stale.discard(group)
            else:
                self._persist_mode(group, "epoch", stale=False)
                self.recovery_modes[group] = "epoch"
                for op_id in self.group_ops(group):
                    rt = self.runtimes.get(op_id)
                    if rt is not None and not rt.keep_state_history:
                        with rt.op_lock:
                            rt.state_interval = self.epoch_interval

    # ------------------------------------------------------------------
    def _build(self, first: bool, only_group: Optional[str] = None,
               restarted: bool = False):
        # step mode is single-threaded: a blocking put would deadlock the
        # deterministic round-robin, so its channels are effectively
        # unbounded. Thread and process mode run the configured capacity —
        # the credit window of the transport layer.
        cap_override = 1_000_000 if self.mode == "step" else None
        if first:
            for (s, sp, d, dp, cap) in self.pipeline.connections:
                self.channels.append(Channel(s, sp, d, dp,
                                             cap_override or cap))
        for op_id, factory in self.pipeline.factories.items():
            if only_group and self.pipeline.groups[op_id] != only_group:
                continue
            op = factory()
            assert op.id == op_id
            op.state = "restarted" if restarted else "running"
            self.ops[op_id] = op
            self._wire(op)
            if restarted:
                # deferred acks of the dead runtime rewind: the events are
                # still buffered and will be re-delivered (obsolete-filtered
                # once recovery restores the context)
                for ch in op.in_channels.values():
                    ch.reset_pending()
            lin_in, lin_out = self._lineage_ports.get(op_id, (set(), set()))
            g = self.pipeline.groups[op_id]
            self.runtimes[op_id] = OperatorRuntime(
                op, self.store,
                lineage_in=lin_in, lineage_out=lin_out,
                external=self.external,
                crash_point=self.injector,
                stop_flag=self._stop.is_set,
                replay_mode=op_id in self.replay_ops,
                keep_state_history=bool(lin_out),
                state_interval=(self.epoch_interval
                                if self.recovery_modes.get(g) == "epoch"
                                else 1),
            )
            self.runtimes[op_id].governor = make_governor(self.batching)
        for g in set(self.pipeline.groups.values()):
            if only_group and g != only_group:
                continue
            self.group_state[g] = "running"

    def _wire(self, op: Operator):
        op.in_channels = {}
        op.out_channels = {p: [] for p in op.output_ports}
        for ch in self.channels:
            if ch.rec_op == op.id:
                op.in_channels[ch.rec_port] = ch
            if ch.send_op == op.id:
                op.out_channels.setdefault(ch.send_port, []).append(ch)

    def group_ops(self, group: str) -> List[str]:
        return [o for o, g in self.pipeline.groups.items() if g == group]

    def make_bootstrap(self, group: str, *, recover: bool,
                       incarnation: int) -> WorkerBootstrap:
        """The picklable payload a worker (re)starts from — a snapshot of
        the live topology (scaling mutates ``pipeline.connections`` and
        ``engine.channels`` in lock-step, so connection tuples are the
        authoritative channel specs) plus this group's factories.  No
        recovery state crosses: the worker rebuilds it from the log."""
        p = self.pipeline
        opts = dict(self.transport_options)
        if self.transport == "shm":
            # rings are a same-host medium: ship the placement node map so
            # each worker picks ring vs. socket per peer (None == None for
            # unplaced pairs — the single-host default is co-located)
            opts["placement"] = {g: self.placement.node_of(g)
                                 for g in set(p.groups.values())}
        return WorkerBootstrap(
            group=group,
            incarnation=incarnation,
            recover=recover,
            transport=self.transport,
            transport_options=opts,
            factories={o: f for o, f in p.factories.items()
                       if p.groups[o] == group},
            connections=list(p.connections),
            groups=dict(p.groups),
            lineage_ports={o: self._lineage_ports[o]
                           for o in self.group_ops(group)
                           if o in self._lineage_ports},
            replay_ops=frozenset(self.replay_ops),
            batching=self.batching,
            recovery={"modes": dict(self.recovery_modes),
                      "stale": sorted(self._mode_stale),
                      "interval": self.epoch_interval},
        )

    # ------------------------------------------------------------------
    def signal_done(self):
        self._done.set()

    def kill_group(self, group: str):
        """External kill switch: SIGKILL the worker in process mode, a
        simulated node failure in thread mode."""
        if self._proc is not None:
            self._proc.kill_group(group)
            return
        self._kill_requests.add(group)

    def start(self):
        if self.protocol == "abs":
            from repro.core.abs import AbsEngineDriver
            self._abs = AbsEngineDriver(self, **self.abs_options)
            self._abs.start()
            return
        if self.mode == "process":
            from repro.core.procmode import ProcessEngineDriver
            self._proc = ProcessEngineDriver(self)
            self._proc.start()
            return
        for g in set(self.pipeline.groups.values()):
            self._start_group(g, recover=self._resume)

    def _start_group(self, group: str, recover: bool):
        t = threading.Thread(target=self._run_group, args=(group, recover),
                             daemon=True, name=f"grp-{group}")
        self.threads[group] = t
        t.start()

    def _run_group(self, group: str, recover: bool):
        try:
            if recover:
                for op_id in self.group_ops(group):
                    self._recover_op(self.ops[op_id])
            while not self._stop.is_set() and not self._done.is_set():
                if self.group_state.get(group) == "removed":
                    return
                if group in self._kill_requests:
                    self._kill_requests.discard(group)
                    raise SimulatedCrash(f"external kill of {group}")
                progressed = False
                for op_id in self.group_ops(group):
                    op = self.ops.get(op_id)
                    if op is not None:
                        progressed |= self._step_op(op)
                    rt = self.runtimes.get(op_id)
                    if rt is not None:
                        progressed |= rt.drain_durable()
                # checkpoint cadence: compact the log once the configured
                # record count accumulated (no-op for non-checkpointing
                # stores), keeping warm-restart replay O(interval)
                self.store.maybe_checkpoint()
                if not progressed and self._sources_exhausted():
                    # end of stream: force the durability watermark forward
                    # so held acks/writes release before we conclude we're
                    # done. Mid-stream idle gaps rely on the interval
                    # watermark instead — forcing there would collapse
                    # group-commit batches to single transactions.
                    for op_id in self.group_ops(group):
                        rt = self.runtimes.get(op_id)
                        if rt is not None:
                            progressed |= rt.drain_durable(force=True)
                if not progressed:
                    if self._sources_exhausted() and self._all_idle():
                        time.sleep(0.01)
                        if self._sources_exhausted() and self._all_idle():
                            self._done.set()
                            return
                    time.sleep(0.001)
        except SimulatedCrash as e:
            self._on_crash(group, e)

    # ------------------------------------------------------------------
    def _step_op(self, op: Operator) -> bool:
        rt = self.runtimes[op.id]
        if isinstance(op, GeneratorSource):
            gov = rt.governor
            if gov is not None:
                n = gov.limit(op.pending_emits())
                if n > 1:
                    t0 = time.monotonic()
                    k = op.step_run(n)
                    gov.observe(k, time.monotonic() - t0)
                    return k > 0
            return op.step()
        progressed = False
        gov = rt.governor
        for port in op.input_ports:
            ch = op.in_channels.get(port)
            if ch is None:
                continue
            if gov is not None:
                # drain a governed run of already-queued events through one
                # vectored pass; an idle channel degenerates to runs of one
                n = gov.limit(ch.unprocessed())
                if n > 1:
                    evs = ch.peek_run(n)
                    if evs:
                        t0 = time.monotonic()
                        k = rt.handle_inputs(port, evs)
                        gov.observe(k, time.monotonic() - t0)
                        progressed = progressed or k > 0
                    continue
            ev = ch.peek()
            if ev is not None:
                rt.handle_input(port, ev)
                progressed = True
        if not progressed:
            # an InSet can be left triggered with its channel already
            # drained (the input's ack txn committed but the engine
            # interleaved away before generation) — fire it here, since
            # the idle detection counts queued triggers as live work
            for inset in op.triggers():
                rt.generate(inset)
                progressed = True
        return progressed

    def _recover_op(self, op: Operator):
        rt = self.runtimes[op.id]
        is_source = isinstance(op, GeneratorSource)
        replay_pred_ports = {dp for s, sp, d, dp, _ in
                             self.pipeline.connections
                             if d == op.id and s in self.replay_ops}
        g = self.pipeline.groups[op.id]
        recover_operator(rt, is_source=is_source,
                         source_driver=GeneratorSource.driver
                         if is_source else None,
                         replay_pred_ports=replay_pred_ports,
                         include_done=(self.recovery_modes.get(g) == "epoch"
                                       or g in self._mode_stale))

    def _replay_cascade(self, failed_group: str) -> List[str]:
        """Replay predecessors (transitively through replay ops) of the
        failed group's operators — they must restart in state 'replay'
        (Sec. 5.2)."""
        frontier = set(self.group_ops(failed_group))
        cascade: set = set()
        while True:
            preds = {s for s, sp, d, dp, _ in self.pipeline.connections
                     if d in frontier and s in self.replay_ops} - cascade                 - set(self.group_ops(failed_group))
            if not preds:
                break
            cascade |= preds
            frontier = preds
        return sorted({self.pipeline.groups[o] for o in cascade})

    def _on_crash(self, group: str, exc: SimulatedCrash):
        self.failures += 1
        self.group_state[group] = "dead"
        # volatile state of every op in the group is lost; logs+channels live
        def restart():
            if self.restart_delay > 0:
                time.sleep(self.restart_delay)     # warm pod restart
            with self._restart_lock:
                self._build(first=False, only_group=group, restarted=True)
                self.restarts += 1
                self.group_state[group] = "running"
            if self.mode == "thread":
                self._start_group(group, recover=True)
        if self.mode == "thread":
            threading.Thread(target=restart, daemon=True).start()
        else:
            restart()

    # ------------------------------------------------------------------
    def _sources_exhausted(self) -> bool:
        return all(op.exhausted for op in self.ops.values()
                   if isinstance(op, GeneratorSource))

    def _all_idle(self) -> bool:
        if any(s == "dead" for s in self.group_state.values()):
            return False
        if any(op.has_pending() for op in self.ops.values()):
            return False
        # a triggered-but-ungenerated InSet is live work even though its
        # input already left the channel: the generation (and its sends)
        # is still to come — without this, a slow generate on the final
        # event races the idle double-check and the output lands in a
        # channel whose consumer thread has already exited
        if any(op.triggers() for op in list(self.ops.values())):
            return False
        if any(rt._deferred for rt in list(self.runtimes.values())):
            return False    # effects still gated on the durability watermark
        return all(len(ch) == 0 for ch in self.channels)

    # ------------------------------------------------------------------
    # the unified typed metrics plane (docs/metrics.md)
    # ------------------------------------------------------------------
    def metrics(self) -> MetricsSnapshot:
        """One typed, coherent point-in-time view of the whole engine —
        per-operator counters + queue-depth gauges, transport counters and
        store scan effort — identical in thread, step and process mode.
        The single supported stats surface; the legacy accessors below are
        DeprecationWarning shims over it."""
        groups = dict(self.pipeline.groups)
        modes = {g: self.recovery_mode_of(g)
                 for g in set(self.pipeline.groups.values())}
        if self._proc is not None:
            op_counters, qdepth, wire = self._proc.metrics_raw()
        else:
            op_counters: Dict[str, Dict[str, int]] = {}
            qdepth: Dict[str, int] = {}
            wire: Dict[str, float] = {}
            for op_id, rt in list(self.runtimes.items()):
                c = dict(rt.stats)
                gov = rt.governor
                if gov is not None:
                    gs = gov.stats()
                    c["gov_runs"] = gs["runs"]
                    c["gov_events"] = gs["events"]
                    c["gov_max_run"] = gs["max_run"]
                op_counters[op_id] = c
                op = self.ops.get(op_id)
                if op is not None:
                    qdepth[op_id] = sum(ch.unprocessed()
                                        for ch in op.in_channels.values())
        return build_snapshot(mode=self.mode, protocol=self.protocol,
                              failures=self.failures, restarts=self.restarts,
                              op_counters=op_counters, groups=groups,
                              queue_depths=qdepth, wire=wire,
                              store=self.store, recovery_modes=modes)

    # -- deprecated accessors (shims over metrics()) --------------------
    #: the legacy ``op_stats_detail`` dict keys (rt.stats shape)
    _DETAIL_KEYS = ("events_in", "events_out", "txns", "recovered_resends",
                    "recovered_inputs", "recovery_scan_batches",
                    "batched_runs", "batched_events", "commit_us",
                    "send_stall_us")

    def process_stats(self) -> Dict[str, int]:
        """Deprecated: use ``Engine.metrics()`` (``ops[op].processed``)."""
        warnings.warn(
            "Engine.process_stats() is deprecated; use Engine.metrics() — "
            "MetricsSnapshot.ops[op].processed", DeprecationWarning,
            stacklevel=2)
        return {op: m.processed for op, m in self.metrics().ops.items()}

    def op_stats_detail(self) -> Dict[str, Dict[str, int]]:
        """Deprecated: use ``Engine.metrics()`` (``ops[op]`` fields)."""
        warnings.warn(
            "Engine.op_stats_detail() is deprecated; use Engine.metrics() "
            "— MetricsSnapshot.ops[op] carries the same counters as typed "
            "fields", DeprecationWarning, stacklevel=2)
        return {op: {k: getattr(m, k) for k in self._DETAIL_KEYS}
                for op, m in self.metrics().ops.items()}

    def wire_stats(self) -> Dict[str, float]:
        """Deprecated: use ``Engine.metrics()`` (``.transport``)."""
        warnings.warn(
            "Engine.wire_stats() is deprecated; use Engine.metrics() — "
            "MetricsSnapshot.transport (TransportMetrics)",
            DeprecationWarning, stacklevel=2)
        t = self.metrics().transport
        if not (t.frames or t.bytes or t.events or t.ctrl
                or t.ctrl_frames or t.extra):
            return {}
        out: Dict[str, float] = {
            "frames": t.frames, "bytes": t.bytes, "events": t.events,
            "ctrl": t.ctrl, "ctrl_frames": t.ctrl_frames, **dict(t.extra)}
        out["events_per_frame"] = t.events_per_frame
        out["ctrl_per_ctrl_frame"] = t.ctrl_per_ctrl_frame
        return out

    def wait(self, timeout: float = 60.0) -> bool:
        if self.protocol == "abs":
            return self._abs.wait(timeout)
        if self._proc is not None:
            return self._proc.wait(timeout)
        deadline = time.time() + timeout
        while time.time() < deadline:
            if self._done.is_set():
                self._stop.set()
                return True
            if all(not t.is_alive() for t in self.threads.values()) \
                    and all(s != "dead" for s in self.group_state.values()):
                return True
            time.sleep(0.005)
        self._stop.set()
        return False

    def stop(self):
        self._stop.set()
        if self._proc is not None:
            self._proc.stop()
        self.store.flush()
        for ch in self.channels:
            ch.close()

    def replay(self, outputs, scope=None, *, mode: Optional[str] = None,
               depth: int = 64, timeout: float = 60.0, injector=None,
               check: bool = True):
        """Partial replay-from-lineage: rederive ``outputs`` (EventKeys or
        raw ``(op, port, ssn)`` tuples) by re-executing only the operators
        in their lineage slice, feeding logged source payloads back in.
        ``scope`` (a LineageScope) bounds the walk at its start operator.
        Runs on a fresh in-memory store in ``mode`` ("thread" default, or
        "process"); ``injector`` installs a FailureInjector in the replay
        run. Returns a :class:`repro.core.replay.ReplayReport`; with
        ``check=True`` raises :class:`repro.core.replay.ReplayMismatch`
        when a deterministic slice fails to reproduce byte-identically."""
        from repro.core.replay import replay_from_log
        return replay_from_log(self, outputs, scope=scope, mode=mode,
                               depth=depth, timeout=timeout,
                               injector=injector, check=check)

    # ------------------------------------------------------------------
    # deterministic single-threaded mode (property tests)
    # ------------------------------------------------------------------
    def run_to_completion(self, max_steps: int = 200_000) -> bool:
        assert self.mode == "step"
        groups = sorted(set(self.pipeline.groups.values()))
        self._rq: List[str] = []        # ordered recovery queue

        def on_crash(group: str):
            self.failures += 1
            replay_groups = self._replay_cascade(group)
            self._build(first=False, only_group=group, restarted=True)
            for rg in replay_groups:
                self._build(first=False, only_group=rg, restarted=True)
                for oid in self.group_ops(rg):
                    self.ops[oid].state = "replay"
            self.restarts += 1
            # ordering: failed group recovers first (it marks the inputs it
            # needs as "replay" before the replay preds look for them)
            fresh = [o for o in self.group_ops(group)]
            for rg in replay_groups:
                fresh += self.group_ops(rg)
            self._rq = fresh + [o for o in self._rq if o not in fresh]

        for _ in range(max_steps):
            if self._done.is_set():
                return True
            # drain pending recoveries first (a recovery can crash too)
            if self._rq:
                oid = self._rq[0]
                op = self.ops.get(oid)
                try:
                    if op is not None and op.state in ("restarted", "replay"):
                        self._recover_op(op)
                    self._rq.pop(0)
                except SimulatedCrash:
                    on_crash(self.pipeline.groups[oid])
                continue
            progressed = False
            for g in groups:
                if self.group_state.get(g) in ("dead", "removed"):
                    continue
                crashed = False
                for op_id in self.group_ops(g):
                    op = self.ops.get(op_id)
                    if op is None:
                        continue
                    try:
                        if op.state in ("restarted", "replay"):
                            self._recover_op(op)
                            progressed = True
                        progressed |= self._step_op(op)
                    except SimulatedCrash:
                        on_crash(g)
                        progressed = True
                        crashed = True
                        break
                if crashed:
                    break

            def drain_all(force: bool) -> bool:
                any_released = False
                for rt in list(self.runtimes.values()):
                    try:
                        any_released |= rt.drain_durable(force=force)
                    except SimulatedCrash:
                        on_crash(self.pipeline.groups[rt.op.id])
                        any_released = True
                return any_released

            progressed |= drain_all(force=False)
            self.store.maybe_checkpoint()
            if not progressed:
                # push the durability watermark before concluding idleness
                if drain_all(force=True):
                    continue
                if self._sources_exhausted() and self._all_idle():
                    return True
                return self._done.is_set()
        return False
