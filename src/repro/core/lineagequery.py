"""Queryable lineage: the typed query facade over any LogBackend (Sec. 7.3).

The log's EVENT_LINEAGE x EVENT_LOG join is exposed as a product feature —
audit ("which inputs produced this output?"), debugging, and selective
reprocessing — instead of only the recovery mechanism's private read path:

  * :class:`EventKey` — the typed event identity ``(op, port, ssn)``
    replacing bare 3-tuples at the API boundary (tuples still accepted,
    coerced with loud ``ValueError`` on malformed input);
  * :class:`LineageQuery` — ``backward`` / ``forward`` / ``slice`` walks
    with scan-time filtering (:class:`~repro.core.logstore.base.
    LineageFilter` predicates pushed into the store layer when the backend
    advertises ``supports_query_pushdown``) and bounded results (``limit``
    + an explicit ``truncated`` flag, never silently unbounded lists);
  * :class:`LineageSlice` — the minimal upstream event set and operator
    sub-DAG that rederives chosen outputs: the input of replay-from-lineage
    (``Engine.replay``).

Pushdown never changes an answer: the facade re-applies the exact predicate
client-side, so a backend is free to return a superset restricted by
whatever it evaluated natively.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Set, Tuple, Union

from repro.core.logstore.base import LineageFilter, LogBackend

KeyLike = Union["EventKey", Tuple[str, str, int]]


@dataclasses.dataclass(frozen=True, order=True)
class EventKey:
    """Typed identity of a logged event: sender operator, output port, and
    send sequence number (the paper's (Sender_ID, Send_Port, SSN))."""

    op: str
    port: str
    ssn: int

    def __post_init__(self):
        if not isinstance(self.op, str) or not self.op:
            raise ValueError(
                f"EventKey.op must be a non-empty operator id string "
                f"(got {self.op!r})")
        if not isinstance(self.port, str) or not self.port:
            raise ValueError(
                f"EventKey.port must be a non-empty port name string "
                f"(got {self.port!r})")
        if not isinstance(self.ssn, int) or isinstance(self.ssn, bool) \
                or self.ssn < 0:
            raise ValueError(
                f"EventKey.ssn must be a non-negative int (got {self.ssn!r})")

    @classmethod
    def coerce(cls, key: KeyLike) -> "EventKey":
        """Accept an EventKey or a raw ``(op, port, ssn)`` tuple/list."""
        if isinstance(key, cls):
            return key
        if isinstance(key, (tuple, list)):
            if len(key) != 3:
                raise ValueError(
                    f"event key must be (op, port, ssn), got "
                    f"{len(key)}-tuple {key!r}")
            return cls(key[0], key[1], key[2])
        raise ValueError(
            f"event key must be an EventKey or (op, port, ssn) tuple, "
            f"got {type(key).__name__}: {key!r}")

    def astuple(self) -> Tuple[str, str, int]:
        return (self.op, self.port, self.ssn)


@dataclasses.dataclass(frozen=True)
class LineageResult:
    """Events found by a backward/forward walk, in discovery (BFS) order.
    ``truncated`` is True when ``limit`` cut the result or ``depth`` ran
    out with the frontier still live — the walk may not be exhaustive."""

    events: Tuple[EventKey, ...]
    truncated: bool = False

    def __iter__(self):
        return iter(self.events)

    def __len__(self):
        return len(self.events)

    def keys(self) -> List[Tuple[str, str, int]]:
        return [e.astuple() for e in self.events]


@dataclasses.dataclass(frozen=True)
class LineageSlice:
    """The minimal sub-DAG that rederives ``targets`` from the log.

    ``events`` is the full contributing closure (targets included);
    ``sources`` are the events with no recorded lineage inputs — replay
    must materialize their payloads from EVENT_DATA and inject them;
    ``ops`` are the operators that must re-execute (producers of derivable
    events); ``edges`` are operator-level flows ``(src_op, src_port,
    dst_op)`` restricted to the slice."""

    targets: Tuple[EventKey, ...]
    events: Tuple[EventKey, ...]
    sources: Tuple[EventKey, ...]
    ops: frozenset
    edges: frozenset
    truncated: bool = False


class LineageQuery:
    """Backward/forward/slice lineage queries over one :class:`LogBackend`.

    ``pushdown=None`` (default) auto-detects via the backend's
    ``supports_query_pushdown``; ``False`` forces the legacy full-scan ops
    with client-side filtering (the benchmark's baseline arm); ``True``
    requires the filtered ops (every backend answers them — the base class
    falls back to client-side filtering internally).
    """

    def __init__(self, store: LogBackend, *, pushdown: Optional[bool] = None):
        if not isinstance(store, LogBackend):
            raise ValueError(
                f"LineageQuery needs a LogBackend (got "
                f"{type(store).__name__})")
        self.store = store
        if pushdown is None:
            pushdown = bool(getattr(store, "supports_query_pushdown", False))
        self.pushdown = pushdown

    # ---- store access (pushdown vs legacy scan) --------------------------
    def _insets_of(self, key: EventKey, flt) -> List[str]:
        if self.pushdown:
            return self.store.query_lineage_insets(key.astuple(), flt)
        if flt is not None and not flt.matches(key.op, key.port, key.ssn):
            return []
        return self.store.lineage_insets_of(key.astuple())

    def _inset_events(self, rec_op: str, inset: str, flt) -> List[Tuple]:
        if self.pushdown:
            keys = self.store.query_inset_events(rec_op, inset, flt)
        else:
            keys = self.store.lineage_events_of_inset(rec_op, inset)
        if flt is not None:
            keys = [k for k in keys if flt.matches(k[0], k[1], k[2])]
        return keys

    def _inset_outputs(self, send_op: str, inset: str, flt) -> List[Tuple]:
        if self.pushdown:
            keys = self.store.query_inset_outputs(send_op, inset, flt)
        else:
            keys = self.store.lineage_outputs_of_inset(send_op, inset)
        if flt is not None:
            keys = [k for k in keys if flt.matches(k[0], k[1], k[2])]
        return keys

    def _event_insets(self, key: EventKey, rec_op: str, flt) -> List[str]:
        if self.pushdown:
            return self.store.query_event_insets(key.astuple(), rec_op, flt)
        if flt is not None and not flt.matches(key.op, key.port, key.ssn):
            return []
        return self.store.insets_of_event(key.astuple(), rec_op)

    def _consumers(self, key: EventKey, flt) -> List[str]:
        if self.pushdown:
            return self.store.query_consumers(key.astuple(), flt)
        recs = self.store.consumers_of(key.astuple())
        if flt is not None and flt.ops is not None:
            recs = [r for r in recs if r in flt.ops]
        return recs

    # ---- queries ---------------------------------------------------------
    @staticmethod
    def _check_args(depth: int, limit: Optional[int]):
        if not isinstance(depth, int) or depth < 1:
            raise ValueError(f"depth must be a positive int (got {depth!r})")
        if limit is not None and (not isinstance(limit, int) or limit < 0):
            raise ValueError(
                f"limit must be a non-negative int or None (got {limit!r})")

    def backward(self, key: KeyLike, *, where: Optional[LineageFilter] = None,
                 depth: int = 64, limit: Optional[int] = None
                 ) -> LineageResult:
        """Input events (transitively) used to produce ``key``, BFS order.
        ``where`` prunes the traversal: a non-matching input event is
        neither reported nor expanded."""
        self._check_args(depth, limit)
        key = EventKey.coerce(key)
        seen: Set[EventKey] = set()
        frontier = [key]
        found: List[EventKey] = []
        truncated = False
        for _ in range(depth):
            nxt: List[EventKey] = []
            for ev in frontier:
                # the root is expanded unfiltered: `where` scopes the
                # contributors, not the event being explained
                root_flt = None if ev is key else where
                for inset in self._insets_of(ev, root_flt):
                    for ik in self._inset_events(ev.op, inset, where):
                        ike = EventKey(*ik)
                        if ike in seen:
                            continue
                        if limit is not None and len(found) >= limit:
                            return LineageResult(tuple(found), True)
                        seen.add(ike)
                        found.append(ike)
                        nxt.append(ike)
            if not nxt:
                break
            frontier = nxt
        else:
            truncated = bool(frontier)
        return LineageResult(tuple(found), truncated)

    def forward(self, key: KeyLike, rec_op: str, *,
                where: Optional[LineageFilter] = None, depth: int = 64,
                limit: Optional[int] = None) -> LineageResult:
        """Output events (transitively) derived from ``key`` as consumed by
        ``rec_op``, BFS order."""
        self._check_args(depth, limit)
        if not isinstance(rec_op, str) or not rec_op:
            raise ValueError(
                f"rec_op must be a non-empty operator id (got {rec_op!r})")
        key = EventKey.coerce(key)
        seen: Set[EventKey] = set()
        found: List[EventKey] = []
        frontier: List[Tuple[EventKey, str]] = [(key, rec_op)]
        truncated = False
        for _ in range(depth):
            nxt: List[Tuple[EventKey, str]] = []
            for ev, op in frontier:
                for inset in self._event_insets(ev, op, None):
                    for ok in self._inset_outputs(op, inset, where):
                        oke = EventKey(*ok)
                        if oke in seen:
                            continue
                        if limit is not None and len(found) >= limit:
                            return LineageResult(tuple(found), True)
                        seen.add(oke)
                        found.append(oke)
                        for consumer in self._consumers(oke, None):
                            if consumer != op:
                                nxt.append((oke, consumer))
            if not nxt:
                break
            frontier = nxt
        else:
            truncated = bool(frontier)
        return LineageResult(tuple(found), truncated)

    def slice(self, keys: Union[KeyLike, Sequence[KeyLike]], *,
              where: Optional[LineageFilter] = None, depth: int = 64,
              limit: Optional[int] = None,
              cut: Optional[Sequence[str]] = None) -> LineageSlice:
        """Minimal upstream closure + operator sub-DAG rederiving ``keys``.

        Walks backward from every target simultaneously (shared seen-set),
        recording which operators produced derivable events and the
        operator-level edges the data flowed over — exactly what
        ``Engine.replay`` re-executes. Events with no recorded lineage
        inputs are the slice's ``sources``: their payloads come from
        EVENT_DATA, everything downstream is recomputed. ``cut`` names
        operators whose events are forced into ``sources`` (not expanded
        further) — the replay-scope boundary: replay injects their logged
        payloads instead of re-deriving them."""
        self._check_args(depth, limit)
        cut_ops = frozenset(cut) if cut is not None else frozenset()
        if isinstance(keys, (EventKey, tuple, list)) and (
                isinstance(keys, EventKey)
                or (len(keys) == 3 and isinstance(keys[0], str))):
            keys = [keys]
        targets = tuple(EventKey.coerce(k) for k in keys)
        if not targets:
            raise ValueError("slice() needs at least one target event key")
        seen: Set[EventKey] = set(targets)
        events: List[EventKey] = list(targets)
        sources: List[EventKey] = []
        ops: Set[str] = set()
        edges: Set[Tuple[str, str, str]] = set()
        frontier = list(targets)
        truncated = False
        for _ in range(depth):
            nxt: List[EventKey] = []
            for ev in frontier:
                insets = () if ev.op in cut_ops else self._insets_of(ev, None)
                if not insets:
                    sources.append(ev)      # no lineage inputs: inject
                    continue
                ops.add(ev.op)              # derivable: op must re-execute
                for inset in insets:
                    for ik in self._inset_events(ev.op, inset, where):
                        ike = EventKey(*ik)
                        edges.add((ike.op, ike.port, ev.op))
                        if ike in seen:
                            continue
                        if limit is not None and len(events) >= limit:
                            truncated = True
                            continue
                        seen.add(ike)
                        events.append(ike)
                        nxt.append(ike)
            if not nxt:
                frontier = []
                break
            frontier = nxt
        truncated = truncated or bool(frontier)
        return LineageSlice(targets=targets, events=tuple(events),
                            sources=tuple(sources), ops=frozenset(ops),
                            edges=frozenset(edges), truncated=truncated)
