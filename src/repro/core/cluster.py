"""Node agents + the :class:`LocalCluster` harness (multi-host process mode).

A *node agent* is the per-machine half of a multi-host deployment: a tiny
process that connects to the supervisor's control hub (authkey-
authenticated TCP), receives picklable
:class:`~repro.core.transport.base.WorkerBootstrap` payloads, and launches
one **spawn-context** worker process per payload.  The workers it starts
share nothing with the supervisor: they rebuild their operators from the
bootstrap + the log, dial their RPC/transport connections back to the hub,
and (under the ``tcp`` transport) exchange events over brokered
``(host, port)`` channels.  The agent also reports worker exits and
executes kill requests — the supervisor cannot signal a pid on another
machine.

:class:`LocalCluster` runs N such agents as "virtual hosts" on localhost.
Everything a real cluster deployment would exercise — bootstrap-only
worker starts, AF_INET channel brokering, per-node SIGKILL, whole-node
death and warm node restart, placing new replicas on other nodes — runs
against genuinely non-shared-memory processes, just without the network
between them.  ``kill_node`` SIGKILLs the agent's entire process group
(each agent calls ``setpgrp`` at birth, so its workers share its pgid):
the closest local analogue of pulling a machine's plug.

A production deployment would replace ``LocalCluster`` with an agent per
machine started from the same ``_agent_main`` entrypoint (the control-hub
address + authkey are its only inputs); nothing in the engine or the
transports distinguishes the two.
"""
from __future__ import annotations

import atexit
import multiprocessing
import os
import signal
import threading
import time
from multiprocessing import connection as mpc
from typing import Dict, List, Optional, Sequence, Union


def _agent_main(name: str, control_addr, authkey: bytes):
    """Node-agent entrypoint (runs in its own spawn-context process).

    Protocol (over the control-hub connection):
      supervisor -> agent: ("spawn", WorkerBootstrap) | ("kill", pid)
                           | ("stop",)
      agent -> supervisor: ("node", name, pid)           on connect
                           ("spawned", group, token, pid) per launch
                           ("exit", group, token, pid)    per worker death

    Losing the control connection is treated as supervisor death: the
    agent SIGKILLs its whole process group (itself + every worker it
    started) so no orphan pipelines outlive their supervisor.
    """
    os.setpgrp()          # workers inherit the pgid: one killpg = node dies
    from repro.core.procmode import _worker_entry
    try:
        conn = mpc.Client(control_addr, authkey=authkey)
        conn.send(("node", name, os.getpid()))
    except (OSError, EOFError, multiprocessing.AuthenticationError):
        os._exit(1)
    ctx = multiprocessing.get_context("spawn")
    send_lock = threading.Lock()

    def send(msg):
        with send_lock:
            try:
                conn.send(msg)
            except (OSError, ValueError):
                pass

    def watch(proc, group, token):
        proc.join()
        send(("exit", group, token, proc.pid))

    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            break                              # supervisor gone
        kind = msg[0]
        if kind == "spawn":
            bootstrap = msg[1]
            proc = ctx.Process(target=_worker_entry, args=(bootstrap,),
                               daemon=True,
                               name=f"logio-{bootstrap.group}")
            proc.start()
            send(("spawned", bootstrap.group, bootstrap.incarnation,
                  proc.pid))
            threading.Thread(
                target=watch,
                args=(proc, bootstrap.group, bootstrap.incarnation),
                daemon=True).start()
        elif kind == "kill":
            try:
                os.kill(msg[1], signal.SIGKILL)
            except ProcessLookupError:
                pass
        elif kind == "stop":
            break
    # take the whole process group down (this process included): workers
    # were either stopped by the supervisor already or must not outlive
    # their node
    try:
        os.killpg(os.getpgrp(), signal.SIGKILL)
    except OSError:
        os._exit(0)


class LocalCluster:
    """N "virtual hosts" on localhost: one node agent each, every worker a
    spawn-context process rebuilt purely from its bootstrap payload + the
    log.  Pass to ``Engine(mode="process", cluster=..., placement=...)``;
    the engine's driver starts the agents against its control hub and
    stops them on ``engine.stop()``.

    ``kill_node`` is the failure injector for whole-node death (SIGKILL of
    the agent's process group); the driver detects the lost control
    connection, and the next warm restart of the node's groups brings the
    agent back up via ``ensure_node`` — other nodes keep processing
    throughout (the paper's non-blocking recovery, across node
    boundaries)."""

    def __init__(self, nodes: Union[int, Sequence[str]] = 2):
        if isinstance(nodes, int):
            self.names: List[str] = [f"node{i}" for i in range(nodes)]
        else:
            self.names = list(nodes)
        self._ctx = multiprocessing.get_context("spawn")
        self._agents: Dict[str, multiprocessing.process.BaseProcess] = {}
        self._lock = threading.Lock()
        self._control: Optional[tuple] = None

    # -- driver-facing lifecycle -------------------------------------------
    def start(self, control_addr, authkey: bytes):
        with self._lock:
            self._control = (control_addr, authkey)
        # agents are non-daemonic (they launch workers) and the
        # multiprocessing atexit hook JOINS non-daemonic children: if the
        # supervisor process ever exits without engine.stop(), kill the
        # agents first (atexit is LIFO — this runs before mp's join)
        atexit.register(self.stop)
        for name in self.names:
            self.ensure_node(name)

    def ensure_node(self, name: str):
        """Start (or warm-restart) the node's agent if it is not running.
        Idempotent and thread-safe; the caller waits for the agent's
        control-hub hello, not for this method."""
        with self._lock:
            if self._control is None:
                raise RuntimeError("cluster not started by an engine yet")
            agent = self._agents.get(name)
            if agent is not None and agent.is_alive():
                return
            # agents must NOT be daemonic: daemonic processes cannot have
            # children, and launching workers is their whole job
            agent = self._ctx.Process(
                target=_agent_main,
                args=(name, self._control[0], self._control[1]),
                daemon=False, name=f"logio-node-{name}")
            agent.start()
            self._agents[name] = agent
            if name not in self.names:
                self.names.append(name)

    def stop(self):
        with self._lock:
            agents = dict(self._agents)
        for agent in agents.values():
            self._killpg(agent)
        for agent in agents.values():
            agent.join(timeout=5.0)

    # -- failure injection -------------------------------------------------
    def kill_node(self, name: str):
        """SIGKILL the node: agent + every worker it launched, no cleanup
        — the local analogue of a machine losing power."""
        with self._lock:
            agent = self._agents.get(name)
        if agent is not None:
            self._killpg(agent)
            agent.join(timeout=5.0)

    @staticmethod
    def _killpg(agent):
        if agent.pid is None:
            return
        try:
            # the agent called setpgrp, so its pid is the group's pgid
            os.killpg(agent.pid, signal.SIGKILL)
        except (ProcessLookupError, PermissionError, OSError):
            try:
                agent.kill()
            except (ValueError, OSError):
                pass

    # -- introspection -----------------------------------------------------
    def alive_nodes(self) -> List[str]:
        with self._lock:
            return sorted(n for n, a in self._agents.items()
                          if a.is_alive())

    def wait_node_dead(self, name: str, timeout: float = 10.0) -> bool:
        deadline = time.time() + timeout
        while time.time() < deadline:
            with self._lock:
                agent = self._agents.get(name)
            if agent is None or not agent.is_alive():
                return True
            time.sleep(0.01)
        return False
