"""Replay-from-lineage: re-execute the minimal sub-DAG deriving chosen
outputs (time-travel debugging; cf. Bauplan/Nessie replayable pipelines).

``Engine.replay(outputs, scope)`` delegates here. The flow:

  1. ``LineageQuery.slice`` walks EVENT_LINEAGE backward from the targets —
     the contributing event closure, its source events (no recorded lineage
     inputs, or produced by the scope's start operator), and the operator
     sub-DAG between them.
  2. Source payloads are materialized from EVENT_DATA. While the replay
     handle is live the slice's producer operators are added to the store's
     ``gc_protect`` registry, so a checkpoint compaction racing the replay
     cannot drop the payloads out from under it.
  3. A derived sub-pipeline is built: one injector source per (source port
     -> consumer) edge carrying exactly the events that consumer originally
     drew from that edge (per-edge injection keeps count-based InSet
     assignment aligned with the original run), the original factories for
     the slice operators, and one collector sink per target port.
  4. The sub-pipeline runs on a fresh in-memory store — thread mode or
     ``mode="process"`` (real SIGKILL injection works during replay; the
     replay run is itself recoverable).
  5. Rederived target outputs are matched positionally against the slice
     and compared byte-for-byte (``pickle.dumps``) with the logged
     payloads. Deterministic slices must reproduce exactly
     (:class:`ReplayMismatch` otherwise); non-deterministic slices are
     checked for lineage consistency only (every target rederived).

Exactness caveat: partial replay re-derives, per producer port, exactly the
slice's events. When a *re-executed* operator's port fans out to consumers
that originally drew different event subsets from it, no single re-derived
stream can serve both — that topology raises ``ValueError`` (fan-out ports
at the slice's *source* boundary are fine: sources are injected per edge).
"""
from __future__ import annotations

import dataclasses
import pickle
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

from repro.core.builtin import GeneratorSource, TerminalSink
from repro.core.lineage import LineageScope
from repro.core.lineagequery import EventKey, LineageQuery, LineageSlice
from repro.core.logstore import MemoryLogStore
from repro.core.operator import ExternalSystem, ReadSource

_MISSING = object()


class ReplayMismatch(ValueError):
    """A deterministic slice failed to rederive a target byte-identically."""


@dataclasses.dataclass
class ReplayReport:
    """Outcome of one ``Engine.replay`` call."""

    targets: Tuple[EventKey, ...]
    slice: LineageSlice
    rederived: Dict[EventKey, Any]          # target -> replayed body
    matches: Dict[EventKey, Optional[bool]]  # vs logged payload (None =
    #                                          original payload unavailable)
    executed_ops: frozenset                  # operators that re-executed
    deterministic: bool
    completed: bool

    @property
    def ok(self) -> bool:
        if not self.completed:
            return False
        if self.deterministic:
            return all(m is not False for m in self.matches.values())
        return all(t in self.rederived for t in self.targets)


def _injector_id(s: str, sp: str, d: str) -> str:
    return f"__replay__{s}.{sp}->{d}"


def _collector_id(op: str, port: str) -> str:
    return f"__replay_sink__{op}.{port}"


def replay_from_log(engine, outputs, *, scope: Optional[LineageScope] = None,
                    mode: Optional[str] = None, depth: int = 64,
                    timeout: float = 60.0, injector=None,
                    check: bool = True) -> ReplayReport:
    """See :meth:`repro.core.engine.Engine.replay`."""
    from repro.core.engine import Engine, Pipeline   # circular at import time

    store = engine.store
    pipeline = engine.pipeline
    if isinstance(outputs, (EventKey, tuple)) and (
            isinstance(outputs, EventKey)
            or (len(outputs) == 3 and isinstance(outputs[0], str))):
        outputs = [outputs]
    targets = [EventKey.coerce(k) for k in outputs]
    if scope is not None and not isinstance(scope, LineageScope):
        raise ValueError(
            f"scope must be a LineageScope (got {type(scope).__name__})")
    cut = [scope.start[0]] if scope is not None else None

    q = LineageQuery(store)
    sl = q.slice(targets, depth=depth, cut=cut)
    if sl.truncated:
        raise ValueError(
            f"lineage slice for {targets} is truncated at depth={depth}; "
            "raise depth= to capture the full upstream closure")
    if not sl.ops:
        raise ValueError(
            f"targets {targets} have no recorded lineage — nothing to "
            "re-execute (was lineage capture enabled for their scope?)")
    src_set = set(sl.sources)
    for t in sl.targets:
        if t in src_set:
            raise ValueError(
                f"target {t} has no recorded lineage inputs; it can only "
                "be read back from EVENT_DATA, not rederived")

    # while the replay is live, compaction must not GC any payload the
    # slice references (sources feed injection; the rest feed verification)
    prev_protect = store.gc_protect
    store.set_gc_protect(prev_protect | {e.op for e in sl.events})
    try:
        # ---- materialize payloads from the log -------------------------
        payloads: Dict[EventKey, Any] = {}
        for e in sl.events:
            p = store.get_event_payload(e.astuple())
            if p is not None:
                payloads[e] = p[1]
            elif e in src_set:
                raise ValueError(
                    f"payload of slice source {e} is no longer in "
                    "EVENT_DATA (GC'd?) — cannot inject it for replay; "
                    "register its operator in Engine(replay_ops=...) or "
                    "gc_protect to keep replay sources materializable")

        # ---- per-consumer consumed-event sets (from EVENT_LINEAGE) -----
        derivable = [e for e in sl.events if e not in src_set]
        consumed: Dict[str, set] = {}
        for e in derivable:
            acc = consumed.setdefault(e.op, set())
            for inset in q._insets_of(e, None):
                acc.update(EventKey(*k) for k in q._inset_events(e.op,
                                                                 inset, None))
        derived_on: Dict[Tuple[str, str], List[int]] = {}
        for e in derivable:
            derived_on.setdefault((e.op, e.port), []).append(e.ssn)
        for ssns in derived_on.values():
            ssns.sort()

        # ---- build the derived sub-pipeline ----------------------------
        rp = Pipeline()
        for op_id in sorted(sl.ops):
            rp.add(pipeline.factories[op_id])
        for (s, sp, d, dp, cap) in pipeline.connections:
            if d not in sl.ops:
                continue
            on_edge = sorted(e.ssn for e in consumed.get(d, ())
                             if (e.op, e.port) == (s, sp))
            if not on_edge:
                continue        # this input edge contributed nothing
            if s in sl.ops:
                if on_edge != derived_on.get((s, sp), []):
                    raise ValueError(
                        f"partial replay cannot align {s}.{sp} -> {d}: the "
                        f"slice re-derives events {derived_on.get((s, sp))} "
                        f"on {s}.{sp} but {d} originally consumed "
                        f"{on_edge}; a re-executed fan-out port must feed "
                        "every consumer the same event set")
                rp.connect(s, sp, d, dp, cap)
            else:
                inj = _injector_id(s, sp, d)
                bodies = [payloads[EventKey(s, sp, n)] for n in on_edge]
                rp.add(partial(GeneratorSource, inj, ReadSource(bodies),
                               conn_id="replay"))
                rp.connect(inj, "out", d, dp, cap)
        for (op, port) in sorted({(t.op, t.port) for t in sl.targets}):
            sink = _collector_id(op, port)
            rp.add(partial(TerminalSink, sink,
                           len(derived_on.get((op, port), ())),
                           record=True, conn_id="out"))
            rp.connect(op, port, sink, "in", 256)

        # ---- run it -----------------------------------------------------
        run_mode = mode or "thread"
        kw: Dict[str, Any] = {}
        if run_mode == "process":
            kw["transport"] = "routed"
            kw["ctx"] = engine.proc_ctx
        reng = Engine(rp, store=MemoryLogStore(), external=ExternalSystem(),
                      mode=run_mode, injector=injector, **kw)
        reng.start()
        completed = reng.wait(timeout)
        reng.stop()

        # ---- collect + verify -------------------------------------------
        rederived: Dict[EventKey, Any] = {}
        matches: Dict[EventKey, Optional[bool]] = {}
        for t in sl.targets:
            idx = derived_on[(t.op, t.port)].index(t.ssn)
            body = reng.external.writes.get(
                (_collector_id(t.op, t.port), "out", idx), _MISSING)
            if body is _MISSING:
                matches[t] = False
                continue
            rederived[t] = body
            orig = payloads.get(t, _MISSING)
            matches[t] = None if orig is _MISSING else \
                pickle.dumps(orig) == pickle.dumps(body)
        deterministic = all(
            getattr(engine.ops.get(op_id), "deterministic", True)
            for op_id in sl.ops)
        executed = frozenset(op for op, m in reng.metrics().ops.items()
                             if m.processed > 0
                             and not op.startswith("__replay"))
        report = ReplayReport(targets=sl.targets, slice=sl,
                              rederived=rederived, matches=matches,
                              executed_ops=executed,
                              deterministic=deterministic,
                              completed=completed)
        if check:
            if not completed:
                raise ReplayMismatch(
                    f"replay run did not complete within {timeout}s "
                    f"(executed: {sorted(executed)})")
            missing = [t for t in sl.targets if t not in rederived]
            if missing:
                raise ReplayMismatch(
                    f"replay did not rederive targets {missing}")
            if deterministic:
                bad = [t for t, m in matches.items() if m is False]
                if bad:
                    raise ReplayMismatch(
                        f"deterministic slice rederived different bytes "
                        f"for {bad}")
        return report
    finally:
        store.set_gc_protect(prev_protect)
