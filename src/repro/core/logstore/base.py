"""Formal LOG.io log-backend interface (Sec. 3.2).

Five tables: EVENT_LOG, EVENT_DATA, READ_ACTION, STATE, EVENT_LINEAGE.

A backend owns the tables and exposes

  * ``begin()`` — a :class:`LogTransaction` buffering mutations; ``commit``
    applies them atomically (validation of conditional mutations before any
    mutation => the abort semantics the dynamic-scaling mutual exclusion of
    Algorithm 13 needs) and returns a *durability token*;
  * queries — the read paths of the recovery/lineage/scaling algorithms;
  * a durability watermark — ``is_durable(token)`` says whether a commit has
    reached the durable medium. Plain backends are durable at commit
    (token ``None``); a :class:`~repro.core.logstore.batched.GroupCommitStore`
    pipelines commits and advances the watermark at batch flushes. Consumers
    that release *externally visible* effects (channel acks, external-system
    writes) must gate them on ``is_durable`` — the durability-watermark rule.

Transaction ops are plain tuples (``(kind, *args)``) so they can be routed
between shards, buffered into batches, and persisted as a WAL verbatim.
"""
from __future__ import annotations

import abc
import dataclasses
import warnings
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.events import Event


@dataclasses.dataclass(frozen=True)
class LineageFilter:
    """Scan-time predicate for the filtered lineage query ops.

    ``ops``/``ports`` restrict results to those sender operators/output
    ports; ``ssn_min``/``ssn_max`` bound the event id (inclusive). A backend
    that opts into predicate pushdown (``supports_query_pushdown``) evaluates
    these *at the scan* — SQL WHERE, secondary indexes, sidecar-index segment
    skipping — instead of materializing every row. ``epoch_min``/``epoch_max``
    are *scan hints* for log-structured backends (they bound the flush epochs
    a durable scan must visit); memory-image backends ignore them, and
    ``matches`` does not evaluate them, so a hint can only skip I/O, never
    change results.

    Backends may return a superset restricted by whatever they can evaluate
    natively; :class:`~repro.core.lineagequery.LineageQuery` re-applies the
    exact predicate client-side, so pushdown is purely a performance contract.
    """

    ops: Optional[frozenset] = None
    ports: Optional[frozenset] = None
    ssn_min: Optional[int] = None
    ssn_max: Optional[int] = None
    epoch_min: Optional[int] = None
    epoch_max: Optional[int] = None

    def __post_init__(self):
        for name in ("ops", "ports"):
            val = getattr(self, name)
            if val is None:
                continue
            if isinstance(val, str):
                val = (val,)
            try:
                val = frozenset(val)
            except TypeError:
                raise ValueError(
                    f"LineageFilter.{name} must be an iterable of strings "
                    f"(got {getattr(self, name)!r})") from None
            if not all(isinstance(x, str) for x in val):
                raise ValueError(
                    f"LineageFilter.{name} entries must be strings "
                    f"(got {sorted(map(repr, val))})")
            object.__setattr__(self, name, val)
        for name in ("ssn_min", "ssn_max", "epoch_min", "epoch_max"):
            val = getattr(self, name)
            if val is not None and not isinstance(val, int):
                raise ValueError(f"LineageFilter.{name} must be an int "
                                 f"(got {val!r})")
        if self.ssn_min is not None and self.ssn_max is not None \
                and self.ssn_min > self.ssn_max:
            raise ValueError(
                f"LineageFilter ssn range is empty "
                f"({self.ssn_min} > {self.ssn_max})")

    def matches(self, op: str, port: Optional[str], ssn: int) -> bool:
        """Exact (client-side) evaluation — epoch hints intentionally
        excluded: they narrow scans, never membership."""
        if self.ops is not None and op not in self.ops:
            return False
        if self.ports is not None and port not in self.ports:
            return False
        if self.ssn_min is not None and ssn < self.ssn_min:
            return False
        if self.ssn_max is not None and ssn > self.ssn_max:
            return False
        return True


class TxnAborted(Exception):
    """Raised at commit when a conditional mutation fails (e.g. marking a
    non-existent InSet done — the dynamic-scaling mutual exclusion of
    Algorithm 13)."""


class LogTransaction:
    """Buffered mutation set against one backend. Mutations are recorded as
    op tuples; nothing is visible before ``commit``."""

    def __init__(self, store: "LogBackend"):
        self.store = store
        self.ops: List[Tuple] = []

    # -- mutations (buffered) ---------------------------------------------
    def log_event(self, ev: Event, status: str,
                  inset_id: Optional[str] = None):
        if ev.cached_blob() is not None:
            # the payload travels as a put_event_blob op: carrying the body
            # here too would double-ship it through store RPC and WAL
            # pickles (EVENT_LOG rows only ever read the routing fields)
            ev = dataclasses.replace(ev, body=None, header=dict(ev.header))
        self.ops.append(("log_event", ev, status, inset_id))

    def log_events(self, entries: Iterable[Tuple]):
        """Vectored ``log_event``: one op carrying a *run* of events. Each
        entry is ``(event, status, inset_id)`` and every row stays
        individually keyed in EVENT_LOG — a crash mid-run replays exactly
        the unlogged suffix, never a whole batch. Backends apply the run
        under one lock acquisition / one durable append."""
        recs = []
        for ev, status, inset_id in entries:
            if ev.cached_blob() is not None:
                ev = dataclasses.replace(ev, body=None,
                                         header=dict(ev.header))
            recs.append((ev, status, inset_id))
        self.ops.append(("log_events", recs))

    def put_event_data(self, ev: Event):
        blob = ev.cached_blob()
        if blob is not None:
            # zero-copy path: the transport's wire payload doubles as the
            # EVENT_DATA blob — one encode per event, shared end to end.
            # op[2] is the row's home operator (the sharded router's key).
            home = ev.rec_op if ev.rec_op is not None else ev.send_op
            self.ops.append(("put_event_blob", ev.key(), home, blob))
        else:
            self.ops.append(("put_event_data", ev))

    def delete_event_data(self, key):
        self.ops.append(("delete_event_data", key))

    def set_status(self, key, status: str, inset_id: Optional[str] = "*",
                   rec_op: Optional[str] = None,
                   only_status: Optional[str] = None):
        """key = (send_op, send_port, event_id). rec_op filters to one
        receiver's rows; only_status makes the flip conditional."""
        self.ops.append(("set_status", key, status, inset_id, rec_op,
                         only_status))

    def set_status_many(self, entries: Iterable[Tuple]):
        """Vectored ``set_status``: one op flipping a run of individually
        keyed rows. Each entry is ``(key, status, inset_id, rec_op,
        only_status)`` — the same fields ``set_status`` takes."""
        self.ops.append(("set_status_many",
                         [(tuple(k), s, i, r, o)
                          for (k, s, i, r, o) in entries]))

    def assign_insets(self, key, inset_ids: List[str],
                      rec_op: Optional[str] = None):
        self.ops.append(("assign_insets", key, list(inset_ids), rec_op))

    def set_inset_status(self, rec_op: str, inset_id: str, status: str,
                         require_rows: bool = False):
        self.ops.append(("set_inset_status", rec_op, inset_id, status,
                         require_rows))

    def clear_inset(self, rec_op: str, inset_id: str):
        self.ops.append(("clear_inset", rec_op, inset_id))

    def put_state(self, op_id: str, state_id: int, blob: bytes,
                  keep_history: bool = False):
        self.ops.append(("put_state", op_id, state_id, blob, keep_history))

    def put_lineage(self, event_id: int, send_op: str, send_port: str,
                    inset_id: str):
        self.ops.append(("put_lineage", event_id, send_op, send_port,
                         inset_id))

    def put_read_action(self, op_id: str, conn_id: str, action_id: int,
                        status: str, desc: str):
        self.ops.append(("put_read_action", op_id, conn_id, action_id,
                         status, desc))

    def set_read_action_status(self, op_id: str, conn_id: str,
                               action_id: int, status: str):
        self.ops.append(("set_read_action_status", op_id, conn_id, action_id,
                         status))

    def delete_event_rows(self, key):
        self.ops.append(("delete_event_rows", key))

    def reassign_event(self, old_key, old_rec: Optional[str], new_key,
                       tgt_op: str, tgt_port: str):
        """Alg 13 step 1.c: move a still-undone event to a new destination
        (+ new event id); rows already done are skipped at apply time."""
        self.ops.append(("reassign_event", old_key, old_rec, new_key,
                         tgt_op, tgt_port))

    def commit(self):
        """Atomically apply the buffered ops. Returns a durability token
        (``None`` = durable now). Raises TxnAborted and applies nothing when
        a conditional mutation fails."""
        ops, self.ops = self.ops, []
        return self.store._commit(ops)


class LogBackend(abc.ABC):
    """Abstract log backend: the contract every protocol module (operator
    runtime, recovery, scaling, lineage, engine) programs against."""

    # ---- transactions ----------------------------------------------------
    def begin(self) -> LogTransaction:
        return LogTransaction(self)

    @abc.abstractmethod
    def _commit(self, ops: List[Tuple]):
        """Validate + apply one transaction's ops; return durability token."""

    # ---- durability watermark -------------------------------------------
    def is_durable(self, token) -> bool:
        """True once the commit identified by ``token`` is durable. Plain
        backends commit durably, so any token (incl. None) is durable."""
        return True

    def flush(self):
        """Force everything committed so far to the durable medium."""

    def maybe_flush(self):
        """Flush if a size/time watermark has been reached (group commit)."""

    def crash(self):
        """Simulate a full-process crash: committed-but-unflushed data is
        lost; the store image rolls back to the durable watermark."""

    def close(self):
        pass

    # ---- checkpoint / truncation (bounded-replay recovery) ---------------
    # A checkpointing backend periodically captures the full table image as
    # a *checkpoint record* inside the log store and truncates the log
    # records below that watermark, so a warm restart replays only
    # O(records-since-last-checkpoint) work instead of O(pipeline lifetime).
    # Backends without a durable log (memory) have nothing to truncate; the
    # defaults make checkpointing a no-op for them.

    #: True when ``checkpoint()`` actually truncates a durable log.
    supports_checkpoint: bool = False

    #: Senders whose EVENT_DATA payloads must survive checkpoint GC — the
    #: engine registers the predecessors of replay operators (and lineage-
    #: scoped producers) here: a replay flip can turn done inputs back
    #: into needed ones (Sec. 5), so their payloads are never final-done.
    gc_protect: frozenset = frozenset()

    def set_gc_protect(self, ops: Iterable[str]):
        self.gc_protect = frozenset(ops)

    def checkpoint(self):
        """Write a checkpoint record and truncate log records below it."""

    def checkpoint_due(self) -> bool:
        """True once enough records accumulated since the last checkpoint
        (the configured checkpoint interval) that ``checkpoint()`` should
        run."""
        return False

    def maybe_checkpoint(self):
        """Checkpoint iff the cadence watermark has been reached — the
        engine calls this from its supervision loops (cheap when not due)."""
        if self.checkpoint_due():
            self.checkpoint()

    def recovery_replay_count(self) -> int:
        """Log records replayed when this store (re)opened its durable
        image — the bounded-replay metric: with checkpoint interval K this
        stays O(K) regardless of pipeline lifetime."""
        return 0

    # ---- recovery queries -----------------------------------------------
    @abc.abstractmethod
    def fetch_resend_events(self, op_id: str) -> List[Tuple[Event, str]]:
        """Alg 7 step 1: undone, sender==op, InSet null, real output events."""

    @abc.abstractmethod
    def fetch_ack_events(self, op_id: str, include_done: bool = False
                         ) -> List[Tuple[Event, str, str]]:
        """Alg 9 step 2: undone, receiver==op, InSet assigned.

        ``include_done`` additionally returns DONE rows — needed when the
        receiver recovers from an epoch-mode (interval-snapshotted, hence
        possibly stale) state snapshot and must replay the global-state
        contributions of inputs it already completed."""

    @abc.abstractmethod
    def fetch_replay_outputs(self, op_id: str) -> List[Tuple[int, str, str]]:
        """Sender-side rows marked REPLAY by consumers (Alg 10 step 2)."""

    @abc.abstractmethod
    def undone_outputs_after(self, op_id: str, port: str, min_id: int
                             ) -> List[int]:
        """UNDONE outputs on a port with event_id >= min_id (Alg 10)."""

    @abc.abstractmethod
    def get_write_actions(self, op_id: str) -> List[Event]:
        """Alg 8: undone events with null sender port for op."""

    @abc.abstractmethod
    def get_state(self, op_id: str) -> Optional[bytes]:
        """Latest STATE blob for op."""

    @abc.abstractmethod
    def last_sent_ssn(self, op_id: str) -> Dict[str, int]:
        """max event_id per output port (Alg 9 step 1)."""

    @abc.abstractmethod
    def last_acked(self, op_id: str) -> Dict[str, int]:
        """max event_id per input port with an assigned InSet."""

    @abc.abstractmethod
    def event_status(self, key, rec_op: Optional[str] = None
                     ) -> List[Tuple[Optional[str], str]]:
        """[(inset_id, status)] of EVENT_LOG rows for one event key."""

    @abc.abstractmethod
    def get_read_action(self, op_id: str, conn_id: str):
        """Latest read action for (op, conn): (action_id, row) or (None, None)."""

    # ---- scaling queries (Alg 13) ---------------------------------------
    @abc.abstractmethod
    def undone_events_from(self, send_op: str, rec_op: str) -> List[Tuple]:
        """Keys (send_op, send_port, event_id) of UNDONE rows from send_op
        to rec_op, ordered by event_id (the set O of Alg 13 step 1.b)."""

    # ---- lineage queries (Sec. 7.3) -------------------------------------
    @abc.abstractmethod
    def lineage_insets_of(self, event_key) -> List[str]:
        """InSet_IDs that produced an output event (EVENT_LINEAGE)."""

    @abc.abstractmethod
    def lineage_events_of_inset(self, rec_op: str, inset_id: str
                                ) -> List[Tuple]:
        """Input event keys assigned to an Input Set."""

    @abc.abstractmethod
    def lineage_outputs_of_inset(self, send_op: str, inset_id: str
                                 ) -> List[Tuple]:
        """Output event keys produced from an Input Set."""

    @abc.abstractmethod
    def insets_of_event(self, event_key, rec_op: str) -> List[str]:
        """InSet_IDs an input event joined at one receiver."""

    @abc.abstractmethod
    def consumers_of(self, event_key) -> List[str]:
        """Receiver operator ids holding EVENT_LOG rows for an event."""

    # ---- filtered lineage queries (predicate pushdown) -------------------
    # Optional fast paths for the LineageQuery facade. The defaults delegate
    # to the unfiltered ops above and filter client-side, so every backend
    # answers correctly; backends that can evaluate a LineageFilter at the
    # scan (SQL WHERE, secondary indexes, segment sidecar skipping) override
    # these and advertise it via ``supports_query_pushdown``. Results may be
    # a superset restricted by whatever the backend evaluated natively —
    # LineageQuery re-applies the exact predicate, so pushdown only ever
    # changes how much data the scan touches, never the answer.

    #: True when the filtered query ops evaluate predicates at the scan
    #: rather than via the client-side fallback below.
    supports_query_pushdown: bool = False

    def query_lineage_insets(self, event_key,
                             flt: Optional[LineageFilter] = None
                             ) -> List[str]:
        """InSet_IDs that produced an output event (filtered variant of
        ``lineage_insets_of``; the filter applies to the *output* key)."""
        if flt is not None and not flt.matches(event_key[0], event_key[1],
                                               event_key[2]):
            return []
        return self.lineage_insets_of(event_key)

    def query_inset_events(self, rec_op: str, inset_id: str,
                           flt: Optional[LineageFilter] = None
                           ) -> List[Tuple]:
        """Input event keys of one Input Set, filtered on the *sender* side
        of each key (filtered ``lineage_events_of_inset``)."""
        keys = self.lineage_events_of_inset(rec_op, inset_id)
        if flt is None:
            return keys
        return [k for k in keys if flt.matches(k[0], k[1], k[2])]

    def query_inset_outputs(self, send_op: str, inset_id: str,
                            flt: Optional[LineageFilter] = None
                            ) -> List[Tuple]:
        """Output event keys produced from an Input Set (filtered
        ``lineage_outputs_of_inset``)."""
        keys = self.lineage_outputs_of_inset(send_op, inset_id)
        if flt is None:
            return keys
        return [k for k in keys if flt.matches(k[0], k[1], k[2])]

    def query_event_insets(self, event_key, rec_op: str,
                           flt: Optional[LineageFilter] = None
                           ) -> List[str]:
        """InSet_IDs an input event joined at one receiver (filtered
        ``insets_of_event``; the filter applies to the input key)."""
        if flt is not None and not flt.matches(event_key[0], event_key[1],
                                               event_key[2]):
            return []
        return self.insets_of_event(event_key, rec_op)

    def query_consumers(self, event_key,
                        flt: Optional[LineageFilter] = None) -> List[str]:
        """Receiver ids holding rows for an event; ``flt.ops`` restricts the
        receivers considered (filtered ``consumers_of``)."""
        recs = self.consumers_of(event_key)
        if flt is not None and flt.ops is not None:
            recs = [r for r in recs if r in flt.ops]
        return recs

    def query_lineage(self, flt: Optional[LineageFilter] = None
                      ) -> List[Tuple]:
        """Bulk audit scan: all EVENT_LINEAGE rows matching ``flt`` as
        ``(send_op, send_port, event_id, inset_id)`` tuples. Only backends
        holding the lineage table natively implement this."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support bulk lineage scans")

    def get_event_payload(self, event_key) -> Optional[Tuple[Dict, Any]]:
        """EVENT_DATA payload for a key as ``(header, body)``, or None when
        the payload was GC'd or never stored — the replay-from-lineage
        materialization read. Backends without payload access return None."""
        return None

    # ---- query instrumentation ------------------------------------------
    def query_stats(self) -> Dict[str, int]:
        """Deprecated public accessor — the typed metrics plane
        (``Engine.metrics().store``) is the supported surface; backends
        implement ``_query_stats``."""
        warnings.warn(
            "LogBackend.query_stats() is deprecated; read "
            "Engine.metrics().store (repro.core.metrics.StoreMetrics) "
            "instead", DeprecationWarning, stacklevel=2)
        return self._query_stats()

    def _query_stats(self) -> Dict[str, int]:
        """Scan-effort counters for the lineage query paths (rows_scanned /
        rows_returned, plus backend-specific keys such as segment skip
        counts). Purely diagnostic — the pushdown benchmark and tests assert
        on these; backends without instrumentation return {}."""
        return {}

    def reset_query_stats(self):
        """Zero the ``query_stats`` counters."""

    # ---- GC (Sec. 3.6) ---------------------------------------------------
    @abc.abstractmethod
    def gc(self, lineage_ops: Iterable[str] = ()):
        """Drop payloads (and, without lineage, rows) of done events."""
