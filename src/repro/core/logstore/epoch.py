"""Global flush epochs: lightweight 2PC for multi-shard group commits.

The sharded+group stack used to flush behind a coordinated barrier (every
shard lock held while every shard did its I/O) so that a multi-shard
transaction could never end up half-durable. That serializes all commits
against the flush I/O and cannot extend across process boundaries. The
epoch protocol replaces it:

  1. the flush coordinator assigns a fresh **epoch id** and, under a brief
     exclusive *epoch barrier* (no I/O — just list swaps), cuts every
     shard's pending batch.  Commits hold the barrier shared, so no
     transaction can straddle the cut: each txn is entirely inside or
     entirely after the epoch.
  2. **prepare**: each shard persists its cut batch tagged with the epoch
     id (for a SQLite shard: one SQLite transaction inserting the WAL rows
     with an ``epoch`` column).  Prepared rows are durable but
     *conditional* — they count only if the epoch commits.
  3. **commit point**: one durable epoch-commit record is written by the
     :class:`EpochCoordinator`.  This single write makes the whole
     multi-shard flush atomic.
  4. each shard advances its durability watermark past the epoch's tokens.

On restart (or simulated ``crash()``), prepared-but-uncommitted epochs are
rolled back — shards discard WAL rows whose epoch has no commit record —
so a crash anywhere in the protocol leaves no multi-shard transaction
half-durable, and flush I/O runs without holding any shard lock.
"""
from __future__ import annotations

import contextlib
import sqlite3
import threading
from typing import Optional, Set


class ReadWriteLock:
    """Writer-preferring RW lock. Commits hold it shared (many at once);
    the epoch cut phase holds it exclusive — but only for list swaps, never
    for I/O, so the exclusive window is tiny."""

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    def acquire_read(self):
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self):
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()

    def acquire_write(self):
        with self._cond:
            self._writers_waiting += 1
            try:
                while self._writer or self._readers:
                    self._cond.wait()
            finally:
                self._writers_waiting -= 1
            self._writer = True

    def release_write(self):
        with self._cond:
            self._writer = False
            self._cond.notify_all()

    @contextlib.contextmanager
    def read(self):
        self.acquire_read()
        try:
            yield
        finally:
            self.release_read()

    @contextlib.contextmanager
    def write(self):
        self.acquire_write()
        try:
            yield
        finally:
            self.release_write()


class EpochCoordinator:
    """In-memory epoch coordinator: the committed-epoch set *is* the
    durable epoch-commit record (it survives ``crash()`` by construction,
    mirroring how the memory group-commit store simulates its durable
    medium with the flushed-op history)."""

    def __init__(self):
        self.lock = threading.Lock()
        self._next = 1
        self._committed: Set[int] = set()

    def next_epoch(self) -> int:
        with self.lock:
            eid = self._next
            self._next += 1
            return eid

    def commit_epoch(self, epoch_id: int):
        """The commit point: one durable record makes the epoch atomic."""
        with self.lock:
            self._committed.add(epoch_id)

    def is_committed(self, epoch_id: int) -> bool:
        with self.lock:
            return epoch_id in self._committed

    def crash(self):
        """Commit records are durable; assigned-but-uncommitted epoch ids
        are simply never committed (their prepared batches roll back)."""

    def close(self):
        pass


class SqliteEpochCoordinator(EpochCoordinator):
    """Durable coordinator: epoch-commit records live in their own SQLite
    file next to the shard files. ``commit_epoch`` is one INSERT+COMMIT —
    the single durable write of the protocol's commit point."""

    def __init__(self, path: str):
        super().__init__()
        self.path = path
        self.conn = sqlite3.connect(path, check_same_thread=False)
        self.conn.execute("PRAGMA journal_mode=WAL")
        self.conn.execute(
            "CREATE TABLE IF NOT EXISTS epochs (epoch_id INTEGER PRIMARY KEY)")
        self.conn.commit()
        rows = self.conn.execute("SELECT epoch_id FROM epochs").fetchall()
        self._committed = {r[0] for r in rows}
        self._next = max(self._committed, default=0) + 1

    def commit_epoch(self, epoch_id: int):
        with self.lock:
            self.conn.execute(
                "INSERT OR IGNORE INTO epochs (epoch_id) VALUES (?)",
                (epoch_id,))
            self.conn.commit()
            self._committed.add(epoch_id)

    def crash(self):
        """Simulated process crash: reload the committed set from disk (it
        is durable; uncommitted ids vanish with the process)."""
        with self.lock:
            self.conn.close()
            self.conn = sqlite3.connect(self.path, check_same_thread=False)
            rows = self.conn.execute("SELECT epoch_id FROM epochs").fetchall()
            self._committed = {r[0] for r in rows}
            self._next = max(self._committed, default=0) + 1

    def close(self):
        self.conn.close()


def make_coordinator(base: str, path: Optional[str] = None) -> EpochCoordinator:
    """Coordinator matching a ``build_store`` base: durable (sqlite /
    segment) bases get a durable commit record; memory bases get the
    simulated one."""
    if base in ("sqlite", "segment"):
        if path is None:
            raise ValueError(f"{base} epoch coordinator needs a path")
        return SqliteEpochCoordinator(path)
    return EpochCoordinator()
