"""Sharded log store: partitions the five tables by operator id.

Every row has a *home operator* — the receiver for EVENT_LOG/EVENT_DATA
rows (write-action and read-action events fall back to the sender, which
equals the receiver or is the only party), the owning operator for
STATE/READ_ACTION rows, and the producing operator for EVENT_LINEAGE rows.
Rows live in ``shard(home) = crc32(home) % n_shards``, each shard a full
backend with its own lock, so the per-event transactions of unrelated
operators never contend on one global lock (the storage-layer analogue of
the paper's "parallelization reduces LOG.io overhead" claim, Sec. 9).

A transaction may span shards (an Output-Set transaction touches the
operator's own shard for STATE/InSet flips and the consumers' shards for
the new EVENT_LOG rows). Commit acquires the involved shard locks in index
order (deadlock-free), validates conditional ops against the union image,
then applies each shard's slice — atomicity is preserved because all locks
are held across validation and application. ``reassign_event`` (Alg 13) is
decomposed into per-shard micro-ops so the delete (old replica's shard) and
the insert (new target's shard) land in their home shards.

Shards compose: ``ShardedLogStore(factory=lambda i: GroupCommitStore(...))``
gives per-shard group commit; durability tokens become ``{shard: seq}`` maps
and ``is_durable`` requires every involved shard to have flushed. Flushes of
group-commit shards run the **global flush epoch protocol** (lightweight
2PC, ``logstore/epoch.py``): a brief exclusive epoch barrier cuts every
shard's pending batch (list swaps only), each shard then *prepares* — it
persists its batch tagged with the epoch id, outside all shard locks — and
a single durable epoch-commit record makes the multi-shard flush atomic.
Prepared-but-uncommitted epochs roll back on restart, so no multi-shard
transaction is ever half-durable and flush I/O never blocks commits.
"""
from __future__ import annotations

import threading
import time
import zlib
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.core.logstore.base import LogBackend, TxnAborted
from repro.core.logstore.epoch import EpochCoordinator, ReadWriteLock
from repro.core.logstore.memory import MemoryLogStore

BROADCAST = None


class ShardedLogStore(LogBackend):

    def __init__(self, n_shards: int = 4,
                 factory: Optional[Callable[[int], LogBackend]] = None,
                 epoch_coord: Optional[EpochCoordinator] = None):
        factory = factory or (lambda i: MemoryLogStore())
        self.n_shards = n_shards
        self.shards: List[LogBackend] = [factory(i) for i in range(n_shards)]
        self._group_shards = [s for s in self.shards
                              if hasattr(s, "cut_pending")]
        if self._group_shards and epoch_coord is None:
            # durable shard media need a durable epoch-commit record: a
            # volatile default coordinator would let prepared-but-
            # uncommitted epochs replay as durable after a real restart —
            # the half-durable outcome the protocol exists to prevent.
            # build_store wires the matching coordinator automatically.
            from repro.core.logstore.segment import SegmentLogStore
            from repro.core.logstore.sqlite import SqliteLogStore
            if any(isinstance(getattr(s, "inner", None),
                              (SqliteLogStore, SegmentLogStore))
                   for s in self._group_shards):
                raise ValueError(
                    "sharded store over durable group-commit shards needs "
                    "a durable epoch coordinator (pass epoch_coord=, or "
                    "assemble the stack via build_store)")
            epoch_coord = EpochCoordinator()
        if epoch_coord is not None:
            # propagate so every shard (and durable inner) consults the
            # same commit record at crash()/reopen time
            for s in self._group_shards:
                if getattr(s, "epoch_coord", None) is None:
                    s.epoch_coord = epoch_coord
                inner = getattr(s, "inner", None)
                if inner is not None and \
                        getattr(inner, "epoch_coord", "n/a") is None:
                    inner.epoch_coord = epoch_coord
        self.epoch_coord = epoch_coord
        # commits hold the barrier shared; the epoch cut holds it exclusive
        self._epoch_barrier = ReadWriteLock()
        self._flush_serial = threading.Lock()   # one epoch flush at a time
        self.epochs_flushed = 0
        # async epoch flushes: commits nudge, the flusher thread runs the
        # 2PC (cut under the barrier, prepare I/O outside all shard locks)
        # — operator threads never block on shard fsyncs
        self._flush_wake = threading.Event()
        self._flusher: Optional[threading.Thread] = None
        self._flusher_stop = False
        self._flusher_idle = False

    # ---- placement -------------------------------------------------------
    def _idx(self, op_id) -> int:
        return zlib.crc32(str(op_id).encode()) % self.n_shards

    def _shard(self, op_id) -> LogBackend:
        return self.shards[self._idx(op_id)]

    def _log_entry_home(self, entry) -> int:
        ev = entry[0]
        return self._idx(ev.rec_op if ev.rec_op is not None else ev.send_op)

    def _status_entry_home(self, entry) -> Optional[int]:
        key, _status, _inset, rec_op, _only = entry
        if rec_op is not None:
            return self._idx(rec_op)
        if key[1] is None:            # write action: receiver == sender
            return self._idx(key[0])
        return BROADCAST

    def _route(self, op) -> Optional[List[int]]:
        """Home shard indices for one op tuple; BROADCAST when the rows it
        touches cannot be located from the op alone (rare recovery paths)."""
        kind = op[0]
        if kind in ("log_event", "put_event_data"):
            ev = op[1]
            return [self._idx(ev.rec_op if ev.rec_op is not None
                              else ev.send_op)]
        if kind == "log_events":
            return sorted({self._log_entry_home(e) for e in op[1]})
        if kind == "set_status_many":
            homes = {self._status_entry_home(e) for e in op[1]}
            return BROADCAST if BROADCAST in homes else sorted(homes)
        if kind == "put_event_blob":
            return [self._idx(op[2])]           # pre-computed home operator
        if kind == "set_status":
            _, key, _status, _inset, rec_op, _only = op
            if rec_op is not None:
                return [self._idx(rec_op)]
            if key[1] is None:        # write action: receiver == sender
                return [self._idx(key[0])]
            return BROADCAST
        if kind == "assign_insets":
            rec = op[3]
            return [self._idx(rec)] if rec is not None else BROADCAST
        if kind in ("set_inset_status", "clear_inset"):
            return [self._idx(op[1])]
        if kind in ("put_state", "put_read_action", "set_read_action_status"):
            return [self._idx(op[1])]
        if kind == "put_lineage":
            return [self._idx(op[2])]           # send_op
        return BROADCAST    # delete_event_data / delete_event_rows / micro-ops

    # ---- commit ----------------------------------------------------------
    def _commit(self, ops):
        if not self._group_shards:
            # no epoch protocol in play (volatile/plain shards flush
            # synchronously inside _commit_routed): the barrier and the
            # flusher probe are pure overhead on the hot path
            return self._commit_under_barrier(ops)
        # shared epoch barrier: an epoch cut cannot run mid-commit, so a
        # multi-shard transaction lands entirely inside one flush epoch
        self._epoch_barrier.acquire_read()
        try:
            token = self._commit_under_barrier(ops)
        finally:
            self._epoch_barrier.release_read()
        if self._flusher is None:
            self._ensure_flusher()
        # wake on a reached watermark, or whenever the flusher sits in
        # its indefinite idle wait (it recomputes the interval deadline
        # from the shards' batch timestamps on wakeup); a racy missed
        # wake only delays until the next commit or maybe_flush nudge
        if self._flusher_idle or \
                any(s._watermark_reached() for s in self._group_shards):
            self._flush_wake.set()
        return token

    def _ensure_flusher(self):
        with self._flush_serial:
            if self._flusher is None and not self._flusher_stop:
                t = threading.Thread(target=self._flusher_loop, daemon=True,
                                     name="epoch-flusher")
                self._flusher = t
                t.start()

    def _flusher_loop(self):
        while True:
            timestamps = [s._first_ts for s in self._group_shards]
            live = [ts for ts in timestamps if ts is not None]
            if live:
                interval = min(getattr(s, "interval", 0.005)
                               for s in self._group_shards)
                timeout = max(0.0, min(live) + interval - time.monotonic())
            else:
                timeout = None
                self._flusher_idle = True
            self._flush_wake.wait(timeout)
            self._flusher_idle = False
            self._flush_wake.clear()
            if self._flusher_stop:
                return
            if any(s._watermark_reached() for s in self._group_shards):
                with self._flush_serial:
                    self._flush_epochs()

    def _commit_under_barrier(self, ops):
        routes = [self._route(op) for op in ops]
        if any(r is BROADCAST for r in routes) or \
                any(op[0] == "reassign_event" for op in ops):
            involved = list(range(self.n_shards))
        else:
            involved = sorted({i for r in routes for i in r})
            if len(involved) == 1:
                # fast path: the whole transaction — including a vectored
                # run of events — homes on one shard, so the run costs
                # exactly one lock acquisition and one routed commit
                i = involved[0]
                sh = self.shards[i]
                with sh.shard_lock:
                    self._validate(ops)
                    t = sh._commit_routed(list(ops))
                return {i: t} if t is not None else None
        locks = [self.shards[i].shard_lock for i in involved]
        for lk in locks:
            lk.acquire()
        try:
            self._validate(ops)
            shard_ops: Dict[int, List[Tuple]] = {i: [] for i in involved}
            for op, route in zip(ops, routes):
                if op[0] == "reassign_event":
                    self._plan_reassign(op, shard_ops)
                elif op[0] in ("log_events", "set_status_many") and \
                        (route is BROADCAST or len(route) > 1):
                    self._split_batch_op(op, involved, shard_ops)
                elif route is BROADCAST:
                    for i in involved:
                        # a broadcast assign (rec_op=None) must only reach
                        # shards that hold rows for the event — applying it
                        # to a rowless shard would fail mid-commit
                        if op[0] == "assign_insets" and not \
                                self.shards[i].image()._has_event_rows(
                                    op[1], op[3]):
                            continue
                        shard_ops[i].append(op)
                else:
                    for i in route:
                        shard_ops[i].append(op)
            token = {}
            for i in involved:
                if shard_ops[i]:
                    t = self.shards[i]._commit_routed(shard_ops[i])
                    if t is not None:
                        token[i] = t
        finally:
            for lk in reversed(locks):
                lk.release()
        return token or None

    def _split_batch_op(self, op, involved, shard_ops):
        """Slice a vectored op so each shard receives only the entries it
        homes (entry order preserved). Replicating the whole run would
        land rows in foreign shards, corrupting the home-routed queries
        and duplicating rows in the sender-side merges."""
        home = self._log_entry_home if op[0] == "log_events" \
            else self._status_entry_home
        for i in involved:
            ents = [e for e in op[1]
                    if home(e) is BROADCAST or home(e) == i]
            if ents:
                shard_ops[i].append((op[0], ents))

    def _validate(self, ops):
        """Conditional-op validation against the union image (locks held)."""
        for op in ops:
            if op[0] == "set_inset_status" and op[4]:
                if not self._shard(op[1]).image()._has_inset_rows(op[1],
                                                                  op[2]):
                    raise TxnAborted(
                        f"no EVENT_LOG rows for InSet {op[2]}@{op[1]}")
            elif op[0] == "assign_insets":
                key, rec = op[1], op[3]
                imgs = [self._shard(rec).image()] if rec is not None \
                    else [s.image() for s in self.shards]
                if not any(img._has_event_rows(key, rec) for img in imgs):
                    raise TxnAborted(f"no EVENT_LOG rows for {key}")

    def _plan_reassign(self, op, shard_ops):
        """Decompose reassign_event into home-shard micro-ops (locks held)."""
        _, old_key, old_rec, new_key, tgt_op, tgt_port = op
        from repro.core.events import UNDONE
        moved = False
        blob = None
        blob_shard = None
        for i, sh in enumerate(self.shards):
            img = sh.image()
            if any((old_rec is None or k[3] == old_rec)
                   and img.event_log[k]["status"] == UNDONE
                   for k in img._by_key3.get(old_key, ())):
                moved = True
                shard_ops[i].append(("_del_undone", old_key, old_rec))
            if blob is None and old_key in img.event_data:
                blob = img.event_data[old_key]
                blob_shard = i
        if not moved:
            return
        t = self._idx(tgt_op)
        shard_ops[t].append(("_ins_row", new_key + (tgt_op, None),
                             tgt_op, tgt_port))
        if blob is not None:
            shard_ops[blob_shard].append(("delete_event_data", old_key))
            shard_ops[t].append(("_put_blob", new_key, blob))

    # ---- durability ------------------------------------------------------
    def is_durable(self, token) -> bool:
        if token is None:
            return True
        return all(self.shards[i].is_durable(t) for i, t in token.items())

    def flush(self):
        """Global flush epoch (lightweight 2PC), replacing the old
        all-shard-locks barrier:

          1. under a brief exclusive epoch barrier (no I/O — commits hold
             it shared), cut every shard's pending batch under a fresh
             epoch id, so no transaction straddles the cut;
          2. prepare: each shard persists its batch tagged with the epoch,
             with NO shard lock held — commits keep flowing during the I/O;
          3. commit point: one durable epoch-commit record marks the whole
             multi-shard flush atomic;
          4. each shard advances its durability watermark.

        A crash anywhere in the protocol rolls back prepared-but-
        uncommitted epochs on restart — the durable images always form a
        consistent cut and no multi-shard transaction is half-durable."""
        if not self._group_shards:
            for s in self.shards:
                s.flush()
            return
        with self._flush_serial:
            self._flush_epochs()

    def _flush_epochs(self):
        """One epoch flush; caller holds ``_flush_serial``."""
        with self._epoch_barrier.write():
            epoch_id = self.epoch_coord.next_epoch()
            cut = [(s, s.cut_pending(epoch_id))
                   for s in self._group_shards]
        prepared = False
        for s, batch in cut:
            if batch:
                s.persist_prepared(epoch_id)
                prepared = True
        if not prepared:
            return
        self.epoch_coord.commit_epoch(epoch_id)
        for s, _batch in cut:
            s.finish_epoch(epoch_id)
        self.epochs_flushed += 1

    def maybe_flush(self):
        if any(s._watermark_reached() for s in self.shards
               if hasattr(s, "_watermark_reached")):
            if self._group_shards and self._flusher is not None \
                    and not self._flusher_stop:
                self._flush_wake.set()      # async: never block the caller
            else:
                self.flush()

    # ---- checkpoint compaction ------------------------------------------
    @property
    def supports_checkpoint(self):
        return any(getattr(s, "supports_checkpoint", False)
                   for s in self.shards)

    def checkpoint_due(self):
        return any(s.checkpoint_due() for s in self.shards)

    def checkpoint(self):
        """Checkpoint every shard. For group-commit shards this must run
        the global-flush-epoch protocol first AND hold ``_flush_serial``
        across the shard compactions: a concurrent epoch flush could
        otherwise persist prepare records of a not-yet-committed epoch into
        a shard image mid-compaction, baking conditional records into the
        checkpoint unconditionally."""
        if not self.supports_checkpoint:
            return
        # the "lineage exists => keep rows" guard is global (see gc())
        keep_rows = any(s.image().lineage for s in self.shards)
        if not self._group_shards:
            for s in self.shards:
                s.compact(keep_rows=keep_rows)
            return
        with self._flush_serial:
            self._flush_epochs()
            for s in self.shards:
                if hasattr(s, "_checkpoint_inner"):
                    if getattr(s, "supports_checkpoint", False):
                        s._checkpoint_inner(keep_rows=keep_rows)
                else:
                    s.compact(keep_rows=keep_rows)

    def maybe_checkpoint(self):
        if self.checkpoint_due():
            self.checkpoint()

    def set_gc_protect(self, ops):
        self.gc_protect = frozenset(ops)
        for s in self.shards:
            s.set_gc_protect(ops)

    def recovery_replay_count(self):
        return sum(s.recovery_replay_count() for s in self.shards)

    def crash(self):
        # _flush_serial parks the crash at a protocol-quiescent point: an
        # in-flight async epoch flush either fully committed or never cut
        with self._flush_serial:
            # the coordinator first: shards consult its (durable) committed
            # set when deciding which prepared epochs survive
            if self.epoch_coord is not None:
                self.epoch_coord.crash()
            for s in self.shards:
                s.crash()

    def _stop_flusher(self):
        self._flusher_stop = True
        self._flush_wake.set()
        t = self._flusher
        if t is not None and t is not threading.current_thread():
            t.join(timeout=2.0)
        self._flusher = None

    def close(self):
        self._stop_flusher()
        self.flush()
        for s in self.shards:
            s.close()
        if self.epoch_coord is not None:
            self.epoch_coord.close()

    # ---- bookkeeping -----------------------------------------------------
    @property
    def commits(self):
        return sum(s.commits for s in self.shards)

    @property
    def bytes_written(self):
        return sum(s.bytes_written for s in self.shards)

    # ---- queries ---------------------------------------------------------
    # receiver-/owner-homed: answered by one shard
    def fetch_ack_events(self, op_id, include_done=False):
        return self._shard(op_id).fetch_ack_events(
            op_id, include_done=include_done)

    def last_acked(self, op_id):
        return self._shard(op_id).last_acked(op_id)

    def get_write_actions(self, op_id):
        return self._shard(op_id).get_write_actions(op_id)

    def get_state(self, op_id):
        return self._shard(op_id).get_state(op_id)

    def get_read_action(self, op_id, conn_id):
        return self._shard(op_id).get_read_action(op_id, conn_id)

    def undone_events_from(self, send_op, rec_op):
        return self._shard(rec_op).undone_events_from(send_op, rec_op)

    def lineage_insets_of(self, event_key):
        return self._shard(event_key[0]).lineage_insets_of(event_key)

    def lineage_events_of_inset(self, rec_op, inset_id):
        return self._shard(rec_op).lineage_events_of_inset(rec_op, inset_id)

    def lineage_outputs_of_inset(self, send_op, inset_id):
        return self._shard(send_op).lineage_outputs_of_inset(send_op,
                                                             inset_id)

    def insets_of_event(self, event_key, rec_op):
        return self._shard(rec_op).insets_of_event(event_key, rec_op)

    # filtered lineage queries: same home-shard routing; the fan-out ones
    # skip shards the filter's ``ops`` prove uninvolved (per-shard pushdown
    # composes with shard pruning)
    @property
    def supports_query_pushdown(self):
        return all(getattr(s, "supports_query_pushdown", False)
                   for s in self.shards)

    def query_lineage_insets(self, event_key, flt=None):
        return self._shard(event_key[0]).query_lineage_insets(event_key, flt)

    def query_inset_events(self, rec_op, inset_id, flt=None):
        return self._shard(rec_op).query_inset_events(rec_op, inset_id, flt)

    def query_inset_outputs(self, send_op, inset_id, flt=None):
        return self._shard(send_op).query_inset_outputs(send_op, inset_id,
                                                        flt)

    def query_event_insets(self, event_key, rec_op, flt=None):
        return self._shard(rec_op).query_event_insets(event_key, rec_op, flt)

    def query_consumers(self, event_key, flt=None):
        out = set()
        for s in self.shards:
            out.update(s.query_consumers(event_key, flt))
        return sorted(out)

    def query_lineage(self, flt=None):
        if flt is not None and flt.ops is not None:
            involved = sorted({self._idx(o) for o in flt.ops})
        else:
            involved = range(self.n_shards)
        rows = []
        for i in involved:
            rows.extend(self.shards[i].query_lineage(flt))
        return sorted(rows)

    def get_event_payload(self, event_key):
        # EVENT_DATA is receiver-homed and the receiver isn't in the key:
        # probe shards until one holds the payload
        for s in self.shards:
            payload = s.get_event_payload(event_key)
            if payload is not None:
                return payload
        return None

    def _query_stats(self):
        out: Dict[str, int] = {}
        for s in self.shards:
            for k, v in s._query_stats().items():
                out[k] = out.get(k, 0) + v
        return out

    def reset_query_stats(self):
        for s in self.shards:
            s.reset_query_stats()

    # sender-side: rows live in the consumers' shards — merge
    def fetch_resend_events(self, op_id):
        rows = []
        for s in self.shards:
            rows.extend(s.fetch_resend_events(op_id))
        rows.sort(key=lambda es: es[0].event_id)
        return rows

    def fetch_replay_outputs(self, op_id):
        rows = []
        for s in self.shards:
            rows.extend(s.fetch_replay_outputs(op_id))
        return sorted(rows)

    def undone_outputs_after(self, op_id, port, min_id):
        ids = set()
        for s in self.shards:
            ids.update(s.undone_outputs_after(op_id, port, min_id))
        return sorted(ids)

    def last_sent_ssn(self, op_id):
        out: Dict[str, int] = {}
        for s in self.shards:
            for port, last in s.last_sent_ssn(op_id).items():
                out[port] = max(out.get(port, -1), last)
        return out

    def event_status(self, key, rec_op=None):
        if rec_op is not None:
            return self._shard(rec_op).event_status(key, rec_op)
        rows = []
        for s in self.shards:
            rows.extend(s.event_status(key))
        return rows

    def consumers_of(self, event_key):
        out = set()
        for s in self.shards:
            out.update(s.consumers_of(event_key))
        return sorted(out)

    def gc(self, lineage_ops: Iterable[str] = ()):
        ops = list(lineage_ops)
        # the "lineage exists => keep rows" guard is global: EVENT_LINEAGE
        # rows live only in the producing operator's shard
        keep_rows = any(s.image().lineage for s in self.shards)
        for s in self.shards:
            s.gc(ops, keep_rows=keep_rows)
