"""LOG.io persistent log tables (Sec. 3.2) behind atomic transactions.

The log layer is a pluggable backend stack:

  * :class:`LogBackend` / :class:`LogTransaction` — the formal interface
    every protocol module programs against (``base``);
  * :class:`MemoryLogStore`, :class:`NullLogStore` — dict-based backends
    (``memory``);
  * :class:`SqliteLogStore` — durable ACID backend (``sqlite``);
  * :class:`SegmentLogStore` — durable append-only file segments + sidecar
    index, with checkpoint compaction (``segment``);
  * :class:`ShardedLogStore` — partitions the tables by operator id across
    independent shard backends (``sharded``);
  * :class:`GroupCommitStore` — group-commit transaction pipelining with a
    durability watermark (``batched``).

``build_store`` assembles a stack from a typed :class:`StoreConfig` or from
the legacy spec string it round-trips with, e.g. ``"memory"``,
``"sqlite"``, ``"segment+group"``, ``"memory+sharded+group"``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Union

from repro.core.logstore.base import (LineageFilter, LogBackend,
                                      LogTransaction, TxnAborted)
from repro.core.logstore.batched import GroupCommitStore
from repro.core.logstore.epoch import (EpochCoordinator,
                                       SqliteEpochCoordinator,
                                       make_coordinator)
from repro.core.logstore.memory import MemoryLogStore, NullLogStore
from repro.core.logstore.segment import SegmentLogStore
from repro.core.logstore.sharded import ShardedLogStore
from repro.core.logstore.sqlite import SqliteLogStore

__all__ = ["LineageFilter", "LogBackend", "LogTransaction", "TxnAborted",
           "MemoryLogStore", "NullLogStore", "SqliteLogStore",
           "SegmentLogStore", "ShardedLogStore", "GroupCommitStore",
           "EpochCoordinator", "SqliteEpochCoordinator", "StoreConfig",
           "build_store"]

_BASES = ("memory", "sqlite", "segment", "null")
_MODIFIERS = ("sharded", "group")


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    """Typed description of a log-backend stack.

    ``StoreConfig.parse(spec)`` accepts the legacy
    ``"<base>[+sharded][+group]"`` spec strings and ``str(config)`` renders
    the config back to exactly that spec — the two forms round-trip. The
    segment-backend knobs (``segment_bytes``, ``compress``,
    ``checkpoint_interval``) have no spec-string syntax: they are
    configured only through this typed path.
    """

    base: str = "memory"
    sharded: bool = False
    group: bool = False
    #: sqlite: database file; segment: store directory; required for both.
    path: Optional[str] = None
    shards: int = 4
    batch_size: int = 64
    interval: float = 0.005
    #: segment backend: active-segment rotation threshold (bytes).
    segment_bytes: int = 4 * 1024 * 1024
    #: segment backend: zlib-compress sealed segments and checkpoints.
    compress: bool = True
    #: segment backend: records between automatic checkpoint compactions
    #: (0 = checkpoint only on explicit ``store.checkpoint()`` calls).
    checkpoint_interval: int = 0

    def __post_init__(self):
        if self.base not in _BASES:
            raise ValueError(f"unknown store base {self.base!r} "
                             f"(expected one of {list(_BASES)})")
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        if self.batch_size < 1:
            raise ValueError(
                f"batch_size must be >= 1, got {self.batch_size}")
        if self.interval < 0:
            raise ValueError(f"interval must be >= 0, got {self.interval}")
        if self.segment_bytes < 1:
            raise ValueError(
                f"segment_bytes must be >= 1, got {self.segment_bytes}")
        if self.checkpoint_interval < 0:
            raise ValueError(f"checkpoint_interval must be >= 0, got "
                             f"{self.checkpoint_interval}")

    @classmethod
    def parse(cls, spec: str, **overrides) -> "StoreConfig":
        """Parse ``"<base>[+sharded][+group]"`` into a config; keyword
        overrides fill the non-spec fields (path, shards, ...)."""
        if not isinstance(spec, str) or not spec:
            raise ValueError(
                f"store spec must be a non-empty string, got {spec!r}")
        parts = spec.split("+")
        base, mods = parts[0], parts[1:]
        seen = set()
        for m in mods:
            if m not in _MODIFIERS:
                raise ValueError(
                    f"unknown store modifier {m!r} in spec {spec!r} "
                    f"(expected {list(_MODIFIERS)})")
            if m in seen:
                raise ValueError(
                    f"duplicate store modifier {m!r} in spec {spec!r}")
            seen.add(m)
        return cls(base=base, sharded="sharded" in seen,
                   group="group" in seen, **overrides)

    def __str__(self) -> str:
        spec = self.base
        if self.sharded:
            spec += "+sharded"
        if self.group:
            spec += "+group"
        return spec


def build_store(config: Union[StoreConfig, str] = "memory", *,
                path: Optional[str] = None, shards: Optional[int] = None,
                batch_size: Optional[int] = None,
                interval: Optional[float] = None) -> LogBackend:
    """Assemble a backend stack from a :class:`StoreConfig` or a legacy
    ``"<base>[+sharded][+group]"`` spec string.

    base: ``memory`` | ``sqlite`` | ``segment`` (both need ``path``) |
    ``null``. ``+group`` wraps each (shard) store in group commit;
    ``+sharded`` partitions by operator id. ``memory+group`` simulates
    durability via the flushed-op history so ``crash()`` loses exactly the
    unflushed batch. ``sharded+group`` stacks flush under the global-epoch
    2PC protocol — durable bases get a durable epoch coordinator at
    ``<path>.epochs``. The keyword overrides apply to spec strings only;
    with a config object every knob lives in the config.
    """
    if isinstance(config, StoreConfig):
        if any(v is not None for v in (path, shards, batch_size, interval)):
            raise ValueError("pass store options inside the StoreConfig, "
                             "not as build_store keyword overrides")
        cfg = config
    elif isinstance(config, str):
        overrides = {k: v for k, v in [("path", path), ("shards", shards),
                                       ("batch_size", batch_size),
                                       ("interval", interval)]
                     if v is not None}
        cfg = StoreConfig.parse(config, **overrides)
    else:
        raise ValueError(f"build_store expects a StoreConfig or a spec "
                         f"string, got {type(config).__name__}")

    coord = None
    if cfg.sharded and cfg.group and cfg.base != "null":
        coord = make_coordinator(
            cfg.base, None if cfg.path is None else f"{cfg.path}.epochs")

    def leaf(i: Optional[int] = None) -> LogBackend:
        if cfg.base == "memory":
            inner = None if cfg.group else MemoryLogStore()
        elif cfg.base == "null":
            return NullLogStore()
        elif cfg.base == "sqlite":
            if cfg.path is None:
                raise ValueError("sqlite store needs a path")
            p = cfg.path if i is None else f"{cfg.path}.shard{i}"
            inner = SqliteLogStore(p, epoch_coord=coord)
        else:   # segment
            if cfg.path is None:
                raise ValueError("segment store needs a path (a directory)")
            p = cfg.path if i is None else os.path.join(cfg.path,
                                                        f"shard{i}")
            inner = SegmentLogStore(
                p, segment_bytes=cfg.segment_bytes, compress=cfg.compress,
                checkpoint_interval=cfg.checkpoint_interval,
                epoch_coord=coord)
        if cfg.group:
            return GroupCommitStore(inner, batch_size=cfg.batch_size,
                                    interval=cfg.interval,
                                    epoch_coord=coord)
        return inner

    if cfg.sharded:
        return ShardedLogStore(cfg.shards, factory=lambda i: leaf(i),
                               epoch_coord=coord)
    return leaf()
