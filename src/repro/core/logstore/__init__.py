"""LOG.io persistent log tables (Sec. 3.2) behind atomic transactions.

The log layer is a pluggable backend stack:

  * :class:`LogBackend` / :class:`LogTransaction` — the formal interface
    every protocol module programs against (``base``);
  * :class:`MemoryLogStore`, :class:`NullLogStore` — dict-based backends
    (``memory``);
  * :class:`SqliteLogStore` — durable ACID backend (``sqlite``);
  * :class:`ShardedLogStore` — partitions the tables by operator id across
    independent shard backends (``sharded``);
  * :class:`GroupCommitStore` — group-commit transaction pipelining with a
    durability watermark (``batched``).

``build_store`` assembles a stack from a spec string, e.g.
``"memory"``, ``"sqlite"``, ``"memory+sharded"``, ``"sqlite+group"``,
``"memory+sharded+group"``.
"""
from __future__ import annotations

from typing import Optional

from repro.core.logstore.base import LogBackend, LogTransaction, TxnAborted
from repro.core.logstore.batched import GroupCommitStore
from repro.core.logstore.epoch import (EpochCoordinator,
                                       SqliteEpochCoordinator,
                                       make_coordinator)
from repro.core.logstore.memory import MemoryLogStore, NullLogStore
from repro.core.logstore.sharded import ShardedLogStore
from repro.core.logstore.sqlite import SqliteLogStore

__all__ = ["LogBackend", "LogTransaction", "TxnAborted", "MemoryLogStore",
           "NullLogStore", "SqliteLogStore", "ShardedLogStore",
           "GroupCommitStore", "EpochCoordinator", "SqliteEpochCoordinator",
           "build_store"]


def build_store(spec: str = "memory", *, path: Optional[str] = None,
                shards: int = 4, batch_size: int = 64,
                interval: float = 0.005) -> LogBackend:
    """Assemble a backend stack from ``"<base>[+sharded][+group]"``.

    base: ``memory`` | ``sqlite`` (needs ``path``) | ``null``.
    ``+group`` wraps each (shard) store in group commit; ``+sharded``
    partitions by operator id. ``memory+group`` simulates durability via the
    flushed-op history so ``crash()`` loses exactly the unflushed batch.
    ``sharded+group`` stacks flush under the global-epoch 2PC protocol —
    sqlite bases get a durable epoch coordinator at ``<path>.epochs``.
    """
    parts = spec.split("+")
    base, mods = parts[0], set(parts[1:])
    unknown = mods - {"sharded", "group"}
    if unknown:
        raise ValueError(f"unknown store modifiers {sorted(unknown)!r}")

    coord = None
    if "sharded" in mods and "group" in mods and base != "null":
        coord = make_coordinator(
            base, None if path is None else f"{path}.epochs")

    def leaf(i: Optional[int] = None) -> LogBackend:
        if base == "memory":
            inner = None if "group" in mods else MemoryLogStore()
        elif base == "null":
            return NullLogStore()
        elif base == "sqlite":
            if path is None:
                raise ValueError("sqlite store needs a path")
            p = path if i is None else f"{path}.shard{i}"
            inner = SqliteLogStore(p, epoch_coord=coord)
        else:
            raise ValueError(f"unknown store base {base!r}")
        if "group" in mods:
            return GroupCommitStore(inner, batch_size=batch_size,
                                    interval=interval, epoch_coord=coord)
        return inner

    if "sharded" in mods:
        return ShardedLogStore(shards, factory=lambda i: leaf(i),
                               epoch_coord=coord)
    return leaf()
