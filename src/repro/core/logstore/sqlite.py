"""Durable SQLite backend — same txn interface as the in-memory store.

Stands in for the HANA instance of the paper's implementation (Sec. 6.1);
used by the e2e training example and the durability tests.

Implementation note: we reuse the in-memory application logic for the
mutation semantics but persist every commit as one SQLite transaction, and
rebuild the in-memory image from disk on open ⇒ genuine durability with the
exact in-memory read paths. ``apply_many`` persists a whole group-commit
batch under a single SQLite transaction — the group-commit throughput win.
"""
from __future__ import annotations

import pickle
import sqlite3
from typing import List, Tuple

from repro.core.logstore.base import TxnAborted
from repro.core.logstore.memory import MemoryLogStore


class SqliteLogStore(MemoryLogStore):

    def __init__(self, path: str):
        super().__init__(eager_serialize=True)
        self.path = path
        self.conn = sqlite3.connect(path, check_same_thread=False)
        self.conn.execute("PRAGMA journal_mode=WAL")
        self.conn.execute(
            "CREATE TABLE IF NOT EXISTS wal_ops (seq INTEGER PRIMARY KEY "
            "AUTOINCREMENT, blob BLOB)")
        self.conn.commit()
        self._replay_from_disk()

    def _replay_from_disk(self):
        cur = self.conn.execute("SELECT blob FROM wal_ops ORDER BY seq")
        for (blob,) in cur.fetchall():
            ops = pickle.loads(blob)
            try:
                self._validate(ops)
            except TxnAborted:
                continue
            self._apply_ops(ops)

    def _persist(self, ops):
        """Apply one txn's ops and stage its WAL row; caller commits."""
        blob = pickle.dumps(ops)
        self._apply_ops(ops)
        self.conn.execute("INSERT INTO wal_ops (blob) VALUES (?)", (blob,))
        self.bytes_written += len(blob)

    def _commit(self, ops):
        with self.lock:
            self._validate(ops)
            self._persist(ops)
            self.conn.commit()                    # durable point
        return None

    def _commit_routed(self, ops):
        self._persist(ops)
        self.conn.commit()
        return None

    def apply_many(self, batches: List[List[Tuple]]):
        """One SQLite transaction for the whole batch (group commit)."""
        with self.lock:
            for ops in batches:
                try:
                    self._validate(ops)
                except TxnAborted:
                    continue
                self._persist(ops)
            self.conn.commit()                    # durable point, once
        return None

    def close(self):
        self.conn.close()
