"""Durable SQLite backend — same txn interface as the in-memory store.

Stands in for the HANA instance of the paper's implementation (Sec. 6.1);
used by the e2e training example and the durability tests.

Implementation note: we reuse the in-memory application logic for the
mutation semantics but persist every commit as one SQLite transaction, and
rebuild the in-memory image from disk on open ⇒ genuine durability with the
exact in-memory read paths. ``apply_many`` persists a whole group-commit
batch under a single SQLite transaction — the group-commit throughput win.

Global flush epochs (see ``logstore/epoch.py``): when the store is a shard
of an epoch-flushing stack, ``apply_many`` tags the batch's WAL rows with
the flush epoch id. Epoch-tagged rows are 2PC *prepare* records: durable
but conditional on the coordinator's epoch-commit record. On open (real
restart) and on ``crash()`` (simulated one), rows of epochs that never
committed are rolled back — deleted from the WAL — before the image is
rebuilt, so a crash between prepare and epoch commit leaves no multi-shard
transaction half-durable.
"""
from __future__ import annotations

import pickle
import sqlite3
from typing import List, Optional, Tuple

from repro.core.logstore.base import LineageFilter, TxnAborted
from repro.core.logstore.memory import MemoryLogStore


class SqliteLogStore(MemoryLogStore):

    def __init__(self, path: str, epoch_coord=None):
        super().__init__(eager_serialize=True)
        self.path = path
        self.epoch_coord = epoch_coord
        self.conn = sqlite3.connect(path, check_same_thread=False)
        self.conn.execute("PRAGMA journal_mode=WAL")
        self.conn.execute(
            "CREATE TABLE IF NOT EXISTS wal_ops (seq INTEGER PRIMARY KEY "
            "AUTOINCREMENT, blob BLOB, epoch INTEGER)")
        # EVENT_LINEAGE mirror: put_lineage ops land here relationally (in
        # the same SQLite txn as their WAL row) so the filtered query ops
        # run as indexed SQL WHERE instead of scanning the log image.
        self.conn.execute(
            "CREATE TABLE IF NOT EXISTS lineage (eid INTEGER, sop TEXT, "
            "sport TEXT, inset TEXT, epoch INTEGER)")
        self.conn.execute(
            "CREATE INDEX IF NOT EXISTS lineage_out ON lineage "
            "(sop, sport, eid)")
        self.conn.execute(
            "CREATE INDEX IF NOT EXISTS lineage_inset ON lineage "
            "(sop, inset)")
        self.conn.commit()
        self._rollback_uncommitted_epochs()
        self._replay_from_disk()

    def _rollback_uncommitted_epochs(self):
        """Delete prepare records whose flush epoch never committed (the
        restart half of the 2PC protocol)."""
        if self.epoch_coord is None:
            return
        epochs = [e for (e,) in self.conn.execute(
            "SELECT DISTINCT epoch FROM wal_ops WHERE epoch IS NOT NULL")
            if not self.epoch_coord.is_committed(e)]
        if epochs:
            self.conn.executemany("DELETE FROM wal_ops WHERE epoch = ?",
                                  [(e,) for e in epochs])
            self.conn.executemany("DELETE FROM lineage WHERE epoch = ?",
                                  [(e,) for e in epochs])
            self.conn.commit()

    def _replay_from_disk(self):
        cur = self.conn.execute("SELECT blob FROM wal_ops ORDER BY seq")
        for (blob,) in cur.fetchall():
            ops = pickle.loads(blob)
            try:
                self._validate(ops)
            except TxnAborted:
                continue
            self._apply_ops(ops)

    def _persist(self, ops, epoch: Optional[int] = None):
        """Apply one txn's ops and stage its WAL row; caller commits. The
        lineage mirror is staged here (not in WAL replay) so reopening the
        store never double-inserts rows."""
        blob = pickle.dumps(ops)
        self._apply_ops(ops)
        self.conn.execute("INSERT INTO wal_ops (blob, epoch) VALUES (?, ?)",
                          (blob, epoch))
        lin = [(op[1], op[2], op[3], op[4], epoch) for op in ops
               if op[0] == "put_lineage"]
        if lin:
            self.conn.executemany(
                "INSERT INTO lineage (eid, sop, sport, inset, epoch) "
                "VALUES (?, ?, ?, ?, ?)", lin)
        self.bytes_written += len(blob)

    def _commit(self, ops):
        with self.lock:
            self._validate(ops)
            self._persist(ops)
            self.conn.commit()                    # durable point
        return None

    def _commit_routed(self, ops):
        self._persist(ops)
        self.conn.commit()
        return None

    def apply_many(self, batches: List[List[Tuple]],
                   epoch: Optional[int] = None):
        """One SQLite transaction for the whole batch (group commit). With
        ``epoch`` this is the 2PC prepare: rows are durable but count only
        once the epoch-commit record lands."""
        with self.lock:
            for ops in batches:
                try:
                    self._validate(ops)
                except TxnAborted:
                    continue
                self._persist(ops, epoch=epoch)
            self.conn.commit()                    # durable point, once
        return None

    # filtered lineage queries: SQL WHERE over the indexed mirror ---------
    @staticmethod
    def _flt_sql(flt: Optional[LineageFilter]) -> Tuple[str, list]:
        """Translate a LineageFilter into SQL predicate fragments — the
        predicate runs inside SQLite (index-driven), not over fetched rows."""
        conds, params = [], []
        if flt is None:
            return "", params
        if flt.ops is not None:
            conds.append(f"sop IN ({','.join('?' * len(flt.ops))})")
            params.extend(sorted(flt.ops))
        if flt.ports is not None:
            conds.append(f"sport IN ({','.join('?' * len(flt.ports))})")
            params.extend(sorted(flt.ports))
        if flt.ssn_min is not None:
            conds.append("eid >= ?")
            params.append(flt.ssn_min)
        if flt.ssn_max is not None:
            conds.append("eid <= ?")
            params.append(flt.ssn_max)
        return (" AND " + " AND ".join(conds)) if conds else "", params

    def query_lineage_insets(self, event_key,
                             flt: Optional[LineageFilter] = None
                             ) -> List[str]:
        so, sp, eid = tuple(event_key)
        if flt is not None and not flt.matches(so, sp, eid):
            return []
        with self.lock:
            rows = self.conn.execute(
                "SELECT inset FROM lineage WHERE sop = ? AND sport = ? "
                "AND eid = ?", (so, sp, eid)).fetchall()
            return self._count(len(rows), [ins for (ins,) in rows])

    def query_inset_outputs(self, send_op: str, inset_id: str,
                            flt: Optional[LineageFilter] = None
                            ) -> List[Tuple]:
        extra, params = self._flt_sql(flt)
        with self.lock:
            rows = self.conn.execute(
                "SELECT sop, sport, eid FROM lineage WHERE sop = ? "
                "AND inset = ?" + extra, [send_op, inset_id] + params
            ).fetchall()
            return self._count(len(rows), sorted(tuple(r) for r in rows))

    def query_lineage(self, flt: Optional[LineageFilter] = None
                      ) -> List[Tuple]:
        extra, params = self._flt_sql(flt)
        where = ("WHERE " + extra[5:]) if extra else ""
        with self.lock:
            rows = self.conn.execute(
                f"SELECT sop, sport, eid, inset FROM lineage {where}",
                params).fetchall()
            return self._count(len(rows), sorted(tuple(r) for r in rows))

    def crash(self):
        """Simulated process crash: the durable medium (the SQLite file)
        survives; roll back uncommitted prepare records and rebuild the
        image from disk exactly as a real restart would."""
        with self.lock:
            self.conn.rollback()     # anything un-committed dies with us
            self._rollback_uncommitted_epochs()
            self.event_log = {}
            self.event_data = {}
            self.read_actions = {}
            self.state = {}
            self.lineage = []
            self._reindex()
            self._replay_from_disk()

    def close(self):
        self.conn.close()
