"""In-memory LogBackend: dict-based tables behind one lock.

Transactions buffer mutations and apply them under the lock at commit; a
crash between ``begin`` and ``commit`` loses exactly the uncommitted buffer
(the atomicity the protocol needs). Commits are durable immediately
(token ``None``): the store object itself plays the durable HANA instance of
the paper's implementation for engine-level (pod) failures.

``eager_serialize=False`` keeps EVENT_DATA payloads as raw objects and
defers pickling to whoever ships them to a durable medium — the
serialization-off-the-critical-path optimization the group-commit layer
builds on (cf. write-ahead lineage with asynchronous flushing,
arXiv:2403.08062). The zero-copy path stores the *body by reference*:
logged event bodies are part of the log's contract and must not be mutated
after commit (all in-repo operators build fresh bodies per event; an
operator reusing a mutable buffer must copy it before emitting).
"""
from __future__ import annotations

import pickle
import threading
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.events import DONE, REPLAY, UNDONE, Event
from repro.core.logstore.base import LineageFilter, LogBackend, TxnAborted

_RAW = "__raw__"


class MemoryLogStore(LogBackend):
    """EVENT_LOG rows: {key: (send_op,send_port,event_id,rec_op,inset_id|None)
    -> dict(status=..., rec_op=..., rec_port=..., inset=...)}."""

    def __init__(self, eager_serialize: bool = True):
        self.lock = threading.RLock()
        self.eager_serialize = eager_serialize
        self.event_log: Dict[Tuple, Dict[str, Any]] = {}
        self.event_data: Dict[Tuple, Any] = {}
        self.read_actions: Dict[Tuple, Dict[str, Any]] = {}
        self.state: Dict[str, List[Tuple[int, bytes]]] = {}
        self.lineage: List[Tuple[int, str, str, str]] = []
        # secondary indexes: the per-event transactions of the hot path
        # (set_status / assign_insets / set_inset_status and their
        # validation) must not scan the whole EVENT_LOG
        self._by_key3: Dict[Tuple, set] = {}            # (so,sp,id) -> keys
        self._by_rec_inset: Dict[Tuple, set] = {}       # (rec_op,ins) -> keys
        # lineage indexes: the LineageQuery pushdown paths walk these
        # instead of scanning the append-only lineage list / EVENT_LOG
        self._lin_by_out: Dict[Tuple, List[str]] = {}   # (so,sp,id) -> insets
        self._lin_by_inset: Dict[Tuple, List[Tuple]] = {}  # (so,ins) -> key3s
        # scan-effort counters (query_stats): rows touched by the legacy
        # full-scan query paths vs rows returned by the indexed ones
        self._qstats: Dict[str, int] = {"rows_scanned": 0, "rows_returned": 0}
        # checkpoint-truncation floors: once a checkpointing subclass GC's
        # done rows, the max-scan queries below would rewind — these floors
        # (persisted in the checkpoint record) pin the pre-truncation maxima
        self._ssn_floor: Dict[Tuple[str, str], int] = {}   # (op,port)->ssn
        self._ack_floor: Dict[Tuple[str, str], int] = {}   # (op,port)->id
        self.commits = 0
        self.bytes_written = 0

    # -- row index maintenance ---------------------------------------------
    def _add_row(self, k: Tuple, row: Dict[str, Any]):
        self.event_log[k] = row
        self._by_key3.setdefault(k[:3], set()).add(k)
        if k[4] is not None:
            self._by_rec_inset.setdefault((row["rec_op"], k[4]),
                                          set()).add(k)

    def _del_row(self, k: Tuple):
        row = self.event_log.pop(k, None)
        if row is None:
            return
        keys = self._by_key3.get(k[:3])
        if keys is not None:
            keys.discard(k)
            if not keys:
                del self._by_key3[k[:3]]
        if k[4] is not None:
            keys = self._by_rec_inset.get((row["rec_op"], k[4]))
            if keys is not None:
                keys.discard(k)
                if not keys:
                    del self._by_rec_inset[(row["rec_op"], k[4])]

    def _reindex(self):
        self._by_key3 = {}
        self._by_rec_inset = {}
        for k, row in self.event_log.items():
            self._by_key3.setdefault(k[:3], set()).add(k)
            if k[4] is not None:
                self._by_rec_inset.setdefault((row["rec_op"], k[4]),
                                              set()).add(k)
        self._lin_by_out = {}
        self._lin_by_inset = {}
        for (eid, so, sp, ins) in self.lineage:
            self._index_lineage(eid, so, sp, ins)

    def _index_lineage(self, eid: int, so: str, sp: str, ins: str):
        self._lin_by_out.setdefault((so, sp, eid), []).append(ins)
        self._lin_by_inset.setdefault((so, ins), []).append((so, sp, eid))

    # -- commit ------------------------------------------------------------
    def _commit(self, ops):
        with self.lock:
            self._validate(ops)
            self._apply_ops(ops)
        return None

    # legacy entry point (kept for subclasses/tests applying raw op lists)
    def _apply(self, ops):
        return self._commit(ops)

    # -- shard protocol (ShardedLogStore composition) ----------------------
    def image(self) -> "MemoryLogStore":
        return self

    @property
    def shard_lock(self):
        return self.lock

    def _commit_routed(self, ops):
        """Apply a pre-validated op slice; caller holds ``shard_lock``."""
        self._apply_ops(ops)
        return None

    def apply_many(self, batches: List[List[Tuple]], epoch=None):
        """Apply a batch of already-committed transactions (group-commit
        flush / WAL replay): one lock acquisition, aborted ones skipped.
        ``epoch`` (2PC prepare tag) is meaningful only for durable inners;
        a memory inner is durable at apply."""
        with self.lock:
            for ops in batches:
                try:
                    self._validate(ops)
                except TxnAborted:
                    continue
                self._apply_ops(ops)

    # -- validation (conditional ops) => atomicity -------------------------
    def _has_inset_rows(self, rec_op: str, inset_id: str) -> bool:
        return bool(self._by_rec_inset.get((rec_op, inset_id)))

    def _has_event_rows(self, key, rec_op: Optional[str]) -> bool:
        keys = self._by_key3.get(key, ())
        if rec_op is None:
            return bool(keys)
        return any(k[3] == rec_op for k in keys)

    def _validate(self, ops):
        for op in ops:
            if op[0] == "set_inset_status" and op[4]:
                if not self._has_inset_rows(op[1], op[2]):
                    raise TxnAborted(
                        f"no EVENT_LOG rows for InSet {op[2]}@{op[1]}")
            elif op[0] == "assign_insets":
                if not self._has_event_rows(op[1], op[3]):
                    # event vanished (reassigned by a scale-down, Alg 13)
                    raise TxnAborted(f"no EVENT_LOG rows for {op[1]}")

    def _apply_ops(self, ops):
        for op in ops:
            self._apply_one(op)
        self.commits += 1

    # -- payload blobs -----------------------------------------------------
    def _make_blob(self, ev: Event):
        if self.eager_serialize:
            blob = pickle.dumps((ev.header, ev.body))
            self.bytes_written += len(blob)
            return blob
        return (_RAW, dict(ev.header), ev.body)

    @staticmethod
    def _load_blob(blob) -> Tuple[dict, Any]:
        if isinstance(blob, tuple) and blob and blob[0] is _RAW:
            return blob[1], blob[2]
        return pickle.loads(blob)

    def _log_event_row(self, ev: Event, status: str,
                       inset_id: Optional[str]):
        key = (ev.send_op, ev.send_port, ev.event_id, ev.rec_op, inset_id)
        self._add_row(key, {"status": status, "rec_op": ev.rec_op,
                            "rec_port": ev.rec_port, "inset": inset_id})

    def _set_status_rows(self, key, status, inset_id, rec_op, only_status):
        for k in list(self._by_key3.get(key, ())):
            if inset_id != "*" and k[4] != inset_id:
                continue
            if rec_op is not None and k[3] != rec_op:
                continue
            if only_status is not None and \
                    self.event_log[k]["status"] != only_status:
                continue
            self.event_log[k]["status"] = status

    def _apply_one(self, op):
        kind = op[0]
        if kind == "log_event":
            _, ev, status, inset_id = op
            self._log_event_row(ev, status, inset_id)
        elif kind == "log_events":
            # vectored run: rows stay individually keyed — only the op
            # framing (lock/WAL/frame/fsync amortization) is batched
            for ev, status, inset_id in op[1]:
                self._log_event_row(ev, status, inset_id)
        elif kind == "put_event_data":
            _, ev = op
            self.event_data[ev.key()] = self._make_blob(ev)
        elif kind == "put_event_blob":
            # pre-serialized payload (the transport's wire encode, shared):
            # stored verbatim — _load_blob handles pickled bytes natively
            _, key, _home, blob = op
            if not isinstance(blob, bytes):
                blob = bytes(blob)
            self.event_data[key] = blob
            if self.eager_serialize:
                self.bytes_written += len(blob)
        elif kind == "delete_event_data":
            self.event_data.pop(op[1], None)
        elif kind == "set_status":
            _, key, status, inset_id, rec_op, only_status = op
            self._set_status_rows(key, status, inset_id, rec_op, only_status)
        elif kind == "set_status_many":
            for key, status, inset_id, rec_op, only_status in op[1]:
                self._set_status_rows(key, status, inset_id, rec_op,
                                      only_status)
        elif kind == "assign_insets":
            _, key, insets, rec = op
            base = key + (rec, None)
            row = self.event_log.get(base)
            if row is None:
                row = next(self.event_log[k]
                           for k in self._by_key3.get(key, ())
                           if rec is None or k[3] == rec)
            for ins in insets:
                self._add_row(key + (rec, ins), dict(row, inset=ins))
            if insets:
                self._del_row(base)
        elif kind == "set_inset_status":
            _, rec_op, inset_id, status, _req = op
            for k in self._by_rec_inset.get((rec_op, inset_id), ()):
                self.event_log[k]["status"] = status
        elif kind == "clear_inset":
            pass   # event-state clearing is in-memory; log rows stay "done"
        elif kind == "put_state":
            _, op_id, state_id, blob, keep = op
            self.bytes_written += len(blob)
            hist = self.state.setdefault(op_id, [])
            if keep:
                hist.append((state_id, blob))
            else:
                self.state[op_id] = [(state_id, blob)]
        elif kind == "put_lineage":
            _, event_id, send_op, send_port, inset_id = op
            self.lineage.append((event_id, send_op, send_port, inset_id))
            self._index_lineage(event_id, send_op, send_port, inset_id)
        elif kind == "put_read_action":
            _, op_id, conn_id, action_id, status, desc = op
            self.read_actions[(op_id, conn_id, action_id)] = {
                "status": status, "desc": desc}
        elif kind == "set_read_action_status":
            _, op_id, conn_id, action_id, status = op
            k = (op_id, conn_id, action_id)
            if k in self.read_actions:
                self.read_actions[k]["status"] = status
        elif kind == "delete_event_rows":
            _, key = op
            for k in list(self._by_key3.get(key, ())):
                self._del_row(k)
        elif kind == "reassign_event":
            # Alg 13 step 1.c: move an undone event to a new destination
            # (+ new event id); rows already done/acked->done are skipped.
            _, old_key, old_rec, new_key, tgt_op, tgt_port = op
            moved = self._del_undone_rows(old_key, old_rec)
            if moved:
                self._ins_row(new_key + (tgt_op, None), tgt_op, tgt_port)
                blob = self.event_data.pop(old_key, None)
                if blob is not None:
                    self.event_data[new_key] = blob
        # micro-ops: a sharded store decomposes reassign_event into these so
        # the delete and the insert can land in different shards
        elif kind == "_del_undone":
            self._del_undone_rows(op[1], op[2])
        elif kind == "_ins_row":
            _, key5, tgt_op, tgt_port = op
            self._ins_row(key5, tgt_op, tgt_port)
        elif kind == "_put_blob":
            self.event_data[op[1]] = op[2]

    def _del_undone_rows(self, old_key, old_rec) -> bool:
        moved = False
        for k in list(self._by_key3.get(old_key, ())):
            if (old_rec is None or k[3] == old_rec) \
                    and self.event_log[k]["status"] == UNDONE:
                self._del_row(k)
                moved = True
        return moved

    def _ins_row(self, key5, tgt_op, tgt_port):
        self._add_row(key5, {"status": UNDONE, "rec_op": tgt_op,
                             "rec_port": tgt_port, "inset": None})

    # -- image transfer (group-commit crash rebuild) -----------------------
    def load_image(self, src: "MemoryLogStore"):
        """Replace this store's tables with a copy of ``src``'s."""
        with self.lock, src.lock:
            self.event_log = {k: dict(r) for k, r in src.event_log.items()}
            self.event_data = dict(src.event_data)
            self.read_actions = {k: dict(v)
                                 for k, v in src.read_actions.items()}
            self.state = {k: list(v) for k, v in src.state.items()}
            self.lineage = list(src.lineage)
            self._ssn_floor = dict(src._ssn_floor)
            self._ack_floor = dict(src._ack_floor)
            self._reindex()

    # -- queries ----------------------------------------------------------
    def _mk_event(self, k, r) -> Event:
        header, body = ({}, None)
        blob = self.event_data.get(k[:3])
        if blob is not None:
            header, body = self._load_blob(blob)
        return Event(event_id=k[2], send_op=k[0], send_port=k[1],
                     rec_op=r["rec_op"], rec_port=r["rec_port"],
                     body=body, header=dict(header))

    def fetch_resend_events(self, op_id: str) -> List[Tuple[Event, str]]:
        with self.lock:
            rows = [(k, r) for k, r in self.event_log.items()
                    if k[0] == op_id and r["status"] in (UNDONE, REPLAY)
                    and k[4] is None and k[1] is not None
                    and r["rec_port"] is not None]
            rows.sort(key=lambda kr: kr[0][2])
            return [(self._mk_event(k, r), r["status"]) for k, r in rows]

    def fetch_ack_events(self, op_id: str, include_done: bool = False
                         ) -> List[Tuple[Event, str, str]]:
        """Returns [(event, inset_id, status)] ordered by (rec_port,
        event_id)."""
        statuses = (UNDONE, REPLAY, DONE) if include_done \
            else (UNDONE, REPLAY)
        with self.lock:
            rows = [(k, r) for k, r in self.event_log.items()
                    if r["rec_op"] == op_id and r["status"] in statuses
                    and k[4] is not None]
            rows.sort(key=lambda kr: (kr[1]["rec_port"] or "", kr[0][2]))
            return [(self._mk_event(k, r), k[4], r["status"])
                    for k, r in rows]

    def fetch_replay_outputs(self, op_id: str) -> List[Tuple[int, str, str]]:
        with self.lock:
            return sorted((k[2], k[1], r["status"])
                          for k, r in self.event_log.items()
                          if k[0] == op_id and k[1] is not None
                          and r["status"] == REPLAY
                          and r["rec_port"] is not None)

    def undone_outputs_after(self, op_id: str, port: str, min_id: int
                             ) -> List[int]:
        with self.lock:
            return sorted({k[2] for k, r in self.event_log.items()
                           if k[0] == op_id and k[1] == port
                           and r["status"] == UNDONE and k[2] >= min_id})

    def get_write_actions(self, op_id: str) -> List[Event]:
        with self.lock:
            rows = [(k, r) for k, r in self.event_log.items()
                    if k[0] == op_id and k[1] is None
                    and r["status"] == UNDONE]
            rows.sort(key=lambda kr: kr[0][2])
            return [self._mk_event(k, r) for k, r in rows]

    def get_state(self, op_id: str) -> Optional[bytes]:
        with self.lock:
            hist = self.state.get(op_id)
            return hist[-1][1] if hist else None

    def last_sent_ssn(self, op_id: str) -> Dict[str, int]:
        with self.lock:
            out: Dict[str, int] = {}
            for (o, p), ssn in self._ssn_floor.items():
                if o == op_id:
                    out[p] = ssn
            for k in self.event_log:
                if k[0] == op_id and k[1] is not None:
                    out[k[1]] = max(out.get(k[1], -1), k[2])
            return out

    def last_acked(self, op_id: str) -> Dict[str, int]:
        with self.lock:
            out: Dict[str, int] = {}
            for (o, p), eid in self._ack_floor.items():
                if o == op_id:
                    out[p] = eid
            for k, r in self.event_log.items():
                if r["rec_op"] == op_id and k[4] is not None:
                    p = r["rec_port"]
                    out[p] = max(out.get(p, -1), k[2])
            return out

    def event_status(self, key, rec_op: Optional[str] = None
                     ) -> List[Tuple[Optional[str], str]]:
        with self.lock:
            return [(k[4], self.event_log[k]["status"])
                    for k in self._by_key3.get(key, ())
                    if rec_op is None or k[3] == rec_op]

    def get_read_action(self, op_id: str, conn_id: str):
        with self.lock:
            cands = [(k, v) for k, v in self.read_actions.items()
                     if k[0] == op_id and k[1] == conn_id]
            if not cands:
                return None, None
            k, v = max(cands, key=lambda kv: kv[0][2])
            return k[2], dict(v)

    # scaling queries ----------------------------------------------------
    def undone_events_from(self, send_op: str, rec_op: str) -> List[Tuple]:
        with self.lock:
            return sorted({k[:3] for k, r in self.event_log.items()
                           if k[0] == send_op and r["rec_op"] == rec_op
                           and r["status"] == UNDONE},
                          key=lambda key: key[2])

    # lineage queries ----------------------------------------------------
    # The unfiltered ops are the paper's Sec. 7.3 reads, kept as deliberate
    # full scans: they are the honest "no pushdown" baseline the benchmark
    # compares against. The query_* variants below answer the same questions
    # through the secondary indexes. Both report scan effort via query_stats.

    def lineage_insets_of(self, event_key) -> List[str]:
        send_op, send_port, event_id = event_key
        with self.lock:
            self._qstats["rows_scanned"] += len(self.lineage)
            out = [ins for (eid, so, sp, ins) in self.lineage
                   if (so, sp, eid) == (send_op, send_port, event_id)]
            self._qstats["rows_returned"] += len(out)
            return out

    def lineage_events_of_inset(self, rec_op: str, inset_id: str
                                ) -> List[Tuple]:
        with self.lock:
            self._qstats["rows_scanned"] += len(self.event_log)
            out = sorted(k[:3] for k, r in self.event_log.items()
                         if r["rec_op"] == rec_op
                         and r.get("inset") == inset_id)
            self._qstats["rows_returned"] += len(out)
            return out

    def lineage_outputs_of_inset(self, send_op: str, inset_id: str
                                 ) -> List[Tuple]:
        with self.lock:
            self._qstats["rows_scanned"] += len(self.lineage)
            out = sorted((so, sp, eid) for (eid, so, sp, ins) in self.lineage
                         if so == send_op and ins == inset_id)
            self._qstats["rows_returned"] += len(out)
            return out

    def insets_of_event(self, event_key, rec_op: str) -> List[str]:
        with self.lock:
            self._qstats["rows_scanned"] += len(self.event_log)
            out = [k[4] for k, r in self.event_log.items()
                   if k[:3] == event_key and k[3] == rec_op
                   and k[4] is not None]
            self._qstats["rows_returned"] += len(out)
            return out

    def consumers_of(self, event_key) -> List[str]:
        with self.lock:
            self._qstats["rows_scanned"] += len(self.event_log)
            out = sorted({r["rec_op"] for k, r in self.event_log.items()
                          if k[:3] == event_key and r["rec_op"] is not None})
            self._qstats["rows_returned"] += len(out)
            return out

    # filtered lineage queries (native pushdown) -------------------------
    supports_query_pushdown = True

    def _count(self, scanned: int, out):
        self._qstats["rows_scanned"] += scanned
        self._qstats["rows_returned"] += len(out)
        return out

    def query_lineage_insets(self, event_key,
                             flt: Optional[LineageFilter] = None
                             ) -> List[str]:
        k3 = tuple(event_key)
        if flt is not None and not flt.matches(k3[0], k3[1], k3[2]):
            return []
        with self.lock:
            out = list(self._lin_by_out.get(k3, ()))
            return self._count(len(out), out)

    def query_inset_events(self, rec_op: str, inset_id: str,
                           flt: Optional[LineageFilter] = None
                           ) -> List[Tuple]:
        with self.lock:
            keys = self._by_rec_inset.get((rec_op, inset_id), ())
            out = sorted(k[:3] for k in keys
                         if flt is None or flt.matches(k[0], k[1], k[2]))
            return self._count(len(keys), out)

    def query_inset_outputs(self, send_op: str, inset_id: str,
                            flt: Optional[LineageFilter] = None
                            ) -> List[Tuple]:
        with self.lock:
            keys = self._lin_by_inset.get((send_op, inset_id), ())
            out = sorted(k for k in keys
                         if flt is None or flt.matches(k[0], k[1], k[2]))
            return self._count(len(keys), out)

    def query_event_insets(self, event_key, rec_op: str,
                           flt: Optional[LineageFilter] = None
                           ) -> List[str]:
        k3 = tuple(event_key)
        if flt is not None and not flt.matches(k3[0], k3[1], k3[2]):
            return []
        with self.lock:
            keys = self._by_key3.get(k3, ())
            out = [k[4] for k in keys if k[3] == rec_op and k[4] is not None]
            return self._count(len(keys), out)

    def query_consumers(self, event_key,
                        flt: Optional[LineageFilter] = None) -> List[str]:
        with self.lock:
            keys = self._by_key3.get(tuple(event_key), ())
            recs = {k[3] for k in keys if k[3] is not None}
            if flt is not None and flt.ops is not None:
                recs &= flt.ops
            return self._count(len(keys), sorted(recs))

    def query_lineage(self, flt: Optional[LineageFilter] = None
                      ) -> List[Tuple]:
        """Bulk audit scan over EVENT_LINEAGE. With an ``ops`` filter the
        scan walks only those senders' inset buckets; otherwise it walks the
        full lineage list."""
        with self.lock:
            if flt is not None and flt.ops is not None:
                scanned = 0
                out = []
                for (so, ins), keys in self._lin_by_inset.items():
                    if so not in flt.ops:
                        continue
                    scanned += len(keys)
                    out.extend((so2, sp, eid, ins)
                               for (so2, sp, eid) in keys
                               if flt.matches(so2, sp, eid))
                return self._count(scanned, sorted(out))
            out = [(so, sp, eid, ins) for (eid, so, sp, ins) in self.lineage
                   if flt is None or flt.matches(so, sp, eid)]
            return self._count(len(self.lineage), sorted(out))

    def get_event_payload(self, event_key):
        with self.lock:
            blob = self.event_data.get(tuple(event_key))
            if blob is None:
                return None
            return self._load_blob(blob)

    def _query_stats(self) -> Dict[str, int]:
        with self.lock:
            return dict(self._qstats)

    def reset_query_stats(self):
        with self.lock:
            for k in self._qstats:
                self._qstats[k] = 0

    # GC (Sec. 3.6) --------------------------------------------------------
    def gc(self, lineage_ops: Iterable[str] = (),
           keep_rows: Optional[bool] = None):
        """``keep_rows`` overrides the "lineage exists => keep rows" guard —
        a sharded store must evaluate it globally, not per shard (lineage
        rows live only in the producing operator's shard)."""
        keep_data_for = set(lineage_ops)
        with self.lock:
            if keep_rows is None:
                keep_rows = bool(self.lineage)
            for k, r in list(self.event_log.items()):
                if r["status"] == DONE and k[0] not in keep_data_for:
                    # the payload serves every receiver of the event: drop
                    # it only once ALL rows for the key are done, or a
                    # straggling receiver would recover an empty body
                    if all(self.event_log[k2]["status"] == DONE
                           for k2 in self._by_key3.get(k[:3], ())):
                        self.event_data.pop(k[:3], None)
                    if not keep_rows:
                        self._del_row(k)


# ---------------------------------------------------------------------------
# Null backend — the benchmarks' "execution baseline" (no rollback recovery)
# ---------------------------------------------------------------------------

class NullLogStore(MemoryLogStore):
    """No-op store: pipelines run with zero logging (no recovery possible).
    Used to measure the paper's 'execution baseline' (Sec. 9.3.1)."""

    def _commit(self, ops):
        return None
