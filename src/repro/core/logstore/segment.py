"""Append-only file-segment backend with checkpoint compaction.

The "DB for metadata, files for logs" split: transaction records live in
append-only *segment files* (binary length+crc framed, pickled op lists),
while a small JSON *sidecar index* names the files that constitute the
store — the current checkpoint image plus the ordered segment list. All
record I/O is sequential appends to the active segment; SQLite-style page
management never touches the hot path, which is why ``segment+group``
out-runs ``sqlite+group`` on ``benchmarks/logstore_throughput.py``.

Layout of the store directory::

    index.json            {"format": 1, "filegen": N,
                           "checkpoint": "ckpt-000007.binz" | null,
                           "segments": ["seg-000008.logz", "seg-000009.log"]}
    seg-000009.log        active segment (append + fsync)
    seg-000008.logz       sealed segment (zlib, background sealer thread)
    ckpt-000007.binz      checkpoint image (pickled tables + floors)

Record frame: ``<u32 payload_len, u32 crc32, i64 epoch>`` + payload. A torn
tail frame (killed mid-append) fails the length/crc check and is dropped —
it can only ever be the one in-flight commit whose durability was never
acknowledged. ``epoch >= 0`` tags 2PC prepare records of the global-flush-
epoch protocol (see ``logstore/epoch.py``); records of epochs that never
committed are skipped on open and physically purged by an immediate
compaction, so a reissued epoch id can never resurrect them.

**Checkpoint compaction** (Sec. 3.6 meets write-ahead-lineage truncation):
``compact()`` garbage-collects done events, captures the whole table image
(plus the ssn/ack floors that pin the recovery counters past the truncated
records) into a new checkpoint file, opens a fresh active segment, and
atomically swaps ``index.json`` (write-tmp + fsync + ``os.replace`` +
directory fsync). A crash at ANY point leaves either the complete old index
or the complete new one — never a torn store — because every file the new
index references is fsynced before the swap and old files are deleted only
after it. Recovery then loads the checkpoint image and replays only the
records after it: O(checkpoint interval), not O(pipeline lifetime).
"""
from __future__ import annotations

import json
import os
import pickle
import struct
import threading
import zlib
from typing import List, Optional, Tuple

from repro.core.logstore.base import LineageFilter, TxnAborted
from repro.core.logstore.memory import MemoryLogStore

_FRAME = struct.Struct("<IIq")      # payload_len, crc32(payload), epoch|-1
_INDEX = "index.json"


def _read_frames(fpath: str):
    """Yield (epoch|None, ops) per intact frame of one segment file; a
    torn/corrupt tail frame (killed mid-append) ends the segment."""
    try:
        with open(fpath, "rb") as f:
            data = f.read()
    except FileNotFoundError:
        return
    if fpath.endswith(".logz"):
        data = zlib.decompress(data)
    off = 0
    while off + _FRAME.size <= len(data):
        ln, crc, ep = _FRAME.unpack_from(data, off)
        start = off + _FRAME.size
        if start + ln > len(data):
            break
        payload = data[start:start + ln]
        if zlib.crc32(payload) != crc:
            break
        yield (None if ep < 0 else ep), pickle.loads(payload)
        off = start + ln


def _summarize_lineage(summ: dict, ops):
    """Fold a record's put_lineage rows into a per-sender [min_eid, max_eid]
    segment summary — the sidecar skip index of the lineage reader."""
    for op in ops:
        if op[0] != "put_lineage":
            continue
        eid, sop = op[1], op[2]
        rng = summ.get(sop)
        if rng is None:
            summ[sop] = [eid, eid]
        else:
            if eid < rng[0]:
                rng[0] = eid
            if eid > rng[1]:
                rng[1] = eid


def _fsync_dir(path: str):
    """Make a rename/create in ``path`` durable (no-op where unsupported)."""
    if hasattr(os, "O_DIRECTORY"):
        fd = os.open(path, os.O_DIRECTORY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)


class SegmentLogStore(MemoryLogStore):
    """Durable LogBackend over append-only segment files + sidecar index.

    ``path`` is a directory (created on demand). ``segment_bytes`` is the
    rotation threshold for the active segment; sealed segments are zlib-
    compressed when ``compress`` is set. ``checkpoint_interval`` > 0 makes
    ``checkpoint_due()`` fire every that-many appended records — the engine
    supervision loops call ``maybe_checkpoint()``; 0 leaves compaction to
    explicit ``checkpoint()``/``compact()`` calls.
    """

    supports_checkpoint = True

    def __init__(self, path: str, *, segment_bytes: int = 4 * 1024 * 1024,
                 compress: bool = True, checkpoint_interval: int = 0,
                 epoch_coord=None):
        super().__init__(eager_serialize=True)
        self.path = path
        self.segment_bytes = segment_bytes
        self.compress = compress
        self.checkpoint_interval = checkpoint_interval
        self.epoch_coord = epoch_coord
        self.replayed_records = 0
        self.records_since_checkpoint = 0
        self.compactions = 0
        self.rotations = 0
        # test hook: called with a stage label at compaction/rotation
        # control points so crash tests can die at an exact protocol point
        self.test_hook = None
        self._fh = None
        # background sealer: zlib of sealed segments runs OFF the commit
        # path (a 4MB zlib-6 pass inline would stall every committer for
        # tens of ms at each rotation)
        self._gen = 0
        self._seal_q: List[Tuple[str, int]] = []
        self._seal_cv = threading.Condition()
        self._seal_thread: Optional[threading.Thread] = None
        self._closing = False
        self._open()

    # ---- filenames -------------------------------------------------------
    def _next_name(self, prefix: str, suffix: str) -> str:
        self._filegen += 1
        return f"{prefix}-{self._filegen:06d}{suffix}"

    def _fpath(self, name: str) -> str:
        return os.path.join(self.path, name)

    def _hook(self, stage: str):
        if self.test_hook is not None:
            self.test_hook(stage)

    # ---- open / replay ---------------------------------------------------
    def _open(self):
        self._gen += 1          # invalidate queued background seals
        with self._seal_cv:
            self._seal_q.clear()
        os.makedirs(self.path, exist_ok=True)
        ipath = self._fpath(_INDEX)
        if os.path.exists(ipath):
            with open(ipath, "r") as f:
                idx = json.load(f)
        else:
            idx = {"format": 1, "filegen": 0, "checkpoint": None,
                   "segments": []}
        self._filegen = idx["filegen"]
        self._checkpoint_file: Optional[str] = idx["checkpoint"]
        self._segments: List[str] = list(idx["segments"])

        if self._checkpoint_file is not None:
            self._load_checkpoint(self._checkpoint_file)

        self.replayed_records = 0
        dead_epochs = False
        per_seg: dict = {}
        for name in self._segments:
            summ = per_seg.setdefault(name, {})
            for epoch, ops in self._read_segment(name):
                if epoch is not None and self.epoch_coord is not None \
                        and not self.epoch_coord.is_committed(epoch):
                    # 2PC prepare record of an epoch that never committed
                    dead_epochs = True
                    continue
                try:
                    self._validate(ops)
                except TxnAborted:
                    continue
                self._apply_ops(ops)
                _summarize_lineage(summ, ops)
                self.replayed_records += 1
        self.records_since_checkpoint = self.replayed_records

        if self._segments and self._segments[-1].endswith(".log"):
            active = self._segments[-1]
            fresh_index = False
        else:
            active = self._next_name("seg", ".log")
            self._segments.append(active)
            fresh_index = True
        # sidecar lineage summaries: frozen (non-active) segments carry a
        # per-sender event-id range so a reader can skip them wholesale; the
        # active segment keeps accumulating in _active_lin and is always
        # scanned until rotation freezes it
        self._lin_summary = {n: per_seg.get(n, {}) for n in self._segments
                             if n != active}
        self._active_lin = per_seg.get(active, {})
        self._fh = open(self._fpath(active), "ab")
        self._active_size = os.path.getsize(self._fpath(active))
        if fresh_index:
            self._write_index()
        self._clean_orphans()
        if dead_epochs:
            # physically purge the dead prepare records: the coordinator may
            # reissue the same epoch id after a restart, and a later commit
            # of the reissued id must not resurrect these
            self.compact()
        if self.compress:
            # sealed segments whose background compression a crash cut
            # short are plain .log files before the active one — resume
            for name in self._segments[:-1]:
                if name.endswith(".log"):
                    self._enqueue_seal(name)

    def _load_checkpoint(self, name: str):
        with open(self._fpath(name), "rb") as f:
            blob = f.read()
        if name.endswith("z"):
            blob = zlib.decompress(blob)
        img = pickle.loads(blob)
        self.event_log = img["event_log"]
        self.event_data = img["event_data"]
        self.read_actions = img["read_actions"]
        self.state = img["state"]
        self.lineage = img["lineage"]
        self._ssn_floor = img["ssn_floor"]
        self._ack_floor = img["ack_floor"]
        self._reindex()

    def _read_segment(self, name: str):
        """Yield (epoch|None, ops) per intact frame; a torn/corrupt tail
        frame (killed mid-append) ends the segment."""
        yield from _read_frames(self._fpath(name))

    def _clean_orphans(self):
        """Remove segment/checkpoint files the index no longer references —
        leftovers of a crash between file creation and index swap (either
        direction: the swap is the only commit point)."""
        live = set(self._segments)
        if self._checkpoint_file is not None:
            live.add(self._checkpoint_file)
        for name in os.listdir(self.path):
            if name == _INDEX or name in live:
                continue
            if name.startswith(("seg-", "ckpt-", _INDEX + ".")):
                try:
                    os.remove(self._fpath(name))
                except OSError:
                    pass

    # ---- index swap (the atomicity point) --------------------------------
    def _write_index(self):
        idx = {"format": 1, "filegen": self._filegen,
               "checkpoint": self._checkpoint_file,
               "segments": self._segments,
               "lineage_summary": self._lin_summary}
        tmp = self._fpath(_INDEX + ".tmp")
        with open(tmp, "w") as f:
            json.dump(idx, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._fpath(_INDEX))
        _fsync_dir(self.path)

    # ---- append path -----------------------------------------------------
    def _append_record(self, ops, epoch: Optional[int] = None):
        payload = pickle.dumps(ops)
        frame = _FRAME.pack(len(payload), zlib.crc32(payload),
                            -1 if epoch is None else epoch)
        self._fh.write(frame)
        self._fh.write(payload)
        _summarize_lineage(self._active_lin, ops)
        self._active_size += _FRAME.size + len(payload)
        self.bytes_written += _FRAME.size + len(payload)
        self.records_since_checkpoint += 1

    def _sync(self):
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def _commit(self, ops):
        with self.lock:
            self._validate(ops)
            self._apply_ops(ops)
            self._append_record(ops)
            self._sync()                          # durable point
            self._maybe_rotate()
        return None

    def _commit_routed(self, ops):
        """Shard-protocol entry: caller holds ``shard_lock``, already
        validated."""
        self._apply_ops(ops)
        self._append_record(ops)
        self._sync()
        self._maybe_rotate()
        return None

    def apply_many(self, batches: List[List[Tuple]],
                   epoch: Optional[int] = None):
        """One fsync for the whole batch (the group-commit win: sequential
        appends + a single durable point). With ``epoch`` the records are
        2PC prepare records — durable but conditional on the epoch-commit
        record."""
        with self.lock:
            for ops in batches:
                try:
                    self._validate(ops)
                except TxnAborted:
                    continue
                self._apply_ops(ops)
                self._append_record(ops, epoch=epoch)
            self._sync()                          # durable point, once
            self._maybe_rotate()
        return None

    # ---- rotation + background sealed-segment compression ----------------
    def _maybe_rotate(self):
        """Seal the active segment and start a fresh one. The hot path only
        opens the new file and swaps the index; compressing the sealed
        segment happens on the background sealer thread — the index simply
        keeps referencing the plain ``.log`` until the durable ``.logz``
        swap lands (a second index write), so every crash window still
        resolves to a complete store."""
        if self._active_size < self.segment_bytes:
            return
        old = self._segments[-1]
        self._fh.close()
        active = self._next_name("seg", ".log")
        self._segments.append(active)
        self._fh = open(self._fpath(active), "ab")
        self._active_size = 0
        # freeze the sealed segment's lineage summary (an empty dict is
        # meaningful: it proves the segment holds no lineage rows)
        self._lin_summary[old] = self._active_lin
        self._active_lin = {}
        self._hook("rotate:pre_index")
        self._write_index()                       # commit point of rotation
        self.rotations += 1
        if self.compress:
            self._enqueue_seal(old)

    def _enqueue_seal(self, name: str):
        with self._seal_cv:
            self._seal_q.append((name, self._gen))
            if self._seal_thread is None or not self._seal_thread.is_alive():
                self._seal_thread = threading.Thread(
                    target=self._seal_loop, daemon=True, name="seg-sealer")
                self._seal_thread.start()
            self._seal_cv.notify()

    def _seal_loop(self):
        while True:
            with self._seal_cv:
                while not self._seal_q and not self._closing:
                    self._seal_cv.wait()
                if not self._seal_q:
                    return
                name, gen = self._seal_q.pop(0)
            try:
                self._seal_one(name, gen)
            except OSError:
                pass    # store dir vanished under us (tests tearing down)

    def _seal_one(self, name: str, gen: int):
        """Compress one sealed segment and durably swap it into the index.
        zlib runs without any lock; only the swap itself synchronizes with
        committers. A generation or membership mismatch (crash()/reopen or
        a compaction truncated the segment meanwhile) abandons the swap."""
        # level 1: sealed segments are short-lived once checkpointing runs
        # (the next compaction deletes them), and on small machines the
        # sealer shares cores with committers — cheap beats dense here
        with open(self._fpath(name), "rb") as f:
            zdata = zlib.compress(f.read(), 1)
        sealed = name[:-len(".log")] + ".logz"
        tmp = self._fpath(sealed + ".tmp")
        with open(tmp, "wb") as f:
            f.write(zdata)
            f.flush()
            os.fsync(f.fileno())
        with self.lock:
            if gen != self._gen or name not in self._segments:
                try:
                    os.remove(tmp)
                except OSError:
                    pass
                return
            os.replace(tmp, self._fpath(sealed))
            self._segments[self._segments.index(name)] = sealed
            if name in self._lin_summary:
                self._lin_summary[sealed] = self._lin_summary.pop(name)
            self._write_index()
            try:
                os.remove(self._fpath(name))
            except OSError:
                pass

    def _drain_seals(self, timeout: float = 10.0):
        """Wait for queued background compressions to finish (close path —
        keeps test tmpdirs and shutdowns deterministic)."""
        with self._seal_cv:
            self._closing = True
            self._seal_cv.notify_all()
            t = self._seal_thread
        if t is not None and t.is_alive():
            t.join(timeout)
        self._closing = False

    # ---- checkpoint compaction (the truncation watermark) ----------------
    def _advance_floors(self):
        """Pin the per-port recovery counters at their pre-truncation
        maxima, so GC of done rows cannot rewind ``last_sent_ssn`` /
        ``last_acked`` after a restart from the checkpoint."""
        for k, r in self.event_log.items():
            if k[1] is not None:
                key = (k[0], k[1])
                if k[2] > self._ssn_floor.get(key, -1):
                    self._ssn_floor[key] = k[2]
            if k[4] is not None and r["rec_op"] is not None:
                key = (r["rec_op"], r["rec_port"])
                if k[2] > self._ack_floor.get(key, -1):
                    self._ack_floor[key] = k[2]

    def compact(self, keep_rows: Optional[bool] = None):
        """Checkpoint + truncate: GC done events, write the live image as a
        checkpoint file, start a fresh active segment, atomically swap the
        index, and only then delete the truncated files. Kill -9 anywhere
        in here leaves either the old store or the new one — never a torn
        mix — because ``os.replace`` of the index is the single commit
        point and both sides' files are fsynced before it. ``keep_rows``
        overrides the local lineage guard — a sharded stack evaluates it
        globally."""
        with self.lock:
            self._advance_floors()
            self.gc(self.gc_protect, keep_rows=keep_rows)
            img = {"event_log": self.event_log,
                   "event_data": self.event_data,
                   "read_actions": self.read_actions,
                   "state": self.state,
                   "lineage": self.lineage,
                   "ssn_floor": self._ssn_floor,
                   "ack_floor": self._ack_floor}
            blob = pickle.dumps(img)
            if self.compress:
                blob = zlib.compress(blob, 6)
            ckpt = self._next_name("ckpt", ".binz" if self.compress
                                   else ".bin")
            with open(self._fpath(ckpt), "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())

            self._fh.close()
            active = self._next_name("seg", ".log")
            self._fh = open(self._fpath(active), "ab")
            self._active_size = 0

            old_files = list(self._segments)
            if self._checkpoint_file is not None:
                old_files.append(self._checkpoint_file)
            self._checkpoint_file = ckpt
            self._segments = [active]
            # truncated segments' lineage rows now live in the checkpoint
            # image; the summaries die with the files they described
            self._lin_summary = {}
            self._active_lin = {}
            self._hook("compact:pre_swap")
            self._write_index()                   # the atomic swap
            self._hook("compact:post_swap")
            for name in old_files:
                try:
                    os.remove(self._fpath(name))
                except OSError:
                    pass
            self.records_since_checkpoint = 0
            self.compactions += 1

    # LogBackend checkpoint interface
    def checkpoint(self):
        self.compact()

    def checkpoint_due(self) -> bool:
        return self.checkpoint_interval > 0 and \
            self.records_since_checkpoint >= self.checkpoint_interval

    def recovery_replay_count(self) -> int:
        return self.replayed_records

    # ---- disk accounting (the bounded-size acceptance metric) ------------
    def disk_bytes(self) -> int:
        with self.lock:
            total = 0
            for name in os.listdir(self.path):
                try:
                    total += os.path.getsize(self._fpath(name))
                except OSError:
                    pass
            return total

    # ---- crash / close ---------------------------------------------------
    def crash(self):
        """Simulated process crash: every acknowledged commit was fsynced,
        so rebuilding from the files IS the durable image; prepared-but-
        uncommitted epoch records are skipped (and purged) like a real
        restart would."""
        with self.lock:
            try:
                self._fh.close()
            except OSError:
                pass
            self.event_log = {}
            self.event_data = {}
            self.read_actions = {}
            self.state = {}
            self.lineage = []
            self._ssn_floor = {}
            self._ack_floor = {}
            self._reindex()
            self._open()

    def close(self):
        with self.lock:
            if self._fh is not None and not self._fh.closed:
                self._sync()
                self._fh.close()
        self._drain_seals()

    def lineage_reader(self) -> "SegmentLineageReader":
        """Offline lineage scanner over this store's directory (flushes
        first so every committed row is on disk)."""
        with self.lock:
            self._sync()
        return SegmentLineageReader(self.path)


class SegmentLineageReader:
    """Read-only lineage scanner over a SegmentLogStore directory — the
    "audit the log without opening the store" path.

    Answers the filtered lineage-row queries straight from the files: the
    checkpoint image contributes its (already-compacted) lineage list, and
    segments are visited only when their sidecar ``lineage_summary`` entry
    (per-sender [min_eid, max_eid] ranges, frozen at rotation) can overlap
    the filter — a segment with no entry (the active one) is always
    scanned. Within a scanned segment, 2PC epoch tags are matched against
    the filter's ``epoch_min``/``epoch_max`` hints frame by frame.

    The reader sees the durable image only (unflushed commits are
    invisible) and does not consult an epoch coordinator — on a store using
    global flush epochs, quiesce or close the store before auditing.
    ``stats`` exposes the skip/scan counters the pushdown benchmark
    asserts on.
    """

    def __init__(self, path: str):
        self.path = path
        self.stats = {"segments_scanned": 0, "segments_skipped": 0,
                      "frames_scanned": 0, "rows_scanned": 0,
                      "rows_returned": 0}

    def _fpath(self, name: str) -> str:
        return os.path.join(self.path, name)

    def _load_index(self) -> dict:
        with open(self._fpath(_INDEX), "r") as f:
            return json.load(f)

    @staticmethod
    def _skip(summ: Optional[dict], flt: Optional[LineageFilter]) -> bool:
        """True iff the summary proves no row in the segment matches."""
        if summ is None:
            return False            # no summary (active segment): must scan
        if flt is None:
            return not summ         # empty summary == provably no lineage
        for sop, (lo, hi) in summ.items():
            if flt.ops is not None and sop not in flt.ops:
                continue
            if flt.ssn_min is not None and hi < flt.ssn_min:
                continue
            if flt.ssn_max is not None and lo > flt.ssn_max:
                continue
            return False            # this sender's range may overlap
        return True

    def _iter_rows(self, flt: Optional[LineageFilter]):
        """Yield every durable (send_op, send_port, event_id, inset) row a
        matching query must consider; counts scan effort in ``stats``."""
        idx = self._load_index()
        summary = idx.get("lineage_summary", {})
        if idx.get("checkpoint"):
            with open(self._fpath(idx["checkpoint"]), "rb") as f:
                blob = f.read()
            if idx["checkpoint"].endswith("z"):
                blob = zlib.decompress(blob)
            for (eid, so, sp, ins) in pickle.loads(blob)["lineage"]:
                self.stats["rows_scanned"] += 1
                yield so, sp, eid, ins
        for name in idx["segments"]:
            if self._skip(summary.get(name), flt):
                self.stats["segments_skipped"] += 1
                continue
            self.stats["segments_scanned"] += 1
            for epoch, ops in _read_frames(self._fpath(name)):
                if flt is not None and epoch is not None:
                    if flt.epoch_min is not None and epoch < flt.epoch_min:
                        continue
                    if flt.epoch_max is not None and epoch > flt.epoch_max:
                        continue
                self.stats["frames_scanned"] += 1
                for op in ops:
                    if op[0] == "put_lineage":
                        self.stats["rows_scanned"] += 1
                        yield op[2], op[3], op[1], op[4]

    def query_lineage(self, flt: Optional[LineageFilter] = None
                      ) -> List[Tuple]:
        out = sorted((so, sp, eid, ins)
                     for (so, sp, eid, ins) in self._iter_rows(flt)
                     if flt is None or flt.matches(so, sp, eid))
        self.stats["rows_returned"] += len(out)
        return out

    def query_lineage_insets(self, event_key,
                             flt: Optional[LineageFilter] = None
                             ) -> List[str]:
        so, sp, eid = tuple(event_key)
        if flt is not None and not flt.matches(so, sp, eid):
            return []
        key_flt = LineageFilter(ops=frozenset([so]), ssn_min=eid,
                                ssn_max=eid,
                                epoch_min=None if flt is None
                                else flt.epoch_min,
                                epoch_max=None if flt is None
                                else flt.epoch_max)
        out = [ins for (so2, sp2, eid2, ins) in self._iter_rows(key_flt)
               if (so2, sp2, eid2) == (so, sp, eid)]
        self.stats["rows_returned"] += len(out)
        return out

    def query_inset_outputs(self, send_op: str, inset_id: str,
                            flt: Optional[LineageFilter] = None
                            ) -> List[Tuple]:
        base = LineageFilter(ops=frozenset([send_op]))
        out = sorted((so, sp, eid)
                     for (so, sp, eid, ins) in self._iter_rows(base)
                     if so == send_op and ins == inset_id
                     and (flt is None or flt.matches(so, sp, eid)))
        self.stats["rows_returned"] += len(out)
        return out

    def query_stats(self):
        return dict(self.stats)

    def reset_query_stats(self):
        for k in self.stats:
            self.stats[k] = 0
