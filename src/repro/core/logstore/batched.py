"""Group-commit transaction pipelining (commit batching).

The paper's evaluation (Sec. 9) shows per-event pessimistic logging is
LOG.io's overhead driver at high throughput; write-ahead lineage capture
with batched/asynchronous flushing (arXiv:2403.08062) closes that gap
without giving up recoverability. ``GroupCommitStore`` applies that idea at
the log-store layer:

  * ``commit`` validates + applies the transaction to a *speculative view*
    immediately (non-blocking — the operator keeps processing) and enqueues
    the ops into the pending batch; it returns an integer durability token.
  * the pending batch is flushed to the durable inner backend when a
    size/time watermark is reached (``batch_size`` txns or ``interval``
    seconds), advancing the durability watermark; a flush of a SQLite inner
    store is ONE SQLite transaction for the whole batch.
  * the **durability-watermark rule**: externally visible effects — channel
    acks and external-system writes — may only be released once
    ``is_durable(token)`` is true. The operator runtime defers them
    (``OperatorRuntime.drain_durable``), which preserves exactly-once
    recovery semantics while commits pipeline.
  * ``crash()`` simulates a full-process failure: the pending batch is lost
    and the view is rebuilt from the durable image — a crash between
    flushes loses exactly the unflushed batch.
  * as a shard of an epoch-flushing :class:`ShardedLogStore`, the store
    additionally speaks the global-flush-epoch protocol (see
    ``logstore/epoch.py``): ``cut_pending`` snapshots the batch under an
    epoch id, ``persist_prepared`` writes it to the durable medium tagged
    with the epoch (prepare: durable but conditional), and ``finish_epoch``
    advances the durability watermark once the coordinator has committed
    the epoch. A crash rolls back prepared-but-uncommitted epochs.

Without an inner backend the durable image is simulated by retaining the
flushed op history (the moral equivalent of the SQLite WAL, in memory);
engine-level pod failures never lose the store either way.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.core.logstore.base import LogBackend, TxnAborted
from repro.core.logstore.memory import MemoryLogStore


class GroupCommitStore(LogBackend):

    def __init__(self, inner: Optional[LogBackend] = None, *,
                 batch_size: int = 64, interval: float = 0.005,
                 epoch_coord=None):
        self.inner = inner
        self.batch_size = batch_size
        self.interval = interval
        self.epoch_coord = epoch_coord
        self.view = MemoryLogStore(eager_serialize=False)
        if inner is not None:
            # warm restart over a pre-existing durable image (e.g. a SQLite
            # file from before a process crash): serve it from the view
            self.view.load_image(inner)
        self._pending: List[Tuple[int, List[Tuple]]] = []   # (token, ops)
        self._first_ts: Optional[float] = None
        # epoch_id -> cut-but-not-yet-committed batch (2PC prepare buffer)
        self._prepared: Dict[int, List[Tuple[int, List[Tuple]]]] = {}
        self._durable_history: List[List[Tuple]] = []   # inner=None only
        self.commit_seq = 0
        self.durable_seq = 0
        self._lost_tokens: set = set()      # commits dropped by crash()
        self.flushes = 0
        # async flush I/O: commits enqueue + nudge; the flusher thread owns
        # the inner-backend writes (io-overlap — the operator thread never
        # blocks on fsync).  Serialized against explicit flush()/crash()/
        # checkpoint() by _flush_serial (RLock: checkpoint calls flush).
        # Lock order everywhere: _flush_serial -> view.lock.
        self._flush_serial = threading.RLock()
        self._flush_wake = threading.Event()
        self._flusher: Optional[threading.Thread] = None
        self._flusher_stop = False

    # ---- commit (speculative apply + enqueue) ---------------------------
    def _commit(self, ops):
        with self.view.lock:
            self.view._validate(ops)
            first = not self._pending
            token = self._commit_routed(ops)
            nudge = first or self._watermark_reached()
        # standalone store only: as a shard of an epoch-flushing
        # ShardedLogStore, commits arrive via _commit_routed and the
        # sharded store's epoch flusher owns all flush I/O
        if self._flusher is None:
            self._ensure_flusher()
        if nudge:
            self._flush_wake.set()
        return token

    def _ensure_flusher(self):
        with self.view.lock:
            if self._flusher is None and not self._flusher_stop:
                t = threading.Thread(target=self._flusher_loop, daemon=True,
                                     name="group-commit-flusher")
                self._flusher = t
                t.start()

    def _flusher_loop(self):
        while True:
            ts = self._first_ts
            timeout = None if ts is None else \
                max(0.0, ts + self.interval - time.monotonic())
            self._flush_wake.wait(timeout)
            self._flush_wake.clear()
            if self._flusher_stop:
                return
            if self._watermark_reached():
                self.flush()

    def _commit_routed(self, ops) -> int:
        """Shard-protocol entry: caller holds ``shard_lock`` and has
        already validated the ops against this shard's image."""
        self.view._apply_ops(ops)
        self.commit_seq += 1
        self._pending.append((self.commit_seq, ops))
        if self._first_ts is None:
            self._first_ts = time.monotonic()
        return self.commit_seq

    def _watermark_reached(self) -> bool:
        # callers may probe without the lock: snapshot the fields once so a
        # concurrent flush() nulling them cannot blow up mid-expression
        pending = self._pending
        if not pending:
            return False
        if len(pending) >= self.batch_size:
            return True
        ts = self._first_ts
        # monotonic, not wall-clock: an NTP step must neither stall the
        # interval watermark forever nor fire it spuriously
        return ts is not None and time.monotonic() - ts >= self.interval

    # ---- durability ------------------------------------------------------
    def is_durable(self, token) -> bool:
        return token is None or \
            (token <= self.durable_seq and token not in self._lost_tokens)

    def flush(self):
        with self._flush_serial:
            with self.view.lock:
                batch, self._pending = self._pending, []
                self._first_ts = None
            if not batch:
                return
            ops_lists = [ops for _, ops in batch]
            # the inner-backend write runs OUTSIDE view.lock: commits keep
            # applying to the speculative view while the I/O is in flight
            # (_flush_serial keeps concurrent flushes from reordering)
            if self.inner is not None:
                self.inner.apply_many(ops_lists)
            else:
                self._durable_history.extend(ops_lists)
            with self.view.lock:
                # the watermark is the last flushed token — tokens are never
                # reused, so commits lost in a crash() stay non-durable
                self.durable_seq = max(self.durable_seq, batch[-1][0])
                self.flushes += 1

    def maybe_flush(self):
        # racy fast path: flush() re-checks under the lock.  With the
        # flusher running this is just a nudge — the caller (the operator
        # loop's drain_durable) never blocks on flush I/O.
        if self._watermark_reached():
            if self._flusher is not None and not self._flusher_stop:
                self._flush_wake.set()
            else:
                self.flush()

    # ---- global flush epochs (2PC shard side; see logstore/epoch.py) -----
    def cut_pending(self, epoch_id: int) -> List[Tuple[int, List[Tuple]]]:
        """Phase 1a: atomically cut the pending batch under the epoch id.
        Called under the sharded store's exclusive epoch barrier — no
        transaction can straddle the cut. No I/O here."""
        with self.view.lock:
            batch, self._pending = self._pending, []
            self._first_ts = None
            if batch:
                self._prepared[epoch_id] = batch
            return batch

    def persist_prepared(self, epoch_id: int):
        """Phase 1b (prepare): persist the cut batch tagged with the epoch.
        Durable but conditional — it only counts if the epoch commits.
        Runs WITHOUT any shard lock (the I/O is off the commit path)."""
        batch = self._prepared.get(epoch_id)
        if batch and self.inner is not None:
            self.inner.apply_many([ops for _, ops in batch], epoch=epoch_id)
        # inner=None: the prepare buffer itself plays the conditional
        # durable medium; crash() consults the coordinator's verdict.

    def finish_epoch(self, epoch_id: int):
        """Phase 2: the coordinator committed the epoch — advance the
        durability watermark past the epoch's tokens."""
        with self.view.lock:
            batch = self._prepared.pop(epoch_id, None)
            if not batch:
                return
            if self.inner is None:
                self._durable_history.extend(ops for _, ops in batch)
            self.durable_seq = max(self.durable_seq, batch[-1][0])
            self.flushes += 1

    def crash(self):
        """Full-process crash: lose the unflushed batch, roll back
        prepared-but-uncommitted epochs, rebuild the view from the durable
        image (prepared batches of *committed* epochs are durable — the
        epoch-commit record is the atomicity point).  Holding
        ``_flush_serial`` first parks the crash at a flush-protocol
        quiescent point: an in-flight async flush either completed (its
        batch is durable) or never started (its batch is lost) — never
        half-applied."""
        with self._flush_serial, self.view.lock:
            # tokens of the lost commits must never read as durable, even
            # once later commits push the watermark past their numbers
            self._lost_tokens.update(t for t, _ in self._pending)
            self._pending = []
            self._first_ts = None
            for eid, batch in sorted(self._prepared.items()):
                if self.epoch_coord is not None and \
                        self.epoch_coord.is_committed(eid):
                    # committed before the crash: the prepared batch is
                    # durable even though finish_epoch never ran
                    if self.inner is None:
                        self._durable_history.extend(ops for _, ops in batch)
                    self.durable_seq = max(self.durable_seq, batch[-1][0])
                else:
                    self._lost_tokens.update(t for t, _ in batch)
            self._prepared = {}
            fresh = MemoryLogStore(eager_serialize=False)
            if self.inner is not None:
                self.inner.crash()
                fresh.load_image(self.inner)
            else:
                for ops in self._durable_history:
                    try:
                        fresh._validate(ops)
                    except TxnAborted:
                        continue
                    fresh._apply_ops(ops)
            self.view = fresh

    def _stop_flusher(self):
        self._flusher_stop = True
        self._flush_wake.set()
        t = self._flusher
        if t is not None and t is not threading.current_thread():
            t.join(timeout=2.0)
        self._flusher = None

    def close(self):
        self._stop_flusher()
        self.flush()
        if self.inner is not None:
            self.inner.close()

    # ---- checkpoint compaction (forwarded to a durable inner) ------------
    @property
    def supports_checkpoint(self):
        return getattr(self.inner, "supports_checkpoint", False)

    def checkpoint_due(self):
        return self.inner is not None and self.inner.checkpoint_due()

    def checkpoint(self):
        """Flush the pending batch (so the checkpoint covers every durable
        commit), compact the inner store, then truncate the view the same
        way. NOT epoch-safe: a shard of an epoch-flushing ShardedLogStore
        is checkpointed via ``ShardedLogStore.checkpoint`` instead, which
        runs the epoch protocol and then calls ``_checkpoint_inner``."""
        if not self.supports_checkpoint:
            return
        with self._flush_serial:        # no async flush mid-compaction
            self.flush()
            self._checkpoint_inner()

    def _checkpoint_inner(self, keep_rows=None):
        """Compact the durable inner and mirror the truncation into the
        speculative view (floors + GC), keeping the two read images
        aligned. Caller has already made the pending work durable."""
        self.inner.compact(keep_rows=keep_rows)
        with self.view.lock:
            self.view._ssn_floor = dict(self.inner._ssn_floor)
            self.view._ack_floor = dict(self.inner._ack_floor)
            self.view.gc(self.gc_protect, keep_rows=keep_rows)

    def maybe_checkpoint(self):
        if self.checkpoint_due():
            self.checkpoint()

    def set_gc_protect(self, ops):
        self.gc_protect = frozenset(ops)
        if self.inner is not None:
            self.inner.set_gc_protect(ops)

    def recovery_replay_count(self):
        return self.inner.recovery_replay_count() \
            if self.inner is not None else 0

    # ---- shard protocol --------------------------------------------------
    def image(self) -> MemoryLogStore:
        return self.view

    @property
    def shard_lock(self):
        return self.view.lock

    # ---- bookkeeping -----------------------------------------------------
    @property
    def commits(self):
        return self.view.commits

    @property
    def bytes_written(self):
        return self.view.bytes_written + \
            (self.inner.bytes_written if self.inner is not None else 0)

    # ---- queries: the speculative view is the read image ----------------
    def fetch_resend_events(self, op_id):
        return self.view.fetch_resend_events(op_id)

    def fetch_ack_events(self, op_id, include_done=False):
        return self.view.fetch_ack_events(op_id, include_done=include_done)

    def fetch_replay_outputs(self, op_id):
        return self.view.fetch_replay_outputs(op_id)

    def undone_outputs_after(self, op_id, port, min_id):
        return self.view.undone_outputs_after(op_id, port, min_id)

    def get_write_actions(self, op_id):
        return self.view.get_write_actions(op_id)

    def get_state(self, op_id):
        return self.view.get_state(op_id)

    def last_sent_ssn(self, op_id):
        return self.view.last_sent_ssn(op_id)

    def last_acked(self, op_id):
        return self.view.last_acked(op_id)

    def event_status(self, key, rec_op=None):
        return self.view.event_status(key, rec_op)

    def get_read_action(self, op_id, conn_id):
        return self.view.get_read_action(op_id, conn_id)

    def undone_events_from(self, send_op, rec_op):
        return self.view.undone_events_from(send_op, rec_op)

    def lineage_insets_of(self, event_key):
        return self.view.lineage_insets_of(event_key)

    def lineage_events_of_inset(self, rec_op, inset_id):
        return self.view.lineage_events_of_inset(rec_op, inset_id)

    def lineage_outputs_of_inset(self, send_op, inset_id):
        return self.view.lineage_outputs_of_inset(send_op, inset_id)

    def insets_of_event(self, event_key, rec_op):
        return self.view.insets_of_event(event_key, rec_op)

    def consumers_of(self, event_key):
        return self.view.consumers_of(event_key)

    # filtered lineage queries: the speculative view (a MemoryLogStore with
    # native indexes) answers — committed-but-unflushed rows included
    @property
    def supports_query_pushdown(self):
        return getattr(self.view, "supports_query_pushdown", False)

    def query_lineage_insets(self, event_key, flt=None):
        return self.view.query_lineage_insets(event_key, flt)

    def query_inset_events(self, rec_op, inset_id, flt=None):
        return self.view.query_inset_events(rec_op, inset_id, flt)

    def query_inset_outputs(self, send_op, inset_id, flt=None):
        return self.view.query_inset_outputs(send_op, inset_id, flt)

    def query_event_insets(self, event_key, rec_op, flt=None):
        return self.view.query_event_insets(event_key, rec_op, flt)

    def query_consumers(self, event_key, flt=None):
        return self.view.query_consumers(event_key, flt)

    def query_lineage(self, flt=None):
        return self.view.query_lineage(flt)

    def get_event_payload(self, event_key):
        return self.view.get_event_payload(event_key)

    def _query_stats(self):
        return self.view._query_stats()

    def reset_query_stats(self):
        self.view.reset_query_stats()

    def gc(self, lineage_ops=(), keep_rows=None):
        self.view.gc(lineage_ops, keep_rows=keep_rows)
        if self.inner is not None:
            self.inner.gc(lineage_ops, keep_rows=keep_rows)
