"""Dynamic scaling (Sec. 7.2, Algorithms 12-13): Dispatcher + Merger +
Controller.

Scale-up: deploy replica (warm start), Merger state update, Dispatcher state
update — each acknowledged only after persisting the new state in STATE.

Scale-down: the Dispatcher (a) updates its state, (b) computes the set O of
"undone" events previously sent to the removed replica, (c) atomically
reassigns them (new destination + new event ids) together with storing its
state — mutually exclusive with the replica's generation transaction (which
marks InSets done with ``require_rows``), and (d) re-sends events of O that
are still undone. Then the Merger drops the input and topology is updated.

Process mode (``Engine(mode="process")``): the Dispatcher/Merger state
lives in their worker processes, so the controller pauses those two
workers, performs the state updates against STATE in the shared log (the
same blobs recovery uses — "acknowledged" == persisted, exactly Alg 12's
contract), rewires the supervisor's authoritative channels, and
warm-restarts the workers, which recover the updated state. Replicas, the
source and the sink keep processing throughout — only the two topology
parties restart, on live worker processes.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional, Tuple

from repro.core.transport import Channel
from repro.core.events import UNDONE, Event
from repro.core.operator import Operator, OperatorRuntime


class DispatcherOperator(Operator):
    """Round-robin (optionally key-based) dispatch to replica output ports.

    Global state: the routing table (active replicas) + rr counter.
    Output ports are ``to_<replica_id>``.
    """
    input_ports = ("in",)

    def __init__(self, op_id: str, replicas: List[str],
                 key_fn: Optional[Callable[[Any], int]] = None,
                 *, processing_time: float = 0.0):
        self.routes = list(replicas)          # global state
        self.rr = 0                           # global state
        self.output_ports = tuple(f"to_{r}" for r in replicas)
        super().__init__(op_id, processing_time=processing_time)
        self.key_fn = key_fn
        self._queue: List[Tuple[str, Any]] = []

    def on_event(self, event: Event, *, recovery_inset=None) -> List[str]:
        inset = recovery_inset or self.runtime.new_inset_id()
        self._queue.append((inset, event.body))
        return [inset]

    def update_global(self, event: Event):
        pass    # rr advances at generation (persisted with the txn)

    def global_state(self):
        return {"routes": list(self.routes), "rr": self.rr}

    def restore_global(self, blob):
        if blob:
            self.routes = list(blob["routes"])
            self.rr = blob["rr"]
            self._sync_ports()

    def _sync_ports(self):
        self.output_ports = tuple(f"to_{r}" for r in self.routes)
        for p in self.output_ports:
            self.out_channels.setdefault(p, [])
            self.runtime.ctx.ssn.setdefault(p, 0)

    def triggers(self) -> List[str]:
        return [i for i, _ in self._queue]

    def generate(self, inset_id: str):
        body = dict(self._queue)[inset_id]
        if self.key_fn is not None:
            r = self.routes[self.key_fn(body) % len(self.routes)]
        else:
            r = self.routes[self.rr % len(self.routes)]
            self.rr += 1
        return [(f"to_{r}", body)], []

    def clear_inset(self, inset_id: str):
        self._queue = [(i, b) for i, b in self._queue if i != inset_id]


class MergerOperator(Operator):
    """Bundles replica streams into one output stream. Input ports are
    ``from_<replica_id>``; active inputs are global state."""
    output_ports = ("out",)

    def __init__(self, op_id: str, replicas: List[str],
                 *, processing_time: float = 0.0):
        self.inputs = list(replicas)          # global state
        self.input_ports = tuple(f"from_{r}" for r in replicas)
        super().__init__(op_id, processing_time=processing_time)
        self._queue: List[Tuple[str, Any]] = []

    def on_event(self, event: Event, *, recovery_inset=None) -> List[str]:
        inset = recovery_inset or self.runtime.new_inset_id()
        self._queue.append((inset, event.body))
        return [inset]

    def global_state(self):
        return {"inputs": list(self.inputs)}

    def restore_global(self, blob):
        if blob:
            self.inputs = list(blob["inputs"])
            self._sync_ports()

    def _sync_ports(self):
        self.input_ports = tuple(f"from_{r}" for r in self.inputs)
        ctx = self.runtime.ctx
        for p in self.input_ports:
            ctx.last_acked.setdefault(p, -1)
            ctx.global_updated.setdefault(p, -1)

    def triggers(self) -> List[str]:
        return [i for i, _ in self._queue]

    def generate(self, inset_id: str):
        return [("out", dict(self._queue)[inset_id])], []

    def clear_inset(self, inset_id: str):
        self._queue = [(i, b) for i, b in self._queue if i != inset_id]


class Controller:
    """Central scaling controller (the paper's Controller, Sec. 7.2).
    Load-monitoring strategies are out of scope (as in the paper) — tests and
    examples call scale_up/scale_down directly."""

    def __init__(self, engine, dispatcher_id: str, merger_id: str,
                 replica_factory: Callable[[str], Callable[[], Operator]],
                 replica_out_port: str = "out",
                 replica_in_port: str = "in", capacity: int = 256):
        self.e = engine
        self.disp_id = dispatcher_id
        self.merger_id = merger_id
        self.replica_factory = replica_factory
        self.rp_out, self.rp_in = replica_out_port, replica_in_port
        self.capacity = capacity
        self.lock = threading.Lock()

    def _reassign_undone(self, disp, rt, replica_id: str, send_fn):
        """Algorithm 13 steps 1.b-1.d against a dispatcher view (the live
        operator in thread mode, a STATE-restored copy in process mode).
        ``send_fn`` re-sends a still-undone reassigned event."""
        e = self.e
        # Step 1.b: set O = undone events sent to the replica + new ids
        keys = e.store.undone_events_from(self.disp_id, replica_id)
        assignments = []
        for key in keys:
            tgt = disp.routes[disp.rr % len(disp.routes)]
            disp.rr += 1
            new_port = f"to_{tgt}"
            new_id = rt.ctx.ssn.get(new_port, 0)
            rt.ctx.ssn[new_port] = new_id + 1
            assignments.append((key, new_port, tgt, self.rp_in, new_id))
        # Step 1.c: atomic reassignment + dispatcher state store. Mutual
        # exclusion with the replica's generation txn: events that turned
        # "done" in the meantime are skipped at apply time.
        txn = e.store.begin()
        for old_key, new_port, tgt, tport, new_id in assignments:
            txn.reassign_event(old_key, replica_id,
                               (self.disp_id, new_port, new_id), tgt, tport)
        txn.put_state(self.disp_id, rt.new_state_id(), rt._state_blob(),
                      keep_history=rt.keep_state_history)
        txn.commit()
        # Step 1.d: re-send events of O that are still undone (one indexed
        # scan, not a rescan per assignment)
        resend = {(ev.send_port, ev.event_id): ev
                  for ev, _st in e.store.fetch_resend_events(self.disp_id)}
        for old_key, new_port, tgt, tport, new_id in assignments:
            still_undone = any(
                status == UNDONE and ins is None
                for ins, status in e.store.event_status(
                    (self.disp_id, new_port, new_id)))
            ev = resend.get((new_port, new_id))
            if still_undone and ev is not None:
                send_fn(ev)

    def _drain_replica_channels(self, replica_id: str, timeout: float = 5.0):
        """Block until the dying replica's in/out channels are empty and it
        is not mid-transaction (its op_lock is free), so deleting its
        channels cannot lose a logged-and-sent output. Best effort: on
        timeout the topology update proceeds (the replica may be wedged)."""
        import time as _time
        e = self.e
        rt = e.runtimes.get(replica_id)
        deadline = _time.time() + timeout
        while _time.time() < deadline:
            chans = [ch for ch in e.channels
                     if ch.rec_op == replica_id or ch.send_op == replica_id]
            if rt is None:
                if all(len(c) == 0 for c in chans):
                    return
            else:
                with rt.op_lock:     # no handle_input/generate in flight
                    if all(len(c) == 0 for c in chans) \
                            and not rt._deferred:
                        return
            _time.sleep(0.002)

    # -- process-mode helpers (state updates against STATE in the log) ------
    def _restored(self, op_id: str):
        """Fresh operator instance + runtime with its global state and
        LOG.io context restored from the shared log — the parent-side view
        of a paused worker's state. Mirrors the worker's runtime config
        (lineage ports, keep_state_history) so persisting through it
        cannot truncate a lineage-keeping operator's STATE history."""
        e = self.e
        op = e.pipeline.factories[op_id]()
        lin_in, lin_out = getattr(e, "_lineage_ports", {}).get(
            op_id, (set(), set()))
        rt = OperatorRuntime(op, e.store, lineage_in=lin_in,
                             lineage_out=lin_out, external=e.external,
                             keep_state_history=bool(lin_out))
        rt.restore_state()
        return op, rt

    def _persist_rt(self, rt: OperatorRuntime):
        txn = self.e.store.begin()
        txn.put_state(rt.op.id, rt.new_state_id(), rt._state_blob(),
                      keep_history=rt.keep_state_history)
        txn.commit()

    def _scale_up_process(self, replica_id: str):
        e = self.e
        drv = e._proc
        disp_group = e.pipeline.groups[self.disp_id]
        merger_group = e.pipeline.groups[self.merger_id]
        # pause the two topology parties; their volatile state is exactly
        # what recovery rebuilds from STATE + the log
        drv.stop_group(disp_group)
        if merger_group != disp_group:
            drv.stop_group(merger_group)
        # Step 1: deploy replica + create the two connections
        factory = self.replica_factory(replica_id)
        e.pipeline.factories[replica_id] = factory
        e.pipeline.groups[replica_id] = replica_id
        cap = self.capacity          # the new channels' credit windows
        e.pipeline.connections.append(
            (self.disp_id, f"to_{replica_id}", replica_id, self.rp_in, cap))
        e.pipeline.connections.append(
            (replica_id, self.rp_out, self.merger_id,
             f"from_{replica_id}", cap))
        e.channels.append(Channel(self.disp_id, f"to_{replica_id}",
                                  replica_id, self.rp_in, cap))
        e.channels.append(Channel(replica_id, self.rp_out, self.merger_id,
                                  f"from_{replica_id}", cap))
        e.group_state[replica_id] = "running"
        # Step 2: Merger state update (ack = state persisted)
        m_op, m_rt = self._restored(self.merger_id)
        if replica_id not in m_op.inputs:
            m_op.inputs.append(replica_id)
        self._persist_rt(m_rt)
        # Step 3: Dispatcher state update
        d_op, d_rt = self._restored(self.disp_id)
        if replica_id not in d_op.routes:
            d_op.routes.append(replica_id)
        self._persist_rt(d_rt)
        # resume: replica fresh, dispatcher/merger recover the new state
        drv.start_group(replica_id, recover=False)
        drv.start_group(disp_group, recover=True)
        if merger_group != disp_group:
            drv.start_group(merger_group, recover=True)
        drv.pump_all()

    def _scale_down_process(self, replica_id: str):
        e = self.e
        drv = e._proc
        disp_group = e.pipeline.groups[self.disp_id]
        merger_group = e.pipeline.groups[self.merger_id]
        drv.stop_group(disp_group)
        # Step 1.a: dispatcher state update (remove route)
        d_op, d_rt = self._restored(self.disp_id)
        if replica_id in d_op.routes:
            d_op.routes.remove(replica_id)
            d_op._sync_ports()

        def send_to_channel(ev):
            # transport-dependent re-send: the routed supervisor absorbs
            # the already-logged event into its authoritative buffer (the
            # bounded reassignment set, not the stream, sizes this); the
            # socket transport does nothing — the dispatcher is restarted
            # with recover=True below and its log recovery resends every
            # undone + unacknowledged output, reassigned ones included
            drv.transport.reinject(ev)

        # Steps 1.b-1.d; the replica keeps RUNNING — the reassignment
        # transaction is mutually exclusive with its generation
        # transactions by validation
        self._reassign_undone(d_op, d_rt, replica_id, send_to_channel)
        # drain: replica + merger keep running until the replica's channels
        # are empty — its logged-and-sent outputs must reach the merger
        # before the channels are deleted (step 3)
        drv.wait_group_drained(replica_id)
        # Step 2: merger update
        drv.stop_group(replica_id, remove=True)
        if merger_group != disp_group:
            drv.stop_group(merger_group)
        m_op, m_rt = self._restored(self.merger_id)
        if replica_id in m_op.inputs:
            m_op.inputs.remove(replica_id)
        self._persist_rt(m_rt)
        # Step 3: update topology — delete connections + replica
        e.pipeline.connections = [
            c for c in e.pipeline.connections
            if c[0] != replica_id and c[2] != replica_id]
        e.channels = [c for c in e.channels
                      if c.send_op != replica_id and c.rec_op != replica_id]
        e.group_state[replica_id] = "removed"
        e.ops.pop(replica_id, None)
        e.pipeline.factories.pop(replica_id, None)
        e.pipeline.groups.pop(replica_id, None)
        drv.start_group(disp_group, recover=True)
        if merger_group != disp_group:
            drv.start_group(merger_group, recover=True)
        drv.pump_all()

    # -- Algorithm 12 -------------------------------------------------------
    def scale_up(self, replica_id: str):
        # compact first when due: the topology parties re-restore from the
        # log around the update, so the reads should hit the checkpoint
        # image plus a bounded tail, not the full pipeline history
        self.e.store.maybe_checkpoint()
        if self.e.mode == "process":
            with self.lock:
                return self._scale_up_process(replica_id)
        with self.lock:
            e = self.e
            # Step 1: deploy replica + create the two connections (warm start)
            factory = self.replica_factory(replica_id)
            e.pipeline.factories[replica_id] = factory
            e.pipeline.groups[replica_id] = replica_id
            cap = 1_000_000 if e.mode == "step" else self.capacity
            e.pipeline.connections.append(
                (self.disp_id, f"to_{replica_id}", replica_id, self.rp_in, cap))
            e.pipeline.connections.append(
                (replica_id, self.rp_out, self.merger_id,
                 f"from_{replica_id}", cap))
            ch1 = Channel(self.disp_id, f"to_{replica_id}", replica_id,
                          self.rp_in, cap)
            ch2 = Channel(replica_id, self.rp_out, self.merger_id,
                          f"from_{replica_id}", cap)
            e.channels += [ch1, ch2]
            op = factory()
            e.ops[replica_id] = op
            e._wire(op)
            e.runtimes[replica_id] = OperatorRuntime(
                op, e.store, external=e.external, crash_point=e.injector,
                stop_flag=e._stop.is_set)
            e.group_state[replica_id] = "running"
            # Step 2: Merger state update (ack = state persisted) — under
            # its op_lock so the update serializes with its processing
            merger = e.ops[self.merger_id]
            with e.runtimes[self.merger_id].op_lock:
                merger.inputs.append(replica_id)
                merger._sync_ports()
                e._wire(merger)
                self._persist(merger)
            # Step 3: Dispatcher state update
            disp = e.ops[self.disp_id]
            with e.runtimes[self.disp_id].op_lock:
                disp.routes.append(replica_id)
                disp._sync_ports()
                e._wire(disp)
                self._persist(disp)
        if self.e.mode == "thread":
            self.e._start_group(replica_id, recover=False)

    # -- Algorithm 13 -------------------------------------------------------
    def scale_down(self, replica_id: str):
        self.e.store.maybe_checkpoint()
        if self.e.mode == "process":
            with self.lock:
                return self._scale_down_process(replica_id)
        with self.lock:
            e = self.e
            disp = e.ops[self.disp_id]
            rt = e.runtimes[self.disp_id]
            # Steps 1.a-1.d run under the dispatcher's op_lock: its state
            # update must be serialized with its own generation — without
            # this, a generate() that picked the dying replica before 1.a
            # can log its event AFTER the 1.b snapshot, stranding it in the
            # channel that step 3 deletes (a lost event).
            with rt.op_lock:
                # Step 1.a: dispatcher state update (remove route)
                if replica_id in disp.routes:
                    disp.routes.remove(replica_id)
                    disp._sync_ports()
                # Steps 1.b-1.d (shared with process mode)
                self._reassign_undone(disp, rt, replica_id, rt._send)
            # drain: the replica's channels must empty before the topology
            # update — step 3 deletes them, and an output the replica
            # already logged+sent but the merger has not yet consumed would
            # be lost with the buffer (nobody resends it: the replica is
            # being removed). The replica keeps running here: stale inputs
            # abort at assign-insets (their rows were reassigned) and ack.
            self._drain_replica_channels(replica_id)
            # Step 2: merger update
            merger = e.ops[self.merger_id]
            if replica_id in merger.inputs:
                merger.inputs.remove(replica_id)
                merger._sync_ports()
            self._persist(merger)
            # Step 3: update topology — delete connections + replica
            e.pipeline.connections = [
                c for c in e.pipeline.connections
                if c[0] != replica_id and c[2] != replica_id]
            e.channels = [c for c in e.channels
                          if c.send_op != replica_id and c.rec_op != replica_id]
            e.group_state[replica_id] = "removed"
            e.ops.pop(replica_id, None)
            e.pipeline.factories.pop(replica_id, None)
            e.pipeline.groups.pop(replica_id, None)
            e._wire(disp)
            e._wire(merger)

    def _persist(self, op: Operator):
        rt = self.e.runtimes[op.id]
        txn = self.e.store.begin()
        txn.put_state(op.id, rt.new_state_id(), rt._state_blob(),
                      keep_history=rt.keep_state_history)
        txn.commit()
