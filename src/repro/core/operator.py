"""LOG.io operators + the per-operator protocol runtime (Algorithms 1-5).

The runtime owns the LOG.io context (Sec. 3.4): SSN counters per output port,
the last-acked event id per input port (obsolete filter), the array of latest
event ids that updated the global state, and the InSet counter. The context
is serialized into STATE inside the same atomic transaction that logs each
Output Set (Step 4 of Algorithm 3) — the *only* state LOG.io checkpoints;
event state is always rebuilt from logged input events on recovery.

User-defined operators implement small hooks; the runtime implements the
protocol, exposing the paper's API (Tables 7-9) via ``LogioAPI``.
"""
from __future__ import annotations

import pickle
import threading
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.events import DONE, REPLAY, UNDONE, Event, ReadAction
from repro.core.logstore import LogBackend, TxnAborted


class SimulatedCrash(Exception):
    """Raised by the failure injector at a crash point; the engine treats it
    as the operator's pod dying (volatile state lost, logs+channels live)."""


class ExternalSystem:
    """Durable external system accepting write actions (Sec. 2.2).

    Write actions must be *checkable* (status()) or idempotent. The default
    implementation is a durable KV/list sink keyed by (op_id, conn_id,
    event_id) — checkable and idempotent.
    """

    def __init__(self, fail_rate: float = 0.0):
        self.lock = threading.Lock()
        self.writes: Dict[Tuple, Any] = {}
        self.order: List[Tuple] = []

    def execute(self, op_id: str, conn_id: str, event_id: int, body) -> bool:
        with self.lock:
            k = (op_id, conn_id, event_id)
            if k not in self.writes:
                self.writes[k] = body
                self.order.append(k)
            return True

    def status(self, op_id: str, conn_id: str, event_id: int) -> str:
        with self.lock:
            return "success" if (op_id, conn_id, event_id) in self.writes \
                else "unknown"

    def committed(self) -> List[Any]:
        with self.lock:
            return [self.writes[k] for k in self.order]


class ReadSource:
    """External system serving read actions. ``effect(action, from_offset)``
    returns the action's effect — a list of record batches. Replayable
    sources return a superset on later reads (Sec. 2.2)."""

    def __init__(self, batches: Sequence[Any], replayable: bool = True):
        self._batches = list(batches)
        self.replayable = replayable

    def effect(self, desc: str, from_offset: int = 0) -> List[Any]:
        return self._batches[from_offset:]


# ---------------------------------------------------------------------------
# Context
# ---------------------------------------------------------------------------

class LogioContext:
    """In-memory LOG.io context, serialized into STATE."""

    def __init__(self, op: "Operator"):
        self.ssn = {p: 0 for p in op.output_ports}       # next event_id per port
        self.write_ssn: Dict[str, int] = {}              # per connection
        self.last_acked = {p: -1 for p in op.input_ports}
        self.global_updated = {p: -1 for p in op.input_ports}
        self.inset_counter = 0
        self.read_offset = 0                             # source resume point
        self.state_counter = 0

    def snapshot(self) -> dict:
        return dict(ssn=dict(self.ssn), write_ssn=dict(self.write_ssn),
                    global_updated=dict(self.global_updated),
                    inset_counter=self.inset_counter,
                    read_offset=self.read_offset,
                    state_counter=self.state_counter)

    def restore(self, d: dict):
        self.ssn.update(d.get("ssn", {}))
        self.write_ssn.update(d.get("write_ssn", {}))
        self.global_updated.update(d.get("global_updated", {}))
        self.inset_counter = d.get("inset_counter", 0)
        self.read_offset = d.get("read_offset", 0)
        self.state_counter = d.get("state_counter", 0)


# ---------------------------------------------------------------------------
# Operator base
# ---------------------------------------------------------------------------

class Operator:
    """Base class. Subclasses define ports + hooks; the engine wires
    channels and drives ``step()`` (normal processing) after ``recover()``.
    """
    input_ports: Tuple[str, ...] = ("in",)
    output_ports: Tuple[str, ...] = ("out",)

    #: operators that are deterministic AND have lineage on all ports may be
    #: run as replay operators (Sec. 5) — no payload logging.
    deterministic: bool = True

    def __init__(self, op_id: str, *, processing_time: float = 0.0):
        self.id = op_id
        self.processing_time = processing_time
        # wiring (set by the engine)
        self.in_channels: Dict[str, Any] = {}
        self.out_channels: Dict[str, List[Any]] = {p: [] for p in self.output_ports}
        self.runtime: Optional["OperatorRuntime"] = None
        self.state = "running"         # running | dead | restarted | replay

    # ---- hooks ----------------------------------------------------------
    def on_event(self, event: Event, *, recovery_inset: Optional[str] = None
                 ) -> List[str]:
        """State Update (Alg 2 step 2): update event state, return the
        InSet_IDs assigned to this event. Stateless default: fresh singleton
        inset per event."""
        return [self.runtime.new_inset_id()]

    def update_global(self, event: Event) -> None:
        """Update the global state from one event (counters/timers)."""

    def triggers(self) -> List[str]:
        """Return InSet_IDs whose generation should fire now."""
        return list(self._pending_singletons())

    def generate(self, inset_id: str) -> Tuple[List[Tuple[str, Any]],
                                               List[Tuple[str, Any]]]:
        """Compute the Output Set for an Input Set.

        Returns (outputs, writes): outputs = [(port, body)], writes =
        [(conn_id, body)]. May call ``self.runtime.read_action(...)`` for
        side-effect reads (Alg 4)."""
        raise NotImplementedError

    def global_state(self) -> Any:
        return None

    def restore_global(self, blob: Any) -> None:
        pass

    def clear_inset(self, inset_id: str) -> None:
        """Input Sets with done events are emptied (Alg 3 step 4)."""

    def has_pending(self) -> bool:
        """True while the operator holds undelivered work the engine's
        idle-drain detection must wait for (e.g. a train-feed sink whose
        consumer has not acknowledged all batches)."""
        return False

    # ---- helpers ---------------------------------------------------------
    def _pending_singletons(self):
        return getattr(self, "_singleton_insets", [])

    def simulate_work(self):
        if self.processing_time > 0:
            time.sleep(self.processing_time)


# ---------------------------------------------------------------------------
# Protocol runtime
# ---------------------------------------------------------------------------

class OperatorRuntime:
    """Implements LOG.io normal processing for one operator instance."""

    def __init__(self, op: Operator, store: LogBackend, *,
                 lineage_in: Iterable[str] = (), lineage_out: Iterable[str] = (),
                 external: Optional[ExternalSystem] = None,
                 crash_point: Callable[[str, str], None] = lambda op, pt: None,
                 stop_flag: Callable[[], bool] = lambda: False,
                 replay_mode: bool = False,
                 keep_state_history: bool = False,
                 state_interval: int = 1):
        self.op = op
        op.runtime = self
        self.store = store
        self.ctx = LogioContext(op)
        # "epoch" recovery mode: snapshot state every N generate txns
        # instead of every txn (ABS-style amortization on the LOG.io log).
        # Lineage-scoped ops pin to 1 — their per-InSet state history IS
        # the lineage record.  Transactions carrying write actions always
        # snapshot regardless (write SSNs have no log-scan recovery floor,
        # so a stale write_ssn would reissue colliding write event ids).
        self.state_interval = 1 if keep_state_history \
            else max(1, int(state_interval))
        self._since_state = 0
        self.lineage_in = set(lineage_in)
        self.lineage_out = set(lineage_out)
        self.external = external or ExternalSystem()
        self.crash_point = crash_point
        self.stop_flag = stop_flag
        self.replay_mode = replay_mode      # Sec. 5: no payload logging
        self.keep_state_history = keep_state_history
        self.pending_reads: List[Tuple[ReadAction, Any]] = []
        self.stats = {"events_in": 0, "events_out": 0, "txns": 0,
                      # recovery-replay accounting (the bounded-replay
                      # claim: with checkpoint compaction these stay
                      # O(records since the last checkpoint))
                      "recovered_resends": 0, "recovered_inputs": 0,
                      # vectored recovery reads (one range scan per
                      # operator per table, not per-event iteration)
                      "recovery_scan_batches": 0,
                      # micro-batched hot path (runs of >1 event applied
                      # through one vectored transaction)
                      "batched_runs": 0, "batched_events": 0,
                      # metrics-plane latency accounting (cumulative µs):
                      # time inside store commits / blocked in credit-gated
                      # channel puts — the controller's mode signals
                      "commit_us": 0, "send_stall_us": 0}
        #: optional :class:`repro.core.batching.BatchGovernor`; set by the
        #: engine/worker when micro-batching is enabled for this operator
        self.governor = None
        # externally visible effects (channel acks, external-system writes)
        # awaiting the store's durability watermark (group commit); FIFO
        self._deferred: List[Tuple[Any, Callable[[], None]]] = []
        # guards ctx mutations when an external driver (train loop) calls
        # generate() concurrently with the engine thread's handle_input()
        self.op_lock = threading.RLock()

    # ---- durability-watermark rule (group-commit pipelining) --------------
    def _after_durable(self, token, fn: Callable[[], None]):
        """Run ``fn`` once the commit behind ``token`` is durable. Plain
        backends are durable at commit, so this is immediate for them.
        Effects release strictly FIFO: once one is queued behind the
        watermark, every later effect queues behind it (external writes must
        reach the external system in commit order)."""
        if not self._deferred and self.store.is_durable(token):
            fn()
        else:
            self._deferred.append((token, fn))

    def _ack(self, ch, token):
        """Release the channel ack for the event just logged — immediately
        when durable, else deferred until the batch flushes."""
        if not self._deferred and self.store.is_durable(token):
            ch.ack()
        else:
            ch.defer_ack()
            self._deferred.append((token, ch.release_ack))

    def drain_durable(self, force: bool = False) -> bool:
        """Release deferred effects whose commits became durable, in FIFO
        order, stopping at the first still-volatile one. Called by the
        engine between steps; ``force`` flushes the store first.
        Returns True if anything was released."""
        if not self._deferred:
            return False
        if force:
            self.store.flush()
        else:
            self.store.maybe_flush()
        released = False
        with self.op_lock:
            while self._deferred and \
                    self.store.is_durable(self._deferred[0][0]):
                _, fn = self._deferred.pop(0)
                fn()
                released = True
        return released

    # ---- id generation (paper API: GetActionID / GetStateID / InSet ids) --
    def new_inset_id(self) -> str:
        self.ctx.inset_counter += 1
        return f"{self.op.id}:{self.ctx.inset_counter}"

    def new_state_id(self) -> int:
        self.ctx.state_counter += 1
        return self.ctx.state_counter

    def next_ssn(self, port: str) -> int:
        ssn = self.ctx.ssn[port]
        self.ctx.ssn[port] = ssn + 1
        return ssn

    def next_write_ssn(self, conn: str) -> int:
        ssn = self.ctx.write_ssn.get(conn, 0)
        self.ctx.write_ssn[conn] = ssn + 1
        return ssn

    # ---- serialization ----------------------------------------------------
    def _state_blob(self) -> bytes:
        return pickle.dumps({"ctx": self.ctx.snapshot(),
                             "global": self.op.global_state()})

    def restore_state(self):
        blob = self.store.get_state(self.op.id)
        if blob is not None:
            d = pickle.loads(blob)
            self.ctx.restore(d["ctx"])
            self.op.restore_global(d["global"])
        # advance SSNs past anything already logged (Alg 9 step 1)
        for port, last in self.store.last_sent_ssn(self.op.id).items():
            if port in self.ctx.ssn:
                self.ctx.ssn[port] = max(self.ctx.ssn[port], last + 1)
        for port, last in self.store.last_acked(self.op.id).items():
            if port in self.ctx.last_acked:
                self.ctx.last_acked[port] = max(
                    self.ctx.last_acked[port], last)

    def _commit(self, txn):
        """Commit with latency accounting (``commit_us`` feeds the
        adaptive controller's commit-share signal)."""
        t0 = time.perf_counter()
        try:
            return txn.commit()
        finally:
            self.stats["commit_us"] += int((time.perf_counter() - t0) * 1e6)

    # ---- normal processing: one input event (Algorithm 2) ----------------
    def handle_input(self, port: str, ev: Event) -> bool:
        """Peeked event at head of channel. Returns True if consumed."""
        with self.op_lock:
            return self._handle_input_locked(port, ev)

    def _handle_input_locked(self, port: str, ev: Event) -> bool:
        ch = self.op.in_channels[port]
        self.crash_point(self.op.id, "pre_filter")
        # Alg 11 step 4.a: while awaiting regenerated events on a port fed by
        # a replay operator, non-replay events there are stale FIFO residue
        # (the replay pred regenerates that whole suffix) — discard them.
        if (not ev.is_replay
                and getattr(self.op, "_awaiting_replay", None)
                and port in getattr(self.op, "_replay_pred_ports", ())):
            ch.ack()
            return True
        # Step 1: obsolete filter
        if self._obsolete(port, ev):
            ch.ack()
            return True
        if ev.is_replay and self._awaited(port, ev) is not None:
            return self._handle_replay_input(port, ev, ch)
        self.crash_point(self.op.id, "pre_state_update")
        # Step 2: state update
        if ev.event_id > self.ctx.global_updated.get(port, -1):
            self.op.update_global(ev)
            self.ctx.global_updated[port] = ev.event_id
        insets = self.op.on_event(ev)
        txn = self.store.begin()
        if ev.is_replay:   # regenerated-but-never-processed: back to normal
            txn.set_status((ev.send_op, ev.send_port, ev.event_id), UNDONE,
                           rec_op=self.op.id)
        txn.assign_insets((ev.send_op, ev.send_port, ev.event_id), insets,
                          rec_op=self.op.id)
        try:
            token = self._commit(txn)
        except TxnAborted:
            # the event was reassigned away (scale-down, Alg 13): drop it
            ch.ack()
            return True
        self.stats["txns"] += 1
        self.ctx.last_acked[port] = max(self.ctx.last_acked.get(port, -1),
                                        ev.event_id)
        self.crash_point(self.op.id, "post_ack_log")
        # event leaves the channel only once acknowledged — and the ack is
        # released only once its transaction is durable (watermark rule)
        self._ack(ch, token)
        self.stats["events_in"] += 1
        # Step 3: triggering
        for inset in self.op.triggers():
            self.generate(inset)
        return True

    # ---- normal processing: a run of input events (micro-batching) --------
    def handle_inputs(self, port: str, evs: List[Event]) -> int:
        """Vectored Algorithm 2: apply a *run* of peeked events through one
        log transaction and one coalesced ack pass. Returns the number of
        events consumed from the channel head (the caller acks nothing —
        consumption happens here, exactly as in ``handle_input``).

        Exactly-once at every batch boundary: the run's log records stay
        individually keyed, the whole run shares one commit (and thus one
        durability token), and channel acks are issued only after that
        commit — a crash anywhere in the run replays exactly the unacked
        suffix through the obsolete filter."""
        if len(evs) == 1:
            return 1 if self.handle_input(port, evs[0]) else 0
        with self.op_lock:
            return self._handle_inputs_locked(port, evs)

    def _handle_inputs_locked(self, port: str, evs: List[Event]) -> int:
        op = self.op
        ch = op.in_channels[port]
        awaiting = getattr(op, "_awaiting_replay", None)
        residue_ports = getattr(op, "_replay_pred_ports", ())
        # -- phase 1: classify + state-update, strictly in FIFO order ------
        plan: List[Tuple] = []     # ("drop", ev) | ("log", ev, insets)
        flips: List[Tuple] = []    # set_status_many entries (replay->UNDONE)
        last = self.ctx.last_acked.get(port, -1)
        for ev in evs:
            if ev.is_replay and self._awaited(port, ev) is not None:
                # an awaited regenerated event cuts the run: it takes the
                # scalar Example-10 path on the next engine pass
                break
            self.crash_point(op.id, "pre_filter")
            if (not ev.is_replay and awaiting
                    and port in residue_ports):
                plan.append(("drop", ev))       # stale FIFO residue
                continue
            if ev.event_id <= last:
                plan.append(("drop", ev))       # obsolete filter
                continue
            self.crash_point(op.id, "pre_state_update")
            if ev.event_id > self.ctx.global_updated.get(port, -1):
                op.update_global(ev)
                self.ctx.global_updated[port] = ev.event_id
            insets = op.on_event(ev)
            if ev.is_replay:    # regenerated-but-never-processed
                flips.append(((ev.send_op, ev.send_port, ev.event_id),
                              UNDONE, "*", op.id, None))
            plan.append(("log", ev, insets))
            last = max(last, ev.event_id)
        if not plan:
            # run cut at its own head (awaited replay event): take the
            # scalar Example-10 path now so a governed loop cannot spin
            return 1 if self._handle_input_locked(port, evs[0]) else 0
        # -- phase 2: ONE vectored transaction for the whole run -----------
        logged = [p for p in plan if p[0] == "log"]
        token = None
        if logged:
            txn = self.store.begin()
            if flips:
                txn.set_status_many(flips)
            for _, ev, insets in logged:
                txn.assign_insets((ev.send_op, ev.send_port, ev.event_id),
                                  insets, rec_op=op.id)
            try:
                token = self._commit(txn)
            except TxnAborted:
                # some event was reassigned away (Alg 13): fall back to
                # per-event commits, reusing the phase-1 state updates
                return self._apply_run_fallback(port, ch, plan)
            self.stats["txns"] += 1
            self.ctx.last_acked[port] = max(
                self.ctx.last_acked.get(port, -1), last)
            for _ in logged:
                self.crash_point(op.id, "post_ack_log")
            self.stats["events_in"] += len(logged)
            self.stats["batched_runs"] += 1
            self.stats["batched_events"] += len(logged)
        # -- phase 3: coalesced FIFO channel verbs -------------------------
        if not self._deferred and self.store.is_durable(token):
            ch.ack_run(len(plan))
        else:
            # interleave in plan order, coalescing same-verb stretches:
            # drops ack immediately, logged events defer behind the run's
            # single durability token (watermark rule)
            i = 0
            while i < len(plan):
                kind = plan[i][0]
                j = i
                while j < len(plan) and plan[j][0] == kind:
                    j += 1
                if kind == "drop":
                    ch.ack_run(j - i)
                else:
                    ch.defer_run(j - i)
                    for _ in range(i, j):
                        self._deferred.append((token, ch.release_ack))
                i = j
        trig = op.triggers()
        if trig:
            self.generate_many(trig)
        return len(plan)

    def _apply_run_fallback(self, port: str, ch, plan) -> int:
        """The run's vectored commit aborted: re-commit per event so only
        the reassigned-away events drop (scalar semantics). Phase 1 already
        applied the state updates — ``on_event`` must not run twice."""
        op = self.op
        consumed = 0
        for entry in plan:
            if entry[0] == "drop":
                ch.ack()
                consumed += 1
                continue
            _, ev, insets = entry
            txn = self.store.begin()
            if ev.is_replay:
                txn.set_status((ev.send_op, ev.send_port, ev.event_id),
                               UNDONE, rec_op=op.id)
            txn.assign_insets((ev.send_op, ev.send_port, ev.event_id),
                              insets, rec_op=op.id)
            try:
                token = self._commit(txn)
            except TxnAborted:
                ch.ack()
                consumed += 1
                continue
            self.stats["txns"] += 1
            self.ctx.last_acked[port] = max(
                self.ctx.last_acked.get(port, -1), ev.event_id)
            self.crash_point(op.id, "post_ack_log")
            self._ack(ch, token)
            self.stats["events_in"] += 1
            consumed += 1
        trig = op.triggers()
        if trig:
            self.generate_many(trig)
        return consumed

    def _awaited(self, port: str, ev: Event):
        for t in getattr(self.op, "_awaiting_replay", ()):
            if t[0] == port and t[1] == ev.event_id:
                return t
        return None

    def _obsolete(self, port: str, ev: Event) -> bool:
        # Example 10: a replay event the receiver never processed is handled
        # like a normal event; one already acked is obsolete — unless this
        # operator is explicitly awaiting it (Alg 11).
        if ev.is_replay and self._awaited(port, ev) is not None:
            return False
        return ev.event_id <= self.ctx.last_acked.get(port, -1)

    def _handle_replay_input(self, port: str, ev: Event, ch) -> bool:
        """Process an awaited regenerated event: re-mark UNDONE, assign its
        original InSet, update event state, trigger (Example 10)."""
        op = self.op
        match = [self._awaited(port, ev)]
        inset = match[0][2]
        txn = self.store.begin()
        txn.set_status((ev.send_op, ev.send_port, ev.event_id), UNDONE,
                       rec_op=self.op.id)
        token = self._commit(txn)
        if ev.event_id > self.ctx.global_updated.get(port, -1):
            op.update_global(ev)
            self.ctx.global_updated[port] = ev.event_id
        op.on_event(ev, recovery_inset=inset)
        op._awaiting_replay.discard(match[0])
        self.ctx.last_acked[port] = max(self.ctx.last_acked.get(port, -1),
                                        ev.event_id)
        self._ack(ch, token)
        self.stats["events_in"] += 1
        for ins2 in op.triggers():
            self.generate(ins2)
        return True

    # ---- generation (Algorithm 3) -----------------------------------------
    def generate(self, inset_id: str, *, replay_events: Optional[dict] = None):
        with self.op_lock:
            return self._generate_locked(inset_id, replay_events=replay_events)

    def _generate_locked(self, inset_id: str, *,
                         replay_events: Optional[dict] = None):
        op = self.op
        op.simulate_work()
        self.pending_reads = []
        outputs, writes = op.generate(inset_id)
        self.crash_point(op.id, "pre_log")
        # Step 3: assign SSNs
        out_events: List[Event] = []
        for port, body in outputs:
            # one SSN per port; the same event fans out per channel
            ssn = self.next_ssn(port)
            for ch in op.out_channels.get(port, []):
                out_events.append(Event(ssn, op.id, port, ch.rec_op,
                                        ch.rec_port, body=body))
        write_events: List[Event] = []
        for conn, body in writes:
            wssn = self.next_write_ssn(conn)
            write_events.append(Event(wssn, op.id, None, op.id, conn,
                                      body=body))
        # Step 2+4: atomic transaction — the run of new output events goes
        # through one vectored log_events op (single-op framing for the
        # segment/WAL append and one routing decision per run in the
        # sharded store); single-output transactions keep the scalar op
        # sequence byte-identical to the per-event path
        # "epoch" recovery mode skips the per-txn snapshot between
        # intervals; recovery then replays from the last snapshot with
        # DONE rows included (see recovery.recover_operator).  Write
        # actions always force a snapshot — stale write SSNs have no
        # recovery floor.
        snap_state = (self.state_interval <= 1 or bool(write_events)
                      or self._since_state + 1 >= self.state_interval)
        txn = self.store.begin()
        log_entries: List[Tuple[Event, str, Optional[str]]] = []
        data_events: List[Event] = []
        for e in out_events:
            if replay_events and (e.send_port, e.event_id) in replay_events:
                txn.set_status((e.send_op, e.send_port, e.event_id), UNDONE,
                               only_status=REPLAY)
                e.header["replay"] = True
            else:
                if not self.replay_mode and \
                        any(getattr(ch, "prefer_blob", False)
                            for ch in op.out_channels.get(e.send_port, ())):
                    # byte transport downstream: serialize the payload once
                    # here and share the encode between the log
                    # (put_event_blob) and the wire (superframe payload)
                    e.cache_blob()
                log_entries.append((e, UNDONE, None))
                if not self.replay_mode:
                    data_events.append(e)
        if len(log_entries) == 1:
            txn.log_event(log_entries[0][0], UNDONE)
        elif log_entries:
            txn.log_events(log_entries)
        for e in data_events:
            txn.put_event_data(e)
        for w in write_events:
            txn.log_event(w, UNDONE)
            txn.put_event_data(w)
        if snap_state:
            txn.put_state(op.id, self.new_state_id(), self._state_blob(),
                          keep_history=self.keep_state_history)
        txn.set_inset_status(op.id, inset_id, DONE, require_rows=True)
        if self.lineage_out:
            for ra, effect in self.pending_reads:
                rev = Event(ra.action_id, op.id, f"{ra.conn_id}.r", None, None,
                            body=effect)
                txn.log_event(rev, DONE, inset_id)
                txn.put_event_data(rev)
            seen = set()
            for e in out_events:
                if e.send_port in self.lineage_out and \
                        (e.send_port, e.event_id) not in seen:
                    txn.put_lineage(e.event_id, op.id, e.send_port, inset_id)
                    seen.add((e.send_port, e.event_id))
        try:
            token = self._commit(txn)
        except TxnAborted:
            # InSet vanished (scaled-down reassignment, Alg 13) — drop output
            for port, _ in outputs:
                self.ctx.ssn[port] -= 1     # roll back the SSN we took
            return
        self.stats["txns"] += 1
        self._since_state = 0 if snap_state else self._since_state + 1
        self.crash_point(op.id, "post_log")
        # Step 5: send — may pipeline ahead of durability (duplicates are
        # dropped by the receivers' obsolete filters on recovery)
        for e in out_events:
            self._send(e)
        self.stats["events_out"] += len(out_events)
        self.crash_point(op.id, "post_send")
        # Step 6: write actions (Algorithm 5) — externally visible, so they
        # are released only once the logging transaction is durable
        for w in write_events:
            self._after_durable(token, lambda w=w: self.execute_write(w))
        op.clear_inset(inset_id)

    def generate_many(self, inset_ids: Sequence[str]) -> None:
        """Vectored Algorithm 3 over a run of triggered Input Sets: all
        their Output Sets go through ONE atomic transaction (one vectored
        ``log_events``, one state snapshot, one commit) and one batched
        dispatch pass.  Used by the batched hot path only — recovery keeps
        the scalar per-InSet generates."""
        if len(inset_ids) == 1:
            return self.generate(inset_ids[0])
        with self.op_lock:
            return self._generate_many_locked(list(inset_ids))

    def _generate_many_locked(self, inset_ids: List[str]) -> None:
        op = self.op
        # SSN counters rewind to this snapshot if the vectored commit
        # aborts (scaled-down reassignment) and the run falls back to
        # scalar generates
        ssn_snap = dict(self.ctx.ssn)
        wssn_snap = dict(self.ctx.write_ssn)
        runs: List[Tuple[str, List[Event], List[Event], List[Tuple]]] = []
        for inset_id in inset_ids:
            op.simulate_work()
            self.pending_reads = []
            outputs, writes = op.generate(inset_id)
            self.crash_point(op.id, "pre_log")
            out_events: List[Event] = []
            for port, body in outputs:
                ssn = self.next_ssn(port)
                for ch in op.out_channels.get(port, []):
                    out_events.append(Event(ssn, op.id, port, ch.rec_op,
                                            ch.rec_port, body=body))
            write_events: List[Event] = []
            for conn, body in writes:
                wssn = self.next_write_ssn(conn)
                write_events.append(Event(wssn, op.id, None, op.id, conn,
                                          body=body))
            runs.append((inset_id, out_events, write_events,
                         list(self.pending_reads)))
        any_writes = any(r[2] for r in runs)
        snap_state = (self.state_interval <= 1 or any_writes
                      or self._since_state + len(runs) >= self.state_interval)
        txn = self.store.begin()
        log_entries: List[Tuple[Event, str, Optional[str]]] = []
        for inset_id, out_events, write_events, reads in runs:
            for e in out_events:
                if not self.replay_mode and \
                        any(getattr(ch, "prefer_blob", False)
                            for ch in op.out_channels.get(e.send_port, ())):
                    e.cache_blob()
                log_entries.append((e, UNDONE, None))
        if len(log_entries) == 1:
            txn.log_event(log_entries[0][0], UNDONE)
        elif log_entries:
            txn.log_events(log_entries)
        for inset_id, out_events, write_events, reads in runs:
            if not self.replay_mode:
                for e in out_events:
                    txn.put_event_data(e)
            for w in write_events:
                txn.log_event(w, UNDONE)
                txn.put_event_data(w)
            txn.set_inset_status(op.id, inset_id, DONE, require_rows=True)
            if self.lineage_out:
                for ra, effect in reads:
                    rev = Event(ra.action_id, op.id, f"{ra.conn_id}.r",
                                None, None, body=effect)
                    txn.log_event(rev, DONE, inset_id)
                    txn.put_event_data(rev)
                seen = set()
                for e in out_events:
                    if e.send_port in self.lineage_out and \
                            (e.send_port, e.event_id) not in seen:
                        txn.put_lineage(e.event_id, op.id, e.send_port,
                                        inset_id)
                        seen.add((e.send_port, e.event_id))
        if snap_state:
            txn.put_state(op.id, self.new_state_id(), self._state_blob(),
                          keep_history=self.keep_state_history)
        try:
            token = self._commit(txn)
        except TxnAborted:
            # one of the InSets vanished under the whole-run transaction
            # (Alg 13): rewind the SSNs and fall back to scalar generates,
            # so only the reassigned-away InSets drop their outputs
            self.ctx.ssn.clear()
            self.ctx.ssn.update(ssn_snap)
            self.ctx.write_ssn.clear()
            self.ctx.write_ssn.update(wssn_snap)
            for inset_id in inset_ids:
                self._generate_locked(inset_id)
            return
        self.stats["txns"] += 1
        self._since_state = 0 if snap_state \
            else self._since_state + len(runs)
        for inset_id, out_events, write_events, _ in runs:
            self.crash_point(op.id, "post_log")
            for e in out_events:
                self._send(e)
            self.stats["events_out"] += len(out_events)
            self.crash_point(op.id, "post_send")
            for w in write_events:
                self._after_durable(token, lambda w=w: self.execute_write(w))
            op.clear_inset(inset_id)

    def _send(self, e: Event):
        t0 = time.perf_counter()
        for ch in self.op.out_channels.get(e.send_port, []):
            if ch.rec_op == e.rec_op and ch.rec_port == e.rec_port:
                ch.put(e, stop_flag=self.stop_flag)
        # time blocked against the credit window (back-pressure from a
        # slow downstream) — the controller's stall-share signal
        self.stats["send_stall_us"] += int((time.perf_counter() - t0) * 1e6)

    # ---- side-effect reads (Algorithm 4) ----------------------------------
    def read_action(self, conn_id: str, desc: str, source: ReadSource):
        effect = source.effect(desc)
        if self.lineage_out:
            aid = len(self.pending_reads)
            ra = ReadAction(aid, self.op.id, conn_id, desc,
                            source.replayable)
            self.pending_reads.append((ra, effect))
        return effect

    # ---- write actions (Algorithm 5 + recovery Alg 8) ---------------------
    def execute_write(self, w: Event):
        self.crash_point(self.op.id, "pre_write")
        ok = self.external.execute(w.send_op, w.rec_port, w.event_id, w.body)
        if ok:
            self.crash_point(self.op.id, "post_write_pre_done")
            txn = self.store.begin()
            txn.set_status((w.send_op, w.send_port, w.event_id), DONE)
            txn.commit()

    def recover_writes(self):
        """Algorithm 8."""
        for w in self.store.get_write_actions(self.op.id):
            if self.external.status(w.send_op, w.rec_port, w.event_id) == "success":
                txn = self.store.begin()
                txn.set_status((w.send_op, w.send_port, w.event_id), DONE)
                txn.commit()
            else:
                self.execute_write(w)
