# The paper's primary contribution: LOG.io unified rollback recovery +
# fine-grain data lineage capture for distributed data pipelines.
#
# ``__all__`` below is the CURATED PUBLIC SURFACE — the documented import
# path (see docs/api.md) guarded by the API-snapshot test in
# tests/test_config_api.py. Everything else imported here remains reachable
# for backward compatibility, but internal modules are not the documented
# way in.
from repro.core.api import LogioAPI
from repro.core.builtin import (CountWindowOperator, GeneratorSource,
                                MapOperator, SyncJoinOperator, TerminalSink)
from repro.core.cluster import LocalCluster
from repro.core.controller import ControllerConfig, RecoveryController
from repro.core.metrics import (MetricsSnapshot, OpMetrics, StoreMetrics,
                                TransportMetrics)
from repro.core.engine import Engine, FailureInjector, Pipeline, \
    TransportConfig
from repro.core.transport import Channel, ChannelClosed
from repro.core.transport.base import Placement, WorkerBootstrap
from repro.core.events import Event, ReadAction
from repro.core.lineage import LineageScope, backward, enabled_ports, forward
from repro.core.lineagequery import (EventKey, LineageQuery, LineageResult,
                                     LineageSlice)
from repro.core.logstore import (GroupCommitStore, LineageFilter, LogBackend,
                                 MemoryLogStore, NullLogStore, SegmentLogStore,
                                 ShardedLogStore, SqliteLogStore, StoreConfig,
                                 TxnAborted, build_store)
from repro.core.operator import (ExternalSystem, Operator, OperatorRuntime,
                                 ReadSource, SimulatedCrash)
from repro.core.replay import ReplayMismatch, ReplayReport

__all__ = [
    "ControllerConfig",
    "Engine",
    "EventKey",
    "LineageFilter",
    "LineageQuery",
    "LineageScope",
    "LocalCluster",
    "LogioAPI",
    "MetricsSnapshot",
    "OpMetrics",
    "Pipeline",
    "Placement",
    "StoreConfig",
    "TransportConfig",
    "build_store",
]
