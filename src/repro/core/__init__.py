# The paper's primary contribution: LOG.io unified rollback recovery +
# fine-grain data lineage capture for distributed data pipelines.
from repro.core.builtin import (CountWindowOperator, GeneratorSource,
                                MapOperator, SyncJoinOperator, TerminalSink)
from repro.core.cluster import LocalCluster
from repro.core.engine import Engine, FailureInjector, Pipeline
from repro.core.transport import Channel, ChannelClosed
from repro.core.transport.base import Placement, WorkerBootstrap
from repro.core.events import Event, ReadAction
from repro.core.lineage import LineageScope, backward, enabled_ports, forward
from repro.core.logstore import (GroupCommitStore, LogBackend, MemoryLogStore,
                                 NullLogStore, ShardedLogStore, SqliteLogStore,
                                 TxnAborted, build_store)
from repro.core.operator import (ExternalSystem, Operator, OperatorRuntime,
                                 ReadSource, SimulatedCrash)
