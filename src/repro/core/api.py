"""The LOG.io Protocol API exactly as published (Sec. 6.2, Tables 7-9).

The framework-internal runtime (`OperatorRuntime`) drives the protocol for
the built-in generic operators; this facade exposes the paper's named
methods for authors porting custom SAP-DI-style operators verbatim
(Listings 1-3). Each method delegates to the runtime/store primitives.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence

from repro.core.events import (COMPLETE, DONE, INCOMPLETE, UNDONE, Event)
from repro.core.operator import OperatorRuntime


class LogioTransaction:
    """Table 8 — the LOG.io transaction interface."""

    def __init__(self, api: "LogioAPI"):
        self.api = api
        self._txn = api.rt.store.begin()

    def LogSourceEvent(self, eventInfo: Event, eventData: Any = None):
        if eventData is not None:
            eventInfo.body = eventData
        self._txn.log_event(eventInfo, UNDONE)
        self._txn.put_event_data(eventInfo)

    def LogOutputEvents(self, eventInfo: Sequence[Event],
                        eventData: Optional[Sequence[Any]] = None,
                        inSetID: Optional[str] = None):
        for i, ev in enumerate(eventInfo):
            if eventData is not None:
                ev.body = eventData[i]
            self._txn.log_event(ev, UNDONE)
            self._txn.put_event_data(ev)
        if inSetID is not None:
            self._txn.set_inset_status(self.api.rt.op.id, inSetID, DONE,
                                       require_rows=True)

    def DoneEvent(self, eventInfo: Event):
        self._txn.set_status((eventInfo.send_op, eventInfo.send_port,
                              eventInfo.event_id), DONE)

    def StoreState(self, stateInfo: int, state: bytes):
        self._txn.put_state(self.api.rt.op.id, stateInfo, state,
                            keep_history=self.api.rt.keep_state_history)

    def Commit(self):
        self._txn.commit()


class LogioAPI:
    """Tables 7 and 9 — interface + recovery methods."""

    def __init__(self, runtime: OperatorRuntime):
        self.rt = runtime

    # ---- Table 7: interface methods ---------------------------------
    def GetActionID(self, actionInit=None) -> int:
        self.rt.ctx.inset_counter += 1          # shared id namespace
        return self.rt.ctx.inset_counter

    def GetStateID(self, procInfo=None) -> int:
        return self.rt.new_state_id()

    def GetInSetID(self) -> str:
        return self.rt.new_inset_id()

    def GetEventID(self, port: str) -> int:
        return self.rt.next_ssn(port)

    def BeginTransaction(self) -> LogioTransaction:
        return LogioTransaction(self)

    def InitializeReadAction(self, actionInfo, stateID=None, state=None):
        action_id, conn_id, desc = actionInfo
        txn = self.rt.store.begin()
        txn.put_read_action(self.rt.op.id, conn_id, action_id, INCOMPLETE,
                            desc)
        if state is not None:
            txn.put_state(self.rt.op.id, stateID or self.GetStateID(), state)
        txn.commit()

    def CompleteReadAction(self, actionInfo, actionData=None):
        action_id, conn_id, desc = actionInfo
        txn = self.rt.store.begin()
        txn.put_read_action(self.rt.op.id, conn_id, action_id, COMPLETE, desc)
        ev = Event(action_id, self.rt.op.id, conn_id, self.rt.op.id, None,
                   body=actionData)
        txn.log_event(ev, UNDONE)
        txn.put_event_data(ev)
        txn.commit()

    def DropReadAction(self, actionInfo):
        action_id, conn_id, _desc = actionInfo
        txn = self.rt.store.begin()
        txn.delete_event_data((self.rt.op.id, conn_id, action_id))
        txn.commit()

    def LogStateEvent(self, stateInfo: int, inSetID: str):
        txn = self.rt.store.begin()
        ev = Event(stateInfo, self.rt.op.id, None, None, None)
        txn.log_event(ev, UNDONE, inset_id=inSetID)
        txn.commit()

    def UpdateContext(self, eventInfo: Event):
        port = eventInfo.rec_port
        self.rt.ctx.global_updated[port] = max(
            self.rt.ctx.global_updated.get(port, -1), eventInfo.event_id)

    def GetWriteActions(self, procInfo=None) -> List[Event]:
        return self.rt.store.get_write_actions(self.rt.op.id)

    def CheckEvent(self, eventInfo: Event) -> bool:
        """True iff the input event is NOT obsolete (Alg 2 step 1)."""
        return not self.rt._obsolete(eventInfo.rec_port, eventInfo)

    def AssignInSets(self, inSetIDs: Sequence[str], eventInfo: Event):
        txn = self.rt.store.begin()
        txn.assign_insets((eventInfo.send_op, eventInfo.send_port,
                           eventInfo.event_id), list(inSetIDs),
                          rec_op=self.rt.op.id)
        txn.commit()

    # ---- Table 9: recovery methods -----------------------------------
    def FetchAckEvents(self, procInfo=None):
        return self.rt.store.fetch_ack_events(self.rt.op.id)

    def FetchResendEvents(self, procInfo=None):
        return [e for e, _ in self.rt.store.fetch_resend_events(self.rt.op.id)]

    def GetProcState(self, procInfo=None) -> Optional[bytes]:
        return self.rt.store.get_state(self.rt.op.id)

    def InitializeContext(self, procInfo=None):
        self.rt.restore_state()
