"""LOG.io persistent log tables (Sec. 3.2) behind atomic transactions.

Five tables: EVENT_LOG, EVENT_DATA, READ_ACTION, STATE, EVENT_LINEAGE.

Two backends:
  * ``MemoryLogStore`` — dict-based; transactions buffer mutations and apply
    them under a lock at commit. A crash between ``begin`` and ``commit``
    loses exactly the uncommitted buffer (the atomicity the protocol needs).
  * ``SqliteLogStore``  — durable ACID store (WAL). Stands in for the HANA
    instance of the paper's implementation (Sec. 6.1); used by the e2e
    training example and the durability tests.

EVENT_LOG may hold multiple rows per event — one per assigned InSet_ID
(Sec. 3.4: "as many entries for e are created in EVENT_LOG").
"""
from __future__ import annotations

import pickle
import sqlite3
import threading
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.events import DONE, REPLAY, UNDONE, Event


class TxnAborted(Exception):
    """Raised at commit when a conditional mutation fails (e.g. marking a
    non-existent InSet done — the dynamic-scaling mutual exclusion of
    Algorithm 13)."""


# ---------------------------------------------------------------------------
# In-memory backend
# ---------------------------------------------------------------------------

class _MemTxn:
    def __init__(self, store: "MemoryLogStore"):
        self.store = store
        self.ops: List[Tuple] = []

    # -- mutations (buffered) ---------------------------------------------
    def log_event(self, ev: Event, status: str = UNDONE,
                  inset_id: Optional[str] = None):
        self.ops.append(("log_event", ev, status, inset_id))

    def put_event_data(self, ev: Event):
        self.ops.append(("put_event_data", ev))

    def delete_event_data(self, key):
        self.ops.append(("delete_event_data", key))

    def set_status(self, key, status: str, inset_id: Optional[str] = "*",
                   rec_op: Optional[str] = None,
                   only_status: Optional[str] = None):
        """key = (send_op, send_port, event_id). rec_op filters to one
        receiver's rows; only_status makes the flip conditional."""
        self.ops.append(("set_status", key, status, inset_id, rec_op,
                         only_status))

    def assign_insets(self, key, inset_ids: List[str], rec_op: str = None):
        self.ops.append(("assign_insets", key, list(inset_ids), rec_op))

    def set_inset_status(self, rec_op: str, inset_id: str, status: str,
                         require_rows: bool = False):
        self.ops.append(("set_inset_status", rec_op, inset_id, status,
                         require_rows))

    def clear_inset(self, rec_op: str, inset_id: str):
        self.ops.append(("clear_inset", rec_op, inset_id))

    def put_state(self, op_id: str, state_id: int, blob: bytes,
                  keep_history: bool = False):
        self.ops.append(("put_state", op_id, state_id, blob, keep_history))

    def put_lineage(self, event_id: int, send_op: str, send_port: str,
                    inset_id: str):
        self.ops.append(("put_lineage", event_id, send_op, send_port, inset_id))

    def put_read_action(self, op_id: str, conn_id: str, action_id: int,
                        status: str, desc: str):
        self.ops.append(("put_read_action", op_id, conn_id, action_id,
                         status, desc))

    def set_read_action_status(self, op_id: str, conn_id: str,
                               action_id: int, status: str):
        self.ops.append(("set_read_action_status", op_id, conn_id, action_id,
                         status))

    def delete_event_rows(self, key):
        self.ops.append(("delete_event_rows", key))

    def commit(self):
        self.store._apply(self.ops)
        self.ops = []


class MemoryLogStore:
    """EVENT_LOG rows: {key: (send_op,send_port,event_id,inset_id|None) ->
    dict(status=..., rec_op=..., rec_port=...)}."""

    def __init__(self):
        self.lock = threading.RLock()
        self.event_log: Dict[Tuple, Dict[str, Any]] = {}
        self.event_data: Dict[Tuple, Tuple[bytes, bytes]] = {}
        self.read_actions: Dict[Tuple, Dict[str, Any]] = {}
        self.state: Dict[str, List[Tuple[int, bytes]]] = {}
        self.lineage: List[Tuple[int, str, str, str]] = []
        self.commits = 0
        self.bytes_written = 0

    def begin(self) -> _MemTxn:
        return _MemTxn(self)

    # -- application ----------------------------------------------------
    def _apply(self, ops):
        with self.lock:
            # validation pass (conditional ops) before mutation => atomicity
            for op in ops:
                if op[0] == "set_inset_status" and op[4]:
                    rec_op, inset_id = op[1], op[2]
                    if not any(r.get("inset") == inset_id
                               and r["rec_op"] == rec_op
                               for r in self.event_log.values()):
                        raise TxnAborted(
                            f"no EVENT_LOG rows for InSet {inset_id}@{rec_op}")
                if op[0] == "assign_insets":
                    key, rec = op[1], op[3]
                    if not any(k[:3] == key and (rec is None or k[3] == rec)
                               for k in self.event_log):
                        # event vanished (reassigned by a scale-down, Alg 13)
                        raise TxnAborted(f"no EVENT_LOG rows for {key}")
            for op in ops:
                self._apply_one(op)
            self.commits += 1

    def _apply_one(self, op):
        kind = op[0]
        if kind == "log_event":
            _, ev, status, inset_id = op
            key = (ev.send_op, ev.send_port, ev.event_id, ev.rec_op, inset_id)
            self.event_log[key] = {"status": status, "rec_op": ev.rec_op,
                                   "rec_port": ev.rec_port, "inset": inset_id}
        elif kind == "put_event_data":
            _, ev = op
            blob = pickle.dumps((ev.header, ev.body))
            self.bytes_written += len(blob)
            self.event_data[ev.key()] = blob
        elif kind == "delete_event_data":
            self.event_data.pop(op[1], None)
        elif kind == "set_status":
            _, key, status, inset_id, rec_op, only_status = op
            for k in list(self.event_log):
                if k[:3] != key:
                    continue
                if inset_id != "*" and k[4] != inset_id:
                    continue
                if rec_op is not None and k[3] != rec_op:
                    continue
                if only_status is not None and \
                        self.event_log[k]["status"] != only_status:
                    continue
                self.event_log[k]["status"] = status
        elif kind == "assign_insets":
            _, key, insets, rec = op
            base = key + (rec, None)
            row = self.event_log.get(base)
            if row is None:
                row = next(r for k, r in self.event_log.items()
                           if k[:3] == key and (rec is None or k[3] == rec))
            for ins in insets:
                self.event_log[key + (rec, ins)] = dict(row, inset=ins)
            if insets and base in self.event_log:
                del self.event_log[base]
        elif kind == "set_inset_status":
            _, rec_op, inset_id, status, _req = op
            for k, r in self.event_log.items():
                if r.get("inset") == inset_id and r["rec_op"] == rec_op:
                    r["status"] = status
        elif kind == "clear_inset":
            pass   # event-state clearing is in-memory; log rows stay "done"
        elif kind == "put_state":
            _, op_id, state_id, blob, keep = op
            self.bytes_written += len(blob)
            hist = self.state.setdefault(op_id, [])
            if keep:
                hist.append((state_id, blob))
            else:
                self.state[op_id] = [(state_id, blob)]
        elif kind == "put_lineage":
            _, event_id, send_op, send_port, inset_id = op
            self.lineage.append((event_id, send_op, send_port, inset_id))
        elif kind == "put_read_action":
            _, op_id, conn_id, action_id, status, desc = op
            self.read_actions[(op_id, conn_id, action_id)] = {
                "status": status, "desc": desc}
        elif kind == "set_read_action_status":
            _, op_id, conn_id, action_id, status = op
            k = (op_id, conn_id, action_id)
            if k in self.read_actions:
                self.read_actions[k]["status"] = status
        elif kind == "delete_event_rows":
            _, key = op
            for k in list(self.event_log):
                if k[:3] == key:
                    del self.event_log[k]
        elif kind == "reassign_event":
            # Alg 13 step 1.c: move an undone event to a new destination
            # (+ new event id); rows already done/acked->done are skipped.
            _, old_key, old_rec, new_key, tgt_op, tgt_port = op
            moved = False
            for k in list(self.event_log):
                if k[:3] == old_key and (old_rec is None or k[3] == old_rec) \
                        and self.event_log[k]["status"] == UNDONE:
                    del self.event_log[k]
                    moved = True
            if moved:
                self.event_log[new_key + (tgt_op, None)] = {
                    "status": UNDONE, "rec_op": tgt_op, "rec_port": tgt_port,
                    "inset": None}
                blob = self.event_data.pop(old_key, None)
                if blob is not None:
                    self.event_data[new_key] = blob

    # -- queries ----------------------------------------------------------
    def _mk_event(self, k, r) -> Event:
        header, body = ({}, None)
        blob = self.event_data.get(k[:3])
        if blob is not None:
            header, body = pickle.loads(blob)
        return Event(event_id=k[2], send_op=k[0], send_port=k[1],
                     rec_op=r["rec_op"], rec_port=r["rec_port"],
                     body=body, header=dict(header))

    def fetch_resend_events(self, op_id: str) -> List[Tuple[Event, str]]:
        """Alg 7 step 1: undone, sender==op, InSet null, real output events."""
        with self.lock:
            rows = [(k, r) for k, r in self.event_log.items()
                    if k[0] == op_id and r["status"] in (UNDONE, REPLAY)
                    and k[4] is None and k[1] is not None
                    and r["rec_port"] is not None]
            rows.sort(key=lambda kr: kr[0][2])
            return [(self._mk_event(k, r), r["status"]) for k, r in rows]

    def fetch_ack_events(self, op_id: str) -> List[Tuple[Event, str, str]]:
        """Alg 9 step 2: undone, receiver==op, InSet assigned.

        Returns [(event, inset_id, status)] ordered by (rec_port, event_id).
        """
        with self.lock:
            rows = [(k, r) for k, r in self.event_log.items()
                    if r["rec_op"] == op_id and r["status"] in (UNDONE, REPLAY)
                    and k[4] is not None]
            rows.sort(key=lambda kr: (kr[1]["rec_port"] or "", kr[0][2]))
            return [(self._mk_event(k, r), k[4], r["status"])
                    for k, r in rows]

    def fetch_replay_outputs(self, op_id: str) -> List[Tuple[int, str, str]]:
        """Sender-side rows marked REPLAY by consumers (Alg 10 step 2 for an
        operator restarted in state 'replay'): [(event_id, port, status)]."""
        with self.lock:
            return sorted((k[2], k[1], r["status"])
                          for k, r in self.event_log.items()
                          if k[0] == op_id and k[1] is not None
                          and r["status"] == REPLAY and r["rec_port"] is not None)

    def undone_outputs_after(self, op_id: str, port: str, min_id: int
                             ) -> List[int]:
        """UNDONE outputs on a port with event_id >= min_id (the 'events
        sent after those marked replay' of Alg 10 step 2)."""
        with self.lock:
            return sorted({k[2] for k, r in self.event_log.items()
                           if k[0] == op_id and k[1] == port
                           and r["status"] == UNDONE and k[2] >= min_id})

    def get_write_actions(self, op_id: str) -> List[Event]:
        """Alg 8: undone events with null sender port for op."""
        with self.lock:
            rows = [(k, r) for k, r in self.event_log.items()
                    if k[0] == op_id and k[1] is None
                    and r["status"] == UNDONE]
            rows.sort(key=lambda kr: kr[0][2])
            return [self._mk_event(k, r) for k, r in rows]

    def get_state(self, op_id: str) -> Optional[bytes]:
        with self.lock:
            hist = self.state.get(op_id)
            return hist[-1][1] if hist else None

    def last_sent_ssn(self, op_id: str) -> Dict[str, int]:
        """max event_id per output port (Alg 9 step 1)."""
        with self.lock:
            out: Dict[str, int] = {}
            for k in self.event_log:
                if k[0] == op_id and k[1] is not None:
                    out[k[1]] = max(out.get(k[1], -1), k[2])
            return out

    def last_acked(self, op_id: str) -> Dict[str, int]:
        """max event_id per input port with an assigned InSet (filter base)."""
        with self.lock:
            out: Dict[str, int] = {}
            for k, r in self.event_log.items():
                if r["rec_op"] == op_id and k[4] is not None:
                    p = r["rec_port"]
                    out[p] = max(out.get(p, -1), k[2])
            return out

    def event_status(self, key, rec_op: Optional[str] = None
                     ) -> List[Tuple[Optional[str], str]]:
        with self.lock:
            return [(k[4], r["status"]) for k, r in self.event_log.items()
                    if k[:3] == key and (rec_op is None or k[3] == rec_op)]

    def get_read_action(self, op_id: str, conn_id: str):
        """latest read action for (op, conn)."""
        with self.lock:
            cands = [(k, v) for k, v in self.read_actions.items()
                     if k[0] == op_id and k[1] == conn_id]
            if not cands:
                return None, None
            k, v = max(cands, key=lambda kv: kv[0][2])
            return k[2], dict(v)

    # lineage queries ----------------------------------------------------
    def lineage_insets_of(self, event_key) -> List[str]:
        send_op, send_port, event_id = event_key
        with self.lock:
            return [ins for (eid, so, sp, ins) in self.lineage
                    if (so, sp, eid) == (send_op, send_port, event_id)]

    def lineage_events_of_inset(self, rec_op: str, inset_id: str) -> List[Tuple]:
        with self.lock:
            return sorted(k[:3] for k, r in self.event_log.items()
                          if r["rec_op"] == rec_op and r.get("inset") == inset_id)

    def lineage_outputs_of_inset(self, send_op: str, inset_id: str) -> List[Tuple]:
        with self.lock:
            return sorted((so, sp, eid) for (eid, so, sp, ins) in self.lineage
                          if so == send_op and ins == inset_id)

    def insets_of_event(self, event_key, rec_op: str) -> List[str]:
        with self.lock:
            return [k[4] for k, r in self.event_log.items()
                    if k[:3] == event_key and k[3] == rec_op
                    and k[4] is not None]

    # GC (Sec. 3.6) --------------------------------------------------------
    def gc(self, lineage_ops: Iterable[str] = ()):
        keep_data_for = set(lineage_ops)
        with self.lock:
            for k, r in list(self.event_log.items()):
                if r["status"] == DONE and k[0] not in keep_data_for:
                    self.event_data.pop(k[:3], None)
                    if not self.lineage:
                        del self.event_log[k]


# ---------------------------------------------------------------------------
# SQLite backend — same txn interface, durable
# ---------------------------------------------------------------------------

class _SqliteTxn(_MemTxn):
    def __init__(self, store: "SqliteLogStore"):
        self.store = store
        self.ops = []

    def commit(self):
        self.store._apply(self.ops)
        self.ops = []


class SqliteLogStore(MemoryLogStore):
    """Durable backend: mirrors MemoryLogStore's logic through SQL.

    Implementation note: we reuse the in-memory application logic for the
    mutation semantics but persist every commit as one SQLite transaction,
    and rebuild the in-memory image from disk on open ⇒ genuine durability
    with the exact in-memory read paths.
    """

    def __init__(self, path: str):
        super().__init__()
        self.path = path
        self.conn = sqlite3.connect(path, check_same_thread=False)
        self.conn.execute("PRAGMA journal_mode=WAL")
        self.conn.execute(
            "CREATE TABLE IF NOT EXISTS wal_ops (seq INTEGER PRIMARY KEY "
            "AUTOINCREMENT, blob BLOB)")
        self.conn.commit()
        self._replay_from_disk()

    def begin(self):
        return _SqliteTxn(self)

    def _replay_from_disk(self):
        cur = self.conn.execute("SELECT blob FROM wal_ops ORDER BY seq")
        for (blob,) in cur.fetchall():
            ops = pickle.loads(blob)
            try:
                MemoryLogStore._apply(self, ops)
            except TxnAborted:
                pass

    def _apply(self, ops):
        with self.lock:
            blob = pickle.dumps(ops)
            MemoryLogStore._apply(self, ops)      # validates + mutates image
            self.conn.execute("INSERT INTO wal_ops (blob) VALUES (?)", (blob,))
            self.conn.commit()                    # durable point
            self.bytes_written += len(blob)

    def close(self):
        self.conn.close()


# ---------------------------------------------------------------------------
# Null backend — the benchmarks' "execution baseline" (no rollback recovery)
# ---------------------------------------------------------------------------

class _NullTxn(_MemTxn):
    def commit(self):
        self.ops = []


class NullLogStore(MemoryLogStore):
    """No-op store: pipelines run with zero logging (no recovery possible).
    Used to measure the paper's 'execution baseline' (Sec. 9.3.1)."""

    def begin(self):
        return _NullTxn(self)

    def _apply(self, ops):
        pass
