"""ABS baseline: aligned Asynchronous Barrier Snapshotting (Sec. 8.1.1),
the SAP-DI variant (no 2PC across writers; per-epoch WAL committed at epoch
completion), used as the comparison protocol in Sec. 9.

Mechanics:
  * sources inject marker events every ``epoch_events`` outputs and record
    their read offset per epoch;
  * an operator receiving marker e on a port BLOCKS that port (alignment)
    until marker e arrived on all ports, then snapshots its full state
    (global + event state) asynchronously and forwards the marker;
  * write actions are buffered into a per-epoch WAL and committed (executed
    on the external system) only when the epoch is complete;
  * on ANY failure the WHOLE pipeline restarts from the last complete epoch:
    channels cleared, operators restored from snapshots, sources rewound —
    the blocking behaviour LOG.io's non-blocking recovery is measured
    against.
"""
from __future__ import annotations

import pickle
import threading
import time
from typing import Any, Dict, List, Tuple

from repro.core.builtin import GeneratorSource, TerminalSink
from repro.core.events import Event
from repro.core.operator import Operator, SimulatedCrash

# per-class volatile state captured in snapshots
STATE_ATTRS = {
    "MapOperator": ("_queue",),
    "CountWindowOperator": ("count", "insets"),
    "SyncJoinOperator": ("counts", "windows"),
    "TerminalSink": ("seen", "_pending", "received"),
    "DispatcherOperator": ("rr", "routes", "_queue"),
    "MergerOperator": ("_queue",),
}


def snapshot_op(op: Operator) -> bytes:
    attrs = STATE_ATTRS.get(type(op).__name__, ())
    return pickle.dumps({a: getattr(op, a) for a in attrs})


def restore_op(op: Operator, blob: bytes):
    for a, v in pickle.loads(blob).items():
        setattr(op, a, v)


class SnapshotStore:
    """Durable store for epoch snapshots + per-epoch write WAL.

    ``backend`` (optional) is a :class:`~repro.core.logstore.LogBackend`:
    snapshots are additionally persisted through the formal log interface
    (STATE rows keyed ``abs:<op>``), so the ABS baseline can run over the
    exact same storage stack (sqlite / sharded / group commit) as LOG.io —
    an epoch's WAL is only committed to the external system once the
    backend's durability watermark covers its snapshots."""

    def __init__(self, backend=None):
        self.lock = threading.Lock()
        self.backend = backend
        self.snaps: Dict[int, Dict[str, bytes]] = {}
        self.offsets: Dict[int, Dict[str, int]] = {}
        self.wal: Dict[int, List[Tuple[str, str, int, Any]]] = {}
        self.committed_epochs: set = set()
        self.complete: set = set()
        self.bytes_written = 0

    def put_snapshot(self, epoch: int, op_id: str, blob: bytes):
        with self.lock:
            self.snaps.setdefault(epoch, {})[op_id] = blob
            self.bytes_written += len(blob)
        if self.backend is not None:
            txn = self.backend.begin()
            txn.put_state(f"abs:{op_id}", epoch, blob, keep_history=True)
            txn.commit()

    def put_offset(self, epoch: int, op_id: str, off: int):
        with self.lock:
            self.offsets.setdefault(epoch, {})[op_id] = off

    def add_write(self, epoch: int, op_id: str, conn: str, n: int, body):
        with self.lock:
            self.wal.setdefault(epoch, []).append((op_id, conn, n, body))
            self.bytes_written += len(pickle.dumps(body))

    def snapshot_count(self, epoch: int) -> int:
        with self.lock:
            return len(self.snaps.get(epoch, {}))

    def last_complete(self) -> int:
        with self.lock:
            return max(self.complete) if self.complete else -1


class _AbsOpState:
    def __init__(self, op: Operator):
        self.op = op
        self.blocked: Dict[str, int] = {}     # port -> epoch blocking it
        self.markers: Dict[int, set] = {}     # epoch -> ports seen
        # writes buffered between markers e-1 and e belong to epoch e
        self.epoch = 1
        self.write_ssn = 0


class AbsEngineDriver:
    """Every group runs in epoch mode under this driver — the barrier
    aligns the whole pipeline, so per-group ``recovery_mode`` freedom does
    not exist here.  ``Engine.recovery_mode_of()`` reports ``"epoch"`` for
    all groups under ``protocol="abs"``, and the engine rejects an explicit
    ``recovery_modes={...: "log"}`` request at construction; the adaptive
    per-group hybrid lives in the log engine (``recovery_modes=`` /
    ``set_recovery_mode``, driven by ``repro.core.controller``)."""

    def __init__(self, engine, *, epoch_events: int = 15,
                 snapshot_async: bool = True, durable_store=None):
        if any(m == "log" for m in engine.recovery_modes.values()):
            raise ValueError(
                "ABS cannot honor per-group recovery_mode 'log' — the "
                "barrier aligns every group")
        self.e = engine
        self.epoch_events = epoch_events
        self.snapshot_async = snapshot_async
        self.store = SnapshotStore(backend=durable_store)
        self.states: Dict[str, _AbsOpState] = {}
        self.src_emit_count: Dict[str, int] = {}
        self.src_epoch: Dict[str, int] = {}
        self._restart_lock = threading.Lock()
        self._epoch_lock = threading.Lock()
        self._stop = engine._stop
        self._done = engine._done
        self.snapshot_threads: List[threading.Thread] = []
        self._next_commit = 1
        self._tl = threading.local()
        # group threads inside a step section; a global restart must see
        # this reach zero before restoring, or an old-generation thread
        # (e.g. the sink draining pre-crash outputs, or a source mid-emit)
        # races the restore and pollutes the WAL/offsets it just rebuilt
        self._active = 0
        self._active_lock = threading.Lock()

    # ------------------------------------------------------------------
    def start(self):
        self._init_states()
        for g in set(self.e.pipeline.groups.values()):
            self._start_group(g)

    def _init_states(self, epoch: int = 0):
        self.states = {oid: _AbsOpState(op) for oid, op in self.e.ops.items()}
        for st in self.states.values():
            st.epoch = max(epoch, 0) + 1
        for oid, op in self.e.ops.items():
            if isinstance(op, GeneratorSource):
                self.src_emit_count.setdefault(oid, 0)
                self.src_epoch.setdefault(oid, 0)
                op._effect = op.source.effect(op.desc, 0)
                op._abs_offset = getattr(op, "_abs_offset", 0)

    def _start_group(self, group: str):
        t = threading.Thread(target=self._run_group, args=(group,),
                             daemon=True, name=f"abs-{group}")
        self.e.threads[group] = t
        t.start()

    def _run_group(self, group: str):
        gen = self._generation
        self._tl.gen = gen
        try:
            while not self._stop.is_set() and not self._done.is_set():
                progressed = False
                with self._active_lock:
                    self._active += 1
                try:
                    # the generation re-check sits INSIDE the active
                    # section: entering after a restart observed zero is
                    # harmless because such a thread exits without stepping
                    if gen != self._generation:
                        return      # superseded by a restart
                    for op_id in self.e.group_ops(group):
                        op = self.e.ops[op_id]
                        progressed |= self._step(op)
                finally:
                    with self._active_lock:
                        self._active -= 1
                if not progressed:
                    time.sleep(0.001)
        except SimulatedCrash as exc:
            self._global_restart(exc)

    _generation = 0

    # ------------------------------------------------------------------
    def _step(self, op: Operator) -> bool:
        if isinstance(op, GeneratorSource):
            return self._step_source(op)
        st = self.states[op.id]
        progressed = False
        for port in op.input_ports:
            ch = op.in_channels.get(port)
            if ch is None or port in st.blocked:
                continue
            ev = ch.peek()
            if ev is None:
                continue
            if "marker" in ev.header:
                ch.ack()
                self._on_marker(op, st, port, ev.header["marker"])
                progressed = True
                continue
            self.e.injector(op.id, "abs_input")
            ch.ack()
            op.update_global(ev)
            insets = op.on_event(ev)
            for inset in op.triggers():
                op.simulate_work()
                outputs, writes = op.generate(inset)
                self.e.injector(op.id, "abs_post_generate")
                for port_out, body in outputs:
                    self._send(op, port_out, body)
                for conn, body in writes:
                    st.write_ssn += 1
                    self.store.add_write(st.epoch, op.id, conn,
                                         st.write_ssn, body)
                op.clear_inset(inset)
            if isinstance(op, TerminalSink) and op.seen >= op.target:
                self._done.set()
            progressed = True
        return progressed

    def _step_source(self, op: GeneratorSource) -> bool:
        if op._effect is None:
            op._effect = op.source.effect(op.desc, 0)
        off = getattr(op, "_abs_offset", 0)
        if off >= len(op._effect):
            op.exhausted = True
            if not getattr(op, "_final_marker", False):
                self._emit_marker(op)
                op._final_marker = True
            return False
        delay = op.rate_fn(off) if op.rate_fn is not None else op.rate
        if delay > 0:
            time.sleep(delay)
        self.e.injector(op.id, "abs_source")
        body = op._effect[off]
        op._abs_offset = off + 1
        self._send(op, "out", body)
        self.src_emit_count[op.id] += 1
        if self.src_emit_count[op.id] % self.epoch_events == 0:
            self._emit_marker(op)
        return True

    def _emit_marker(self, op: GeneratorSource):
        self.src_epoch[op.id] += 1
        epoch = self.src_epoch[op.id]
        self.store.put_offset(epoch, op.id, getattr(op, "_abs_offset", 0))
        self.store.put_snapshot(epoch, op.id, snapshot_op(op))
        for ch in op.out_channels.get("out", []):
            ch.put(Event(-epoch, op.id, "out", ch.rec_op, ch.rec_port,
                         header={"marker": epoch}), stop_flag=self._stopflag)

    def _send(self, op: Operator, port: str, body):
        st = self.states.get(op.id)
        for ch in op.out_channels.get(port, []):
            ch.put(Event(0, op.id, port, ch.rec_op, ch.rec_port, body=body),
                   stop_flag=self._stopflag)

    # ------------------------------------------------------------------
    def _on_marker(self, op: Operator, st: _AbsOpState, port: str, epoch: int):
        seen = st.markers.setdefault(epoch, set())
        seen.add(port)
        if len(seen) < len([p for p in op.input_ports
                            if p in op.in_channels]):
            st.blocked[port] = epoch          # alignment: block this port
            return
        # all markers in: snapshot, forward, unblock
        st.blocked = {p: e for p, e in st.blocked.items() if e != epoch}
        blob = snapshot_op(op)

        def do_snap():
            time.sleep(0)                      # async hand-off
            self.store.put_snapshot(epoch, op.id, blob)
            self._maybe_complete(epoch)

        if self.snapshot_async:
            t = threading.Thread(target=do_snap, daemon=True)
            t.start()                       # start BEFORE publishing: the
            self.snapshot_threads.append(t)  # flush path joins this list
        else:
            do_snap()
        st.epoch = epoch + 1
        for port_out in op.output_ports:
            for ch in op.out_channels.get(port_out, []):
                ch.put(Event(-epoch, op.id, port_out, ch.rec_op, ch.rec_port,
                             header={"marker": epoch}),
                       stop_flag=self._stopflag)

    def _stopflag(self) -> bool:
        gen = getattr(self._tl, "gen", self._generation)
        return self._stop.is_set() or gen != self._generation

    def _maybe_complete(self, epoch: int):
        with self._epoch_lock:
            if self.store.snapshot_count(epoch) >= len(self.e.ops) \
                    and epoch not in self.store.complete:
                self.store.complete.add(epoch)
            # commit strictly in epoch order
            while self._next_commit in self.store.complete:
                self._commit_epoch(self._next_commit)
                self._next_commit += 1

    def _commit_epoch(self, epoch: int):
        """Execute the epoch's WAL on the external system (exactly once).
        With a log backend attached, the external writes are gated on its
        durability watermark (same rule as LOG.io's write actions)."""
        if epoch in self.store.committed_epochs:
            return
        if self.store.backend is not None:
            self.store.backend.flush()
        self.store.committed_epochs.add(epoch)
        for (op_id, conn, n, body) in self.store.wal.get(epoch, []):
            self.e.external.execute(op_id, conn, (epoch, n), body)

    # ------------------------------------------------------------------
    def _global_restart(self, exc):
        with self._restart_lock:
            if self._stop.is_set() or self._done.is_set():
                return
            self.e.failures += 1
            self._generation += 1
            gen = self._generation
            # quiesce: every other group thread must leave its step section
            # before state is restored (they observe the generation bump at
            # their loop top; a blocked channel put aborts via stop_flag).
            # The crashing thread itself already unwound out of its step.
            deadline = time.time() + 30.0
            while time.time() < deadline:
                with self._active_lock:
                    if self._active == 0:
                        break
                time.sleep(0.001)
            for t in list(self.snapshot_threads):
                t.join(timeout=5.0)
            self.snapshot_threads = [t for t in self.snapshot_threads
                                     if t.is_alive()]
            time.sleep(self.e.restart_delay * len(self.e.ops))  # whole-pipeline restart
            epoch = self.store.last_complete()
            for ch in self.e.channels:
                ch.clear()
            # fresh instances, restore from snapshots
            self.e._build(first=False)
            self.e.restarts += 1
            for oid, op in self.e.ops.items():
                blob = self.store.snaps.get(epoch, {}).get(oid)
                if blob is not None:
                    restore_op(op, blob)
                if isinstance(op, GeneratorSource):
                    op._abs_offset = self.store.offsets.get(epoch, {}).get(oid, 0)
                    op._effect = op.source.effect(op.desc, 0)
                    op.exhausted = False
                    op._final_marker = False
            # drop WAL + snapshots of incomplete epochs
            for e in list(self.store.wal):
                if e > epoch:
                    del self.store.wal[e]
            for e in list(self.store.snaps):
                if e > epoch:
                    del self.store.snaps[e]
            self._init_states(epoch)
            for oid in self.src_epoch:
                self.src_epoch[oid] = max(epoch, 0)
                self.src_emit_count[oid] = self.store.offsets.get(
                    epoch, {}).get(oid, 0)
        for g in set(self.e.pipeline.groups.values()):
            self._start_group(g)

    def wait(self, timeout: float) -> bool:
        deadline = time.time() + timeout
        while time.time() < deadline:
            if self._done.is_set():
                self._stop.set()
                self._final_flush()
                return True
            if self.e._sources_exhausted() and \
                    all(len(c) == 0 for c in self.e.channels):
                self._final_flush()
                return True
            time.sleep(0.005)
        self._stop.set()
        return False

    def _final_flush(self):
        """Drain shutdown: join pending snapshots, then commit every
        remaining WAL epoch in order (the job finished cleanly, so the final
        partial epoch commits too — Flink's commit-on-finish)."""
        for t in list(self.snapshot_threads):
            try:
                t.join(0.5)
            except RuntimeError:
                pass    # racing with thread start: snapshot not yet live
        for e in sorted(set(self.store.wal) | self.store.complete):
            self._commit_epoch(e)
