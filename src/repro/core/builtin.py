"""Built-in operator library: sources, maps, windowed aggregators, writers,
sinks — the concrete operators used by the paper's three use cases (Sec. 9.2)
and the training data pipeline.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.events import COMPLETE, DONE, INCOMPLETE, UNDONE, Event
from repro.core.operator import Operator, OperatorRuntime, ReadSource


class ScratchStore:
    """Durable scratch storage for effects of non-replayable read actions
    (Alg 1 step 2.a). Survives operator restarts. ``backend`` makes the
    medium pluggable: a process-mode worker points it at the supervisor
    (ScratchClient) so scratch effects survive worker death too."""
    _global: Dict[Tuple, Any] = {}
    _lock = threading.Lock()
    backend: Any = None

    @classmethod
    def put(cls, key, value):
        if cls.backend is not None:
            return cls.backend.put(key, value)
        with cls._lock:
            cls._global[key] = value

    @classmethod
    def get(cls, key):
        if cls.backend is not None:
            return cls.backend.get(key)
        with cls._lock:
            return cls._global.get(key)

    @classmethod
    def drop(cls, key):
        if cls.backend is not None:
            return cls.backend.drop(key)
        with cls._lock:
            cls._global.pop(key, None)


# ---------------------------------------------------------------------------
# Source (Algorithm 1 + recovery Algorithm 6)
# ---------------------------------------------------------------------------

class GeneratorSource(Operator):
    """Source performing one read action against a ReadSource.

    * replayable source (paper's benchmark generator): Alg 1 step 3 —
      pipelined consumption, offset kept in the global state.
    * non-replayable: Alg 1 step 2 — effect stored first, then iterated.
    """
    input_ports: Tuple[str, ...] = ()
    output_ports = ("out",)

    def __init__(self, op_id: str, source: ReadSource, *, conn_id: str = "Cx",
                 rate: float = 0.0, desc: str = "read A",
                 processing_time: float = 0.0, rate_fn=None):
        super().__init__(op_id, processing_time=processing_time)
        self.source = source
        self.conn_id = conn_id
        self.rate = rate
        # optional shaped arrival process: a picklable callable
        # ``offset -> delay_seconds`` evaluated per emission (diurnal,
        # burst, ... synthetic traces for the adaptive controller) —
        # overrides the constant ``rate`` when set
        self.rate_fn = rate_fn
        self.desc = desc
        self.exhausted = False
        self._effect: Optional[List[Any]] = None

    # -- driver ------------------------------------------------------------
    def start_read(self):
        rt = self.runtime
        if self.source.replayable:
            # Alg 1 step 1 + 3: register action, consume pipelined
            txn = rt.store.begin()
            txn.put_read_action(self.id, self.conn_id, 0, INCOMPLETE,
                                self.desc)
            txn.commit()
            self._effect = self.source.effect(self.desc, 0)
        else:
            # Alg 1 steps 1-2: execute fully + store effect, mark complete
            txn = rt.store.begin()
            txn.put_read_action(self.id, self.conn_id, 0, INCOMPLETE,
                                self.desc)
            txn.commit()
            effect = self.source.effect(self.desc, 0)
            ScratchStore.put((self.id, 0), effect)
            rt.crash_point(self.id, "source_post_store")
            txn = rt.store.begin()
            txn.put_read_action(self.id, self.conn_id, 0, COMPLETE, self.desc)
            rev = Event(0, self.id, self.conn_id, self.id, None,
                        body=("ref", (self.id, 0)))
            txn.log_event(rev, UNDONE)
            txn.put_event_data(rev)
            txn.commit()
            self._effect = effect

    def step(self) -> bool:
        """Emit one output event. Returns False when exhausted."""
        rt = self.runtime
        if self._effect is None:
            self.start_read()
        off = rt.ctx.read_offset
        if off >= len(self._effect):
            if not self.exhausted:
                self._finish()
            return False
        delay = self.rate_fn(off) if self.rate_fn is not None else self.rate
        if delay > 0:
            time.sleep(delay)
        body = self._effect[off]
        rt.ctx.read_offset = off + 1
        rt.crash_point(self.id, "source_pre_log")
        self._emit("out", body, last=(off + 1 >= len(self._effect)))
        return True

    def pending_emits(self) -> int:
        """How much unemitted input the governor may batch over.  Rate-
        limited sources report 1 (each emission waits out its interval, so
        batching would distort the arrival process).  A shaped source
        (``rate_fn``) reports the length of the zero-delay *pack* behind
        the next arrival: those events land together, so batching them
        does not distort the arrival process."""
        if self._effect is None or self.rate > 0:
            return 1
        off = self.runtime.ctx.read_offset
        end = len(self._effect)
        if self.rate_fn is not None:
            if off >= end:
                return 0
            k = 1
            while off + k < end and k < 1024 \
                    and self.rate_fn(off + k) <= 0:
                k += 1
            return k
        return max(0, end - off)

    def step_run(self, max_n: int) -> int:
        """Emit up to ``max_n`` output events through ONE log transaction
        (one vectored ``log_events`` + one trailing state snapshot).
        Returns the number of bodies emitted (0 when exhausted).  A crash
        before the commit loses at most this run — the offset travels in
        the same transaction, so recovery regenerates exactly the
        uncommitted suffix."""
        rt = self.runtime
        if self._effect is None:
            self.start_read()
        off = rt.ctx.read_offset
        n = min(max_n, len(self._effect) - off)
        if self.rate_fn is not None and n >= 1:
            # shaped arrivals: wait out the pack boundary once, then emit
            # the zero-delay arrivals behind it as one run — never run
            # past the next nonzero delay (that is the next pack)
            k = 1
            while k < n and self.rate_fn(off + k) <= 0:
                k += 1
            n = k
            delay = self.rate_fn(off)
            if delay > 0:
                time.sleep(delay)
        elif n <= 1 or self.rate > 0:
            return 1 if self.step() else 0
        bodies = self._effect[off:off + n]
        rt.ctx.read_offset = off + n
        for _ in bodies:
            rt.crash_point(self.id, "source_pre_log")
        self._emit_run("out", bodies, last=(off + n >= len(self._effect)))
        return n

    def _emit(self, port: str, body, last: bool):
        rt = self.runtime
        ssn = rt.next_ssn(port)
        evs = [Event(ssn, self.id, port, ch.rec_op, ch.rec_port, body=body)
               for ch in self.out_channels.get(port, [])]
        txn = rt.store.begin()
        for e in evs:
            txn.log_event(e, UNDONE)
            txn.put_event_data(e)
        txn.put_state(self.id, rt.new_state_id(), rt._state_blob(),
                      keep_history=rt.keep_state_history)
        if last and not self.source.replayable:
            txn.set_status((self.id, self.conn_id, 0), DONE)
        txn.commit()
        rt.crash_point(self.id, "source_post_log")
        for e in evs:
            rt._send(e)
        rt.stats["events_out"] += len(evs)

    def _emit_run(self, port: str, bodies: List[Any], last: bool):
        rt = self.runtime
        chans = self.out_channels.get(port, [])
        evs: List[Event] = []
        for body in bodies:
            ssn = rt.next_ssn(port)
            evs.extend(Event(ssn, self.id, port, ch.rec_op, ch.rec_port,
                             body=body) for ch in chans)
        txn = rt.store.begin()
        if len(evs) == 1:
            txn.log_event(evs[0], UNDONE)
        elif evs:
            txn.log_events([(e, UNDONE, None) for e in evs])
        for e in evs:
            txn.put_event_data(e)
        txn.put_state(self.id, rt.new_state_id(), rt._state_blob(),
                      keep_history=rt.keep_state_history)
        if last and not self.source.replayable:
            txn.set_status((self.id, self.conn_id, 0), DONE)
        txn.commit()
        for _ in bodies:
            rt.crash_point(self.id, "source_post_log")
        for e in evs:
            rt._send(e)
        rt.stats["events_out"] += len(evs)

    def _finish(self):
        rt = self.runtime
        self.exhausted = True
        txn = rt.store.begin()
        if self.source.replayable:
            txn.set_read_action_status(self.id, self.conn_id, 0, COMPLETE)
        else:
            # Alg 1 step 2.d: GC the stored effect
            txn.delete_event_data((self.id, self.conn_id, 0))
            ScratchStore.drop((self.id, 0))
        txn.commit()

    # -- recovery (Algorithm 6 steps 2-4) ------------------------------------
    class _Driver:
        def resume(self, rt: OperatorRuntime):
            op: GeneratorSource = rt.op
            aid, ra = rt.store.get_read_action(op.id, op.conn_id)
            if ra is None:
                return      # never started — normal start will run
            if ra["status"] == COMPLETE and not op.source.replayable:
                statuses = rt.store.event_status((op.id, op.conn_id, 0))
                if any(s == DONE for _, s in statuses):
                    ScratchStore.drop((op.id, 0))    # Alg 6 step 3.a
                    op.exhausted = True
                    op._effect = []
                    return
                op._effect = ScratchStore.get((op.id, 0)) or []
            elif ra["status"] == INCOMPLETE and not op.source.replayable:
                ScratchStore.drop((op.id, 0))        # Alg 6 step 4.a: replay
                effect = op.source.effect(op.desc, 0)
                ScratchStore.put((op.id, 0), effect)
                txn = rt.store.begin()
                txn.put_read_action(op.id, op.conn_id, 0, COMPLETE, op.desc)
                rev = Event(0, op.id, op.conn_id, op.id, None,
                            body=("ref", (op.id, 0)))
                txn.log_event(rev, UNDONE)
                txn.put_event_data(rev)
                txn.commit()
                op._effect = effect
            else:
                # replayable (Alg 6 steps 3.b/4.b): replay from last offset
                op._effect = op.source.effect(op.desc, 0)
                if ra["status"] == COMPLETE:
                    op.exhausted = rt.ctx.read_offset >= len(op._effect)

    driver = _Driver()


# ---------------------------------------------------------------------------
# Middle operators
# ---------------------------------------------------------------------------

class MapOperator(Operator):
    """Stateless: one output event (or none) per input event (Sec. 2.3)."""
    def __init__(self, op_id: str, fn: Callable[[Any], Any] = lambda b: b,
                 *, processing_time: float = 0.0, out_port: str = "out",
                 deterministic: bool = True):
        super().__init__(op_id, processing_time=processing_time)
        self.fn = fn
        self.out_port = out_port
        self.deterministic = deterministic
        self._queue: List[Tuple[str, Any]] = []   # (inset_id, body)

    def on_event(self, event: Event, *, recovery_inset=None) -> List[str]:
        inset = recovery_inset or self.runtime.new_inset_id()
        self._queue.append((inset, event.body))
        return [inset]

    def triggers(self) -> List[str]:
        out = [i for i, _ in self._queue]
        return out

    def generate(self, inset_id: str):
        body = dict(self._queue)[inset_id]
        res = self.fn(body)
        return ([(self.out_port, res)] if res is not None else []), []

    def clear_inset(self, inset_id: str):
        self._queue = [(i, b) for i, b in self._queue if i != inset_id]


class CountWindowOperator(Operator):
    """Stateful: accumulate ``window`` input events, then generate one output
    event via ``agg`` (the paper's OP2/Example 3 pattern). The event count is
    the *global state*; the accumulated bodies are the *event state*."""

    def __init__(self, op_id: str, window: int,
                 agg: Callable[[List[Any]], Any] = lambda bs: bs,
                 *, processing_time: float = 0.0,
                 writes_per_output: int = 0, conn_id: str = "ext",
                 emit_output: bool = True):
        super().__init__(op_id, processing_time=processing_time)
        self.window = window
        self.agg = agg
        self.writes_per_output = writes_per_output
        self.conn_id = conn_id
        self.emit_output = emit_output
        self.count = 0                       # global state
        self.insets: Dict[str, List[Any]] = {}   # event state

    # global state = total events received (drives InSet assignment)
    def update_global(self, event: Event):
        self.count += 1

    def global_state(self):
        return {"count": self.count}

    def restore_global(self, blob):
        if blob:
            self.count = blob["count"]

    def _inset_for(self, n: int) -> str:
        return f"{self.id}:w{(n - 1) // self.window}"

    def on_event(self, event: Event, *, recovery_inset=None) -> List[str]:
        inset = recovery_inset or self._inset_for(self.count)
        self.insets.setdefault(inset, []).append(event.body)
        return [inset]

    def triggers(self) -> List[str]:
        return [i for i, bodies in self.insets.items()
                if len(bodies) >= self.window]

    def generate(self, inset_id: str):
        bodies = self.insets.get(inset_id, [])
        out_body = self.agg(bodies)
        outputs = [("out", out_body)] if self.emit_output else []
        writes = [(self.conn_id, {"inset": inset_id, "result": out_body})
                  for _ in range(self.writes_per_output)]
        return outputs, writes

    def clear_inset(self, inset_id: str):
        self.insets.pop(inset_id, None)


class SyncJoinOperator(Operator):
    """Two synchronized input ports: trigger when n1 events from in1 AND n2
    from in2 have arrived (UC2's OP4; exercises ABS alignment)."""
    input_ports = ("in1", "in2")
    output_ports = ("out",)

    def __init__(self, op_id: str, n1: int, n2: int,
                 agg: Callable[[List, List], Any] = lambda a, b: (len(a), len(b)),
                 *, processing_time: float = 0.0, writes_per_output: int = 0,
                 conn_id: str = "ext"):
        super().__init__(op_id, processing_time=processing_time)
        self.n1, self.n2 = n1, n2
        self.agg = agg
        self.writes_per_output = writes_per_output
        self.conn_id = conn_id
        self.counts = {"in1": 0, "in2": 0}   # global state
        self.windows: Dict[str, Dict[str, List]] = {}

    def update_global(self, event: Event):
        self.counts[event.rec_port] += 1

    def global_state(self):
        return dict(self.counts)

    def restore_global(self, blob):
        if blob:
            self.counts.update(blob)

    def _inset_for(self, port: str) -> str:
        n = {"in1": self.n1, "in2": self.n2}[port]
        return f"{self.id}:j{(self.counts[port] - 1) // n}"

    def on_event(self, event: Event, *, recovery_inset=None) -> List[str]:
        inset = recovery_inset or self._inset_for(event.rec_port)
        w = self.windows.setdefault(inset, {"in1": [], "in2": []})
        w[event.rec_port].append(event.body)
        return [inset]

    def triggers(self) -> List[str]:
        return [i for i, w in self.windows.items()
                if len(w["in1"]) >= self.n1 and len(w["in2"]) >= self.n2]

    def generate(self, inset_id: str):
        w = self.windows[inset_id]
        body = self.agg(w["in1"], w["in2"])
        writes = [(self.conn_id, {"inset": inset_id, "result": body})
                  for _ in range(self.writes_per_output)]
        return [("out", body)], writes

    def clear_inset(self, inset_id: str):
        self.windows.pop(inset_id, None)


class TerminalSink(Operator):
    """Sink that signals completion after ``target`` events. Each received
    body is durably recorded as a *write action* on the external system
    (checkable ⇒ exactly-once), so ``external.committed()`` is the ground
    truth for correctness assertions (the paper's 'destination' notion)."""
    output_ports: Tuple[str, ...] = ()

    def __init__(self, op_id: str, target: int,
                 on_done: Optional[Callable[[], None]] = None,
                 *, processing_time: float = 0.0, record: bool = True,
                 conn_id: str = "sink"):
        super().__init__(op_id, processing_time=processing_time)
        self.target = target
        self.on_done = on_done
        self.record = record
        self.conn_id = conn_id
        self.received: List[Any] = []       # volatile convenience view
        self._pending: Dict[str, Any] = {}
        self.seen = 0                       # global state

    def update_global(self, event: Event):
        self.seen += 1

    def global_state(self):
        return {"seen": self.seen}

    def restore_global(self, blob):
        if blob:
            self.seen = blob["seen"]

    def on_event(self, event: Event, *, recovery_inset=None) -> List[str]:
        inset = recovery_inset or self.runtime.new_inset_id()
        self._pending[inset] = event.body
        self.received.append(event.body)
        return [inset]

    def triggers(self) -> List[str]:
        return list(self._pending)

    def generate(self, inset_id: str):
        body = self._pending[inset_id]
        writes = [(self.conn_id, body)] if self.record else []
        if self.seen >= self.target and self.on_done is not None:
            self.on_done()
        return [], writes

    def clear_inset(self, inset_id: str):
        self._pending.pop(inset_id, None)
