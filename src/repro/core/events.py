"""Event model: batches of records exchanged between operators (Sec. 2.1).

Each event is identified by a System-generated Sequential Number (SSN),
unique per (sender operator, output port). Write/read actions are modelled as
events with a null sender/receiver port respectively (Sec. 3.3 / 3.5.3).
"""
from __future__ import annotations

import dataclasses
import pickle
from typing import Any, Dict, Optional

UNDONE = "undone"
DONE = "done"
REPLAY = "replay"

COMPLETE = "complete"
INCOMPLETE = "incomplete"


@dataclasses.dataclass
class Event:
    event_id: int
    send_op: str
    send_port: Optional[str]          # None => write action (Sec. 3.5.3)
    rec_op: Optional[str]
    rec_port: Optional[str]           # None => read action event (Sec. 3.3)
    body: Any = None
    header: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def is_replay(self) -> bool:
        return bool(self.header.get("replay"))

    def key(self):
        return (self.send_op, self.send_port, self.event_id)

    def clone_for(self, rec_op: str, rec_port: str) -> "Event":
        return dataclasses.replace(self, rec_op=rec_op, rec_port=rec_port,
                                   header=dict(self.header))

    # -- shared payload encode (zero-copy transports + log) ----------------
    def cache_blob(self) -> bytes:
        """``pickle((header, body))`` computed at most once per event: the
        byte-transport wire payload *and* the log's ``put_event_blob``
        payload, so the hot path serializes each event exactly once.  The
        cache must only be taken after the header is final (the replay
        flag is set before logging/sending)."""
        blob = self.__dict__.get("_blob")
        if blob is None:
            blob = pickle.dumps((self.header, self.body))
            self.__dict__["_blob"] = blob
        return blob

    def cached_blob(self):
        return self.__dict__.get("_blob")

    # the cache is derived, process-local state: never pickle it (routed
    # frames and store RPC would double-ship every payload)
    def __getstate__(self):
        d = dict(self.__dict__)
        d.pop("_blob", None)
        return d

    def __setstate__(self, d):
        self.__dict__.update(d)


@dataclasses.dataclass
class ReadAction:
    action_id: int
    op_id: str
    conn_id: str
    desc: str
    replayable: bool = True


@dataclasses.dataclass
class WriteAction:
    """A pending write action = an output event whose send_port is None and
    whose rec_port is the connection id of the external system."""
    event_id: int
    op_id: str
    conn_id: str
    body: Any
