"""Process-per-group execution mode (``Engine(mode="process")``).

Each operator group runs in its own OS process — a real pod, not a
thread — so crash = ``kill -9`` is a first-class scenario: a SIGKILL'd
worker takes its volatile operator state with it and the supervisor
warm-restarts only that group while every other worker keeps processing
(the paper's non-blocking recovery property, across actual process
boundaries).

Topology (transport-dependent; see :mod:`repro.core.transport`)::

    parent (supervisor)                      worker (one per group)
    ───────────────────                      ──────────────────────
    SupervisorTransport     ◄─ tr conn ──►   WorkerTransport
      routed: authoritative Channels           routed: replicas + credits
      socket/tcp: address broker + probes      socket/tcp: sender-held
                                                buffers, direct
                                                worker↔worker sockets
    LogBackend (the one     ◄─── RPC ─────►  StoreClient / ExternalClient /
    sqlite-family store),                    InjectorClient / ScratchClient
    ExternalSystem,
    FailureInjector,
    supervisor + router threads              protocol loop (+ socket threads)
    _ControlHub (cluster    ◄─ dial-back ──  node-agent workers connect
    mode: TCP rendezvous)                    their rpc/tr conns here

* **Worker bootstrap** — a worker never inherits the live engine object.
  It starts from a picklable
  :class:`~repro.core.transport.base.WorkerBootstrap` payload (pipeline
  spec, group assignment, transport config, incarnation) and rebuilds its
  operators purely from the payload + the log, so
  ``Engine(mode="process", ctx="spawn")`` works — and so a worker can in
  principle be launched by an ``ssh``/container entrypoint on another
  machine.  Under ``ctx="fork"`` the payload crosses by inheritance (no
  pickling), so factories may stay closures; under ``ctx="spawn"`` (and
  on node agents, which always spawn) they must be picklable.
* **Placement** — :class:`~repro.core.transport.base.Placement` maps each
  group to a node.  ``None`` spawns a direct child; a node name routes
  the bootstrap to that node's agent (see :mod:`repro.core.cluster`),
  and the worker dials its RPC + transport connections back to the
  supervisor's :class:`_ControlHub` (authkey-authenticated TCP).
* **Transport** — behind the formal interface in
  :mod:`repro.core.transport.base`.  ``routed`` keeps every authoritative
  buffer in the supervisor and pumps deliveries over the tr conn;
  ``socket``/``tcp`` move the reliable buffer to the sender-side worker
  and events bypass the supervisor entirely.  All enforce credit-based
  back-pressure at the channel capacity and preserve per-port FIFO + ack
  + durability-watermark semantics exactly as in thread mode.
* **Log store** — all workers share the parent's single store through a
  synchronous RPC proxy (:class:`StoreClient`).  Transaction ops are plain
  tuples, so they cross the conn verbatim; ``TxnAborted`` stays
  synchronous.  Group-commit batching, the durability watermark and the
  global flush-epoch 2PC all run in the parent, shared by every worker.
* **Failure injection** — crash points RPC to the parent's injector (its
  plan must outlive worker restarts); a firing plan entry answers
  ``("crash",)`` and the worker SIGKILLs itself: every injected failure in
  process mode is a genuine ``kill -9``, not an exception.
* **Done detection** — delegated to the transport: the routed supervisor
  cross-checks worker idle reports against its own delivery counters; the
  socket supervisor runs a two-wave activity probe (no central counters
  exist by design).
"""
from __future__ import annotations

import multiprocessing
import os
import signal
import threading
import time
from multiprocessing import AuthenticationError
from multiprocessing import connection as mpc
from typing import Any, Dict, List, Optional, Tuple

from repro.core.batching import make_governor
from repro.core.builtin import GeneratorSource, ScratchStore
from repro.core.logstore.base import LogBackend, TxnAborted
from repro.core.operator import OperatorRuntime, SimulatedCrash
from repro.core.recovery import recover_operator
from repro.core.transport.base import (WorkerBootstrap,
                                       make_supervisor_transport,
                                       make_worker_transport)

# a group is declared failed (and the run aborted) after this many total
# restarts — a CI hygiene bound against unbounded crash loops, far above
# any finite failure-injection plan; not a protocol constant
MAX_RESTARTS_PER_GROUP = 50

# a node-agent spawn (request -> spawned ack -> rpc/tr dial-back) must
# complete within this budget or the run is declared failed
SPAWN_TIMEOUT = 30.0


# ---------------------------------------------------------------------------
# Worker-side proxies (everything here runs in the worker process)
# ---------------------------------------------------------------------------

class _Rpc:
    """Synchronous request/response over the worker's RPC conn. The worker
    runs one protocol thread, so one outstanding request at a time by
    design (socket reader threads never touch the store)."""

    def __init__(self, conn):
        self.conn = conn

    def call(self, *msg):
        self.conn.send(msg)
        reply = self.conn.recv()
        kind = reply[0]
        if kind == "ok":
            return reply[1]
        if kind == "abort":
            raise TxnAborted(reply[1])
        if kind == "crash":
            # an injector plan entry fired: die like a real pod — SIGKILL,
            # no cleanup, no exception propagation
            os.kill(os.getpid(), signal.SIGKILL)
        raise RuntimeError(f"store RPC failed: {reply[1]}")


class StoreClient(LogBackend):
    """LogBackend proxy: forwards commits and recovery/lineage/scaling
    queries to the parent's shared store."""

    def __init__(self, rpc: _Rpc):
        self.rpc = rpc

    def _commit(self, ops):
        return self.rpc.call("txn", ops)

    def _q(self, name, *args):
        return self.rpc.call("store", name, args)

    def is_durable(self, token) -> bool:
        if token is None:
            return True
        return self._q("is_durable", token)

    def flush(self):
        self._q("flush")

    def maybe_flush(self):
        self._q("maybe_flush")

    def maybe_checkpoint(self):
        """No-op on the worker side: checkpoint cadence is driven by the
        parent's supervision loop against the real store — polling the
        watermark over RPC from every worker would be pure overhead."""

    def checkpoint(self):
        self._q("checkpoint")

    # -- recovery / scaling / lineage queries ------------------------------
    def fetch_resend_events(self, op_id):
        return self._q("fetch_resend_events", op_id)

    def fetch_ack_events(self, op_id, include_done=False):
        return self._q("fetch_ack_events", op_id, include_done)

    def fetch_replay_outputs(self, op_id):
        return self._q("fetch_replay_outputs", op_id)

    def undone_outputs_after(self, op_id, port, min_id):
        return self._q("undone_outputs_after", op_id, port, min_id)

    def get_write_actions(self, op_id):
        return self._q("get_write_actions", op_id)

    def get_state(self, op_id):
        return self._q("get_state", op_id)

    def last_sent_ssn(self, op_id):
        return self._q("last_sent_ssn", op_id)

    def last_acked(self, op_id):
        return self._q("last_acked", op_id)

    def event_status(self, key, rec_op=None):
        return self._q("event_status", key, rec_op)

    def get_read_action(self, op_id, conn_id):
        return self._q("get_read_action", op_id, conn_id)

    def undone_events_from(self, send_op, rec_op):
        return self._q("undone_events_from", send_op, rec_op)

    def lineage_insets_of(self, event_key):
        return self._q("lineage_insets_of", event_key)

    def lineage_events_of_inset(self, rec_op, inset_id):
        return self._q("lineage_events_of_inset", rec_op, inset_id)

    def lineage_outputs_of_inset(self, send_op, inset_id):
        return self._q("lineage_outputs_of_inset", send_op, inset_id)

    def insets_of_event(self, event_key, rec_op):
        return self._q("insets_of_event", event_key, rec_op)

    def consumers_of(self, event_key):
        return self._q("consumers_of", event_key)

    def gc(self, lineage_ops=()):
        return self._q("gc", tuple(lineage_ops))


class ExternalClient:
    """ExternalSystem proxy: write actions must land in the parent's
    durable external system (the ground truth for exactly-once)."""

    def __init__(self, rpc: _Rpc):
        self.rpc = rpc

    def execute(self, op_id, conn_id, event_id, body) -> bool:
        return self.rpc.call("ext", "execute", (op_id, conn_id, event_id,
                                                body))

    def status(self, op_id, conn_id, event_id) -> str:
        return self.rpc.call("ext", "status", (op_id, conn_id, event_id))


class ScratchClient:
    """ScratchStore backend proxy: effects of non-replayable read actions
    must survive worker restarts, so they live in the parent."""

    def __init__(self, rpc: _Rpc):
        self.rpc = rpc

    def put(self, key, value):
        self.rpc.call("scratch", "put", (key, value))

    def get(self, key):
        return self.rpc.call("scratch", "get", (key,))

    def drop(self, key):
        self.rpc.call("scratch", "drop", (key,))


class InjectorClient:
    """crash_point proxy. The injector's plan lives in the parent (it must
    survive worker restarts); a firing entry kills this worker with
    SIGKILL — real process death, not an exception."""

    def __init__(self, rpc: _Rpc):
        self.rpc = rpc

    def __call__(self, op_id: str, point: str):
        self.rpc.call("inj", op_id, point)


def _worker_main(bootstrap: WorkerBootstrap, rpc_conn, tr_conn):
    """The worker: rebuild the group's operators from the bootstrap
    payload against proxy store/external/channels, recover from the log
    if asked, then run the thread-mode group loop with deliveries
    arriving over the transport.  Nothing here reads parent memory."""
    group = bootstrap.group
    recover = bootstrap.recover
    rpc = _Rpc(rpc_conn)
    store = StoreClient(rpc)
    external = ExternalClient(rpc)
    injector = InjectorClient(rpc)
    ScratchStore.backend = ScratchClient(rpc)

    wt = make_worker_transport(bootstrap.transport, bootstrap, group,
                               tr_conn)
    group_ops = bootstrap.group_ops()
    channels = wt.channels
    ops, runtimes = {}, {}
    for op_id in group_ops:
        op = bootstrap.factories[op_id]()
        op.state = "restarted" if recover else "running"
        op.in_channels = {}
        op.out_channels = {p: [] for p in op.output_ports}
        for ch in channels.values():
            if ch.rec_op == op_id:
                op.in_channels[ch.rec_port] = ch
            if ch.send_op == op_id:
                op.out_channels.setdefault(ch.send_port, []).append(ch)
        lin_in, lin_out = bootstrap.lineage_ports.get(op_id, (set(), set()))
        ops[op_id] = op
        rec_info = bootstrap.recovery or {}
        group_mode = rec_info.get("modes", {}).get(group, "log")
        runtimes[op_id] = OperatorRuntime(
            op, store, lineage_in=lin_in, lineage_out=lin_out,
            external=external, crash_point=injector,
            stop_flag=lambda: wt.stopped,
            replay_mode=op_id in bootstrap.replay_ops,
            keep_state_history=bool(lin_out),
            state_interval=(rec_info.get("interval", 16)
                            if group_mode == "epoch" else 1))
        runtimes[op_id].governor = make_governor(bootstrap.batching)

    if recover:
        rec_info = bootstrap.recovery or {}
        # epoch groups (and groups freshly switched off epoch, marked
        # stale) recover from a possibly-interval-stale snapshot: include
        # DONE rows so completed inputs' global contributions replay
        include_done = (rec_info.get("modes", {}).get(group) == "epoch"
                        or group in rec_info.get("stale", ()))
        for op_id in group_ops:
            op = ops[op_id]
            is_source = isinstance(op, GeneratorSource)
            replay_pred_ports = {dp for s, sp, d, dp, _ in
                                 bootstrap.connections
                                 if d == op_id and s in bootstrap.replay_ops}
            recover_operator(runtimes[op_id], is_source=is_source,
                             source_driver=GeneratorSource.driver
                             if is_source else None,
                             replay_pred_ports=replay_pred_ports,
                             include_done=include_done)

    sources = [op for op in ops.values() if isinstance(op, GeneratorSource)]
    last_stats = 0.0

    def step_op(op) -> bool:
        rt = runtimes[op.id]
        gov = rt.governor
        if isinstance(op, GeneratorSource):
            if gov is not None:
                n = gov.limit(op.pending_emits())
                if n > 1:
                    t0 = time.monotonic()
                    k = op.step_run(n)
                    gov.observe(k, time.monotonic() - t0)
                    return k > 0
            return op.step()
        progressed = False
        for port in op.input_ports:
            ch = op.in_channels.get(port)
            if ch is None:
                continue
            if gov is not None:
                # governed run draining: apply already-delivered backlog
                # through one vectored pass (see docs/batching.md)
                n = gov.limit(ch.unprocessed())
                if n > 1:
                    evs = ch.peek_run(n)
                    if evs:
                        t0 = time.monotonic()
                        k = rt.handle_inputs(port, evs)
                        gov.observe(k, time.monotonic() - t0)
                        progressed = progressed or k > 0
                    continue
            ev = ch.peek()
            if ev is not None:
                rt.handle_input(port, ev)
                progressed = True
        return progressed

    def send_stats():
        out = {}
        for o in group_ops:
            c = dict(runtimes[o].stats)
            gov = runtimes[o].governor
            if gov is not None:
                gs = gov.stats()
                c["gov_runs"] = gs["runs"]
                c["gov_events"] = gs["events"]
                c["gov_max_run"] = gs["max_run"]
            # "g_"-prefixed keys are live gauges of THIS incarnation: the
            # supervisor keeps them out of the cumulative fold
            c["g_queue_depth"] = sum(ch.unprocessed()
                                     for ch in ops[o].in_channels.values())
            out[o] = c
        wt.send_stats(out)

    while True:
        wt.pump(0)
        if wt.stopped:
            # final snapshot — short-lived runs would otherwise stop inside
            # the 0.05s throttle window with counters never reported
            send_stats()
            return

        wt.begin_step()
        progressed = False
        for op_id in group_ops:
            progressed |= step_op(ops[op_id])
            progressed |= runtimes[op_id].drain_durable()
        if not progressed and wt.take_force():
            # end of stream (per the supervisor): push the durability
            # watermark so held acks/external writes release
            for op_id in group_ops:
                progressed |= runtimes[op_id].drain_durable(force=True)

        state = {
            "exhausted": all(s.exhausted for s in sources),
            "deferred": sum(len(runtimes[o]._deferred) for o in group_ops),
            "pending": any(ops[o].has_pending() for o in group_ops),
        }
        now = time.time()
        if progressed:
            wt.boundary(state)
            if now - last_stats >= 0.05:
                send_stats()
                last_stats = now
            continue
        if now - last_stats >= 0.05:
            send_stats()
            last_stats = now
        wt.report_idle(state)
        wt.pump(0.005)


def _dial_control(bootstrap: WorkerBootstrap, kind: str):
    """Connect one channel (``"rpc"``/``"tr"``) back to the supervisor's
    control hub — how a node-agent worker, started from nothing but the
    bootstrap payload, reaches its supervisor."""
    addr, authkey = bootstrap.control
    conn = mpc.Client(addr, authkey=authkey)
    conn.send(("worker", kind, bootstrap.group, bootstrap.incarnation))
    return conn


def _worker_entry(bootstrap: WorkerBootstrap, rpc_conn=None, tr_conn=None):
    try:
        if rpc_conn is None:
            rpc_conn = _dial_control(bootstrap, "rpc")
            tr_conn = _dial_control(bootstrap, "tr")
        _worker_main(bootstrap, rpc_conn, tr_conn)
    except (EOFError, BrokenPipeError, OSError, AuthenticationError):
        pass                       # parent stopped / conn torn down
    finally:
        # skip interpreter teardown: under fork the child inherited parent
        # resources (sqlite connections, thread locks) that must not be
        # finalized here; under spawn there is simply nothing to flush
        os._exit(0)


# ---------------------------------------------------------------------------
# Parent side
# ---------------------------------------------------------------------------

class _WorkerHandle:
    def __init__(self, group: str):
        self.group = group
        self.proc: Optional[Any] = None    # mp.Process or _RemoteProc
        self.node: Optional[str] = None    # placement of this incarnation
        self.rpc_conn = None
        self.tr_conn = None
        self.rpc_thread: Optional[threading.Thread] = None
        self.tr_thread: Optional[threading.Thread] = None
        self.send_lock = threading.Lock()
        # serializes delivery pumping toward this worker: held for a whole
        # pump loop, and by the restart path while it rewinds cursors, so a
        # stale pump can never interleave with a fresh incarnation
        self.pump_lock = threading.Lock()
        self.sent = 0                  # "ev" deliveries to this incarnation
        self.last_idle: Optional[dict] = None
        self.probe: Optional[Any] = None   # (round, snapshot) — socket
        self.alive = False
        self.stopping = False
        self.restarts = 0              # total for this group (never reset)
        self.incarnation = 0           # bumped on every (re)spawn
        self.spawn_token = 0           # bumped before each spawn attempt:
        # the bootstrap/dial-back rendezvous id (the incarnation itself is
        # only bumped once the worker's conns are attached, in the same
        # critical section as the credit-window computation)

    def send(self, msg, incarnation: Optional[int] = None) -> bool:
        """Send to the worker. ``incarnation`` pins the message to the
        incarnation it was computed against: a credit grant derived from
        a buffer pop must not land on a fresh incarnation whose initial
        window already accounts for that pop (double grant)."""
        with self.send_lock:
            if not self.alive:
                return False
            if incarnation is not None and incarnation != self.incarnation:
                return False
            try:
                self.tr_conn.send(msg)
                return True
            except (BrokenPipeError, OSError):
                return False


class _RemoteProc:
    """Process-like handle for a worker launched via a node agent: pid and
    liveness come from agent reports over the control hub, and kill is
    routed through the agent (the supervisor cannot signal a pid on
    another host).  A dead node (agent conn EOF) makes every worker on it
    report dead — genuine whole-node failure semantics."""

    def __init__(self, node: "_NodeHandle", group: str, token: int):
        self.node = node
        self.group = group
        self.token = token
        self.pid: Optional[int] = None
        self._pid_evt = threading.Event()
        self._exit_evt = threading.Event()

    def set_pid(self, pid: int):
        self.pid = pid
        self._pid_evt.set()

    def wait_pid(self, timeout: float) -> Optional[int]:
        self._pid_evt.wait(timeout)
        return self.pid

    def mark_exited(self):
        self._exit_evt.set()

    def is_alive(self) -> bool:
        return not self._exit_evt.is_set() and self.node.alive

    def join(self, timeout: Optional[float] = None):
        deadline = None if timeout is None else time.time() + timeout
        while not self._exit_evt.is_set() and self.node.alive:
            if deadline is not None and time.time() >= deadline:
                return
            self._exit_evt.wait(0.05)

    def kill(self):
        if self.pid is not None:
            self.node.send(("kill", self.pid))


class _NodeHandle:
    """Supervisor-side view of one node agent's control connection."""

    def __init__(self, driver: "ProcessEngineDriver", name: str, pid: int,
                 conn):
        self.driver = driver
        self.name = name
        self.pid = pid
        self.conn = conn
        self.alive = True
        self.lock = threading.Lock()       # send + proc registry
        self.procs: Dict[Tuple[str, int], _RemoteProc] = {}

    def send(self, msg) -> bool:
        with self.lock:
            if not self.alive:
                return False
            try:
                self.conn.send(msg)
                return True
            except (OSError, ValueError):
                self.alive = False
                return False

    def loop(self):
        while True:
            try:
                msg = self.conn.recv()
            except (EOFError, OSError):
                self.driver.on_node_dead(self)
                return
            kind = msg[0]
            with self.lock:
                p = self.procs.get((msg[1], msg[2]))
            if p is None:
                continue
            if kind == "spawned":
                p.set_pid(msg[3])
            elif kind == "exit":
                p.mark_exited()


class _ControlHub:
    """Supervisor-side rendezvous listener (AF_INET + authkey): node
    agents announce themselves here, and bootstrap-only workers dial
    their RPC and transport connections back — the supervisor half of a
    worker start that involves no fork inheritance at all."""

    def __init__(self, driver: "ProcessEngineDriver",
                 host: str = "127.0.0.1"):
        self.driver = driver
        self.authkey = os.urandom(20)
        self.listener = mpc.Listener((host, 0), family="AF_INET",
                                     authkey=self.authkey)
        self.address = self.listener.address
        self._cv = threading.Condition()
        self._pending: Dict[Tuple[str, str, int], Any] = {}
        self._closed = False
        threading.Thread(target=self._accept_loop, daemon=True,
                         name="ctl-hub").start()

    def _accept_loop(self):
        while not self._closed:
            try:
                conn = self.listener.accept()
                hello = conn.recv()
            except AuthenticationError:
                continue                  # wrong/missing authkey: reject
            except (OSError, EOFError):
                if self._closed:
                    return
                # dead dialer mid-handshake: keep listening; the sleep
                # bounds the spin if accept() itself fails persistently
                time.sleep(0.01)
                continue
            if not (isinstance(hello, tuple) and hello):
                conn.close()
                continue
            if hello[0] == "node":
                self.driver.on_node_connected(hello[1], hello[2], conn)
            elif hello[0] == "worker":
                with self._cv:
                    self._pending[(hello[1], hello[2], hello[3])] = conn
                    self._cv.notify_all()
            else:
                conn.close()

    def wait_worker(self, kind: str, group: str, token: int,
                    timeout: float):
        """The (kind, group, spawn-token) dial-back conn, or None."""
        deadline = time.time() + timeout
        key = (kind, group, token)
        with self._cv:
            while key not in self._pending:
                left = deadline - time.time()
                if left <= 0:
                    return None
                self._cv.wait(left)
            return self._pending.pop(key)

    def close(self):
        self._closed = True
        try:
            self.listener.close()
        except OSError:
            pass


class ProcessEngineDriver:
    """Supervisor: starts one worker process per operator group (direct
    child under the configured mp context, or via a node agent per the
    placement), owns the shared store/external/injector and the
    transport's supervisor half, detects worker death (SIGKILL included)
    and warm-restarts only the failed group while the rest keep
    processing."""

    def __init__(self, engine):
        self.e = engine
        self.ctx = multiprocessing.get_context(engine.proc_ctx)
        self.lock = threading.RLock()
        self.workers: Dict[str, _WorkerHandle] = {}
        self.ch_by_name: Dict[str, Any] = {}
        self._stop = threading.Event()
        self._failed = threading.Event()
        self._supervisor: Optional[threading.Thread] = None
        self._hub: Optional[_ControlHub] = None
        self._nodes: Dict[str, _NodeHandle] = {}
        self._nodes_cv = threading.Condition()
        # cumulative per-op event counters across worker incarnations
        # (live worker stats land in _op_stats_live, folded into
        # _op_stats_base when the incarnation dies)
        self._op_stats_base: Dict[str, Dict[str, int]] = {}
        self._op_stats_live: Dict[str, Dict[str, int]] = {}
        # full per-operator counter dicts (txns, batched_runs,
        # recovery_scan_batches, ...), same base/live split — op_stats()
        # keeps its collapsed events_in+events_out shape for the benches
        self._op_detail_base: Dict[str, Dict[str, Dict[str, int]]] = {}
        self._op_detail_live: Dict[str, Dict[str, Dict[str, int]]] = {}
        # wire-level transport counters (superframes/bytes/coalescing),
        # same base/live split per group
        self._wire_base: Dict[str, Dict[str, int]] = {}
        self._wire_live: Dict[str, Dict[str, int]] = {}
        # instantaneous gauges ("g_"-prefixed keys in worker stats, e.g.
        # queue depth) — live-only: a dead incarnation's gauge is
        # meaningless, so these are never folded into a base
        self._op_gauge_live: Dict[str, Dict[str, Dict[str, int]]] = {}
        with self.lock:
            self.ch_by_name = {ch.name: ch for ch in self.e.channels}
        self.transport = make_supervisor_transport(engine.transport, self)

    # ---- channel bookkeeping --------------------------------------------
    def refresh_channels(self):
        """(Re)index the engine's authoritative channels — called at start
        and after dynamic-scaling topology changes."""
        with self.lock:
            self.ch_by_name = {ch.name: ch for ch in self.e.channels}
        self.transport.sync_channels()

    def record_stats(self, group: str, stats: Dict[str, dict]):
        """Live per-operator counters from a worker (under self.lock)."""
        stats = dict(stats)
        wire = stats.pop("__wire__", None)
        if wire is not None:
            self._wire_live[group] = dict(wire)
        counters: Dict[str, Dict[str, int]] = {}
        gauges: Dict[str, Dict[str, int]] = {}
        for op, s in stats.items():
            c = counters[op] = {}
            g = gauges[op] = {}
            for k, n in s.items():
                (g if k.startswith("g_") else c)[k] = n
        self._op_stats_live[group] = {
            op: s.get("events_in", 0) + s.get("events_out", 0)
            for op, s in counters.items()}
        self._op_detail_live[group] = counters
        self._op_gauge_live[group] = gauges

    def pump_all(self):
        """Re-deliver/rebroadcast after a topology change (scaling)."""
        self.transport.after_rewire()

    # ---- node agents -----------------------------------------------------
    def on_node_connected(self, name: str, pid: int, conn):
        """A node agent dialed the control hub (cluster start or warm node
        restart): adopt the fresh connection; a previous incarnation of
        the node is dead by definition."""
        nh = _NodeHandle(self, name, pid, conn)
        with self._nodes_cv:
            old = self._nodes.get(name)
            if old is not None:
                old.alive = False
            self._nodes[name] = nh
            self._nodes_cv.notify_all()
        threading.Thread(target=nh.loop, daemon=True,
                         name=f"node-{name}").start()

    def on_node_dead(self, nh: _NodeHandle):
        """Agent conn EOF = the node died.  Every worker on it reports
        dead (their handles' `_RemoteProc.is_alive` goes False), so the
        supervision loop warm-restarts exactly those groups — after
        `_ensure_node` brings a fresh agent up — while workers on other
        nodes keep processing."""
        nh.alive = False
        with self._nodes_cv:
            self._nodes_cv.notify_all()

    def _ensure_node(self, name: str, timeout: float = 20.0) -> _NodeHandle:
        with self._nodes_cv:
            nh = self._nodes.get(name)
            if nh is not None and nh.alive:
                return nh
        cluster = self.e.cluster
        if cluster is None:
            raise RuntimeError(
                f"group placed on node {name!r} but no cluster= given")
        cluster.ensure_node(name)
        deadline = time.time() + timeout
        with self._nodes_cv:
            while True:
                nh = self._nodes.get(name)
                if nh is not None and nh.alive:
                    return nh
                left = deadline - time.time()
                if left <= 0:
                    raise RuntimeError(f"node {name!r} did not come up")
                self._nodes_cv.wait(left)

    # ---- lifecycle -------------------------------------------------------
    def start(self):
        if self.e.cluster is not None:
            self._hub = _ControlHub(self)
            self.e.cluster.start(self._hub.address, self._hub.authkey)
        for g in sorted(set(self.e.pipeline.groups.values())):
            self._spawn(g, recover=self.e._resume)
        self._supervisor = threading.Thread(target=self._supervise,
                                            daemon=True, name="proc-super")
        self._supervisor.start()

    def _remote_spawn(self, node: str, group: str, token: int,
                      bootstrap: WorkerBootstrap):
        """Launch a worker through a node agent: ship the bootstrap, wait
        for the spawned ack and the worker's rpc/tr dial-backs.  One
        retry after re-ensuring the node covers an agent that died
        between placement lookup and spawn."""
        last_err = "node unavailable"
        for _attempt in range(2):
            try:
                nh = self._ensure_node(node)
            except RuntimeError as exc:
                last_err = str(exc)
                continue
            proc = _RemoteProc(nh, group, token)
            with nh.lock:
                for key in [k for k in nh.procs if k[0] == group]:
                    del nh.procs[key]       # dead incarnations' entries
                nh.procs[(group, token)] = proc
            if not nh.send(("spawn", bootstrap)):
                last_err = f"node {node!r} connection lost"
                continue
            if proc.wait_pid(SPAWN_TIMEOUT / 2) is None:
                last_err = f"node {node!r} never acknowledged the spawn"
                continue
            rpc_conn = self._hub.wait_worker("rpc", group, token,
                                             SPAWN_TIMEOUT / 2)
            tr_conn = self._hub.wait_worker("tr", group, token,
                                            SPAWN_TIMEOUT / 2)
            if rpc_conn is None or tr_conn is None:
                last_err = f"worker {group!r} never dialed back"
                continue
            return proc, rpc_conn, tr_conn
        raise RuntimeError(
            f"spawn of {group!r} on node {node!r} failed: {last_err}")

    def _spawn(self, group: str, recover: bool):
        node = self.e.placement.node_of(group)
        with self.lock:
            h = self.workers.get(group)
            if h is None:
                h = _WorkerHandle(group)
                self.workers[group] = h
            h.spawn_token += 1
            token = h.spawn_token
            h.stopping = False
            bootstrap = self.e.make_bootstrap(group, recover=recover,
                                              incarnation=token)
        if node is None:
            # direct child of the supervisor under the configured context:
            # fork inherits the (unpicklable-safe) payload, spawn pickles
            # it — either way the worker reads only the bootstrap
            rpc_parent, rpc_child = self.ctx.Pipe()
            tr_parent, tr_child = self.ctx.Pipe()
            proc = self.ctx.Process(target=_worker_entry,
                                    args=(bootstrap, rpc_child, tr_child),
                                    daemon=True, name=f"logio-{group}")
            proc.start()
            rpc_child.close()
            tr_child.close()
            rpc_conn, tr_conn = rpc_parent, tr_parent
        else:
            bootstrap.control = (self._hub.address, self._hub.authkey)
            try:
                proc, rpc_conn, tr_conn = self._remote_spawn(
                    node, group, token, bootstrap)
            except RuntimeError:
                if self._stop.is_set():
                    return
                with self.lock:
                    self.e.group_state[group] = "failed"
                self._failed.set()
                return
        with self.lock:
            with h.send_lock:      # serialize with incarnation-pinned sends
                h.rpc_conn, h.tr_conn = rpc_conn, tr_conn
                h.incarnation += 1
            h.sent = 0
            h.last_idle = None
            h.probe = None
            h.proc = proc
            h.node = node
            h.alive = True
            self.e.group_state[group] = "running"
            h.rpc_thread = threading.Thread(
                target=self._rpc_loop, args=(h,), daemon=True,
                name=f"rpc-{group}")
            h.tr_thread = threading.Thread(
                target=self.transport.tr_loop, args=(h,), daemon=True,
                name=f"tr-{group}")
            h.rpc_thread.start()
            h.tr_thread.start()
            # computed under the driver lock, in the same critical section
            # as the incarnation bump: no concurrent ack-grant can observe
            # a buffer state this initial window has not accounted for
            initial_msgs = self.transport.on_spawn_locked(h)
            inc = h.incarnation
        for m in initial_msgs:         # conn sends outside the driver lock
            h.send(m, incarnation=inc)
        self.transport.on_spawned(h)
        if self._stop.is_set() or h.stopping:
            h.send(("stop",))          # stop raced the (remote) spawn

    # ---- parent RPC thread ----------------------------------------------
    def _rpc_loop(self, h: _WorkerHandle):
        store, ext = self.e.store, self.e.external
        conn = h.rpc_conn
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                return
            kind = msg[0]
            try:
                if kind == "txn":
                    try:
                        reply = ("ok", store._commit(msg[1]))
                    except TxnAborted as exc:
                        reply = ("abort", str(exc))
                elif kind == "store":
                    reply = ("ok", getattr(store, msg[1])(*msg[2]))
                elif kind == "ext":
                    reply = ("ok", getattr(ext, msg[1])(*msg[2]))
                elif kind == "scratch":
                    reply = ("ok", getattr(ScratchStore, msg[1])(*msg[2]))
                elif kind == "inj":
                    try:
                        self.e.injector(msg[1], msg[2])
                        reply = ("ok", None)
                    except SimulatedCrash:
                        reply = ("crash",)
                else:
                    reply = ("err", f"unknown RPC {kind!r}")
            except Exception as exc:   # surface store errors in the worker
                reply = ("err", f"{type(exc).__name__}: {exc}")
            try:
                conn.send(reply)
            except (BrokenPipeError, OSError):
                return

    # ---- supervision -----------------------------------------------------
    def _supervise(self):
        while not self._stop.is_set():
            self._check_deaths()
            # checkpoint cadence lives here (not in the workers): the store
            # is shared across groups, so one supervisor-side compaction
            # truncates the log for everyone
            self.e.store.maybe_checkpoint()
            if not self._failed.is_set() and self.transport.check_done():
                self.e._done.set()
                return
            time.sleep(0.005)

    def _check_deaths(self):
        dead: List[_WorkerHandle] = []
        with self.lock:
            for h in self.workers.values():
                if h.alive and h.proc is not None and not h.proc.is_alive() \
                        and not h.stopping:
                    h.alive = False
                    dead.append(h)
        for h in dead:
            self._on_worker_death(h)

    def _on_worker_death(self, h: _WorkerHandle):
        """A worker died (SIGKILL, injected crash, node death, or error).
        Volatile state is gone; the store and the external system live in
        this process and buffered events are either held by the transport
        or re-derivable from the log — roll back by warm-restarting only
        this group (non-blocking for the others)."""
        group = h.group
        self.e.failures += 1
        self.e.group_state[group] = "dead"
        h.proc.join()
        # drain every message the worker managed to send before dying
        for t in (h.rpc_thread, h.tr_thread):
            if t is not None:
                t.join(timeout=5.0)
        with self.lock:
            self._fold_stats_locked(group)
            h.restarts += 1
            if h.restarts > MAX_RESTARTS_PER_GROUP:
                self.e.group_state[group] = "failed"
                self._failed.set()
                return
        # transport-side rewind (routed: delivery cursors + inflight;
        # socket: stale address/probe state) — takes its own locks so a
        # stale pump of the dead incarnation finishes first
        self.transport.before_respawn(h)
        if self.e.restart_delay > 0:
            time.sleep(self.e.restart_delay)       # warm pod restart
        if self._stop.is_set():
            return
        self.e.restarts += 1
        self._spawn(group, recover=True)

    # ---- external controls ----------------------------------------------
    def kill_group(self, group: str):
        """SIGKILL the group's worker — genuine node failure.  Remote
        workers are killed through their node agent (the supervisor
        cannot signal a pid on another host)."""
        with self.lock:
            h = self.workers.get(group)
            proc = h.proc if h is not None and h.alive else None
        if proc is None:
            return
        if isinstance(proc, _RemoteProc):
            proc.kill()
            return
        if proc.pid is not None:
            try:
                os.kill(proc.pid, signal.SIGKILL)
            except ProcessLookupError:
                pass

    def stop_group(self, group: str, *, remove: bool = False):
        """Stop a worker deliberately (dynamic scaling): not a failure."""
        with self.lock:
            h = self.workers.get(group)
            if h is None:
                return
            h.stopping = True
        h.send(("stop",))
        if h.proc is not None:
            h.proc.join(timeout=2.0)
            if h.proc.is_alive():
                h.proc.kill()
                h.proc.join(timeout=5.0)
        # drain the router threads BEFORE folding the stats — a buffered
        # final "stats" message would otherwise re-populate the live map
        # after the fold and double-count the incarnation
        for t in (h.rpc_thread, h.tr_thread):
            if t is not None:
                t.join(timeout=5.0)
        with self.lock:
            h.alive = False
            self._fold_stats_locked(group)
            if remove:
                self.workers.pop(group, None)

    def start_group(self, group: str, *, recover: bool):
        """(Re)start a group's worker (dynamic scaling) — lands on
        whatever node the placement currently assigns, so scaling can
        move or add replicas across nodes."""
        self.refresh_channels()
        if recover:
            h = self.workers.get(group)
            if h is not None:
                self.transport.before_respawn(h)
        self._spawn(group, recover=recover)

    def wait_group_drained(self, group: str, timeout: float = 5.0) -> bool:
        """Block until the group's worker has consumed every delivery and
        no event involving its operators is buffered or in flight —
        dynamic scaling must not delete a channel that still buffers a
        logged-and-sent event (nobody would resend it once the endpoint
        is gone)."""
        return self.transport.wait_group_drained(group, timeout)

    def _fold_stats_locked(self, group: str) -> None:
        """An incarnation died/stopped: fold its live counters into the
        cumulative base (driver lock held)."""
        base = self._op_stats_base.setdefault(group, {})
        for op, n in self._op_stats_live.pop(group, {}).items():
            base[op] = base.get(op, 0) + n
        dbase = self._op_detail_base.setdefault(group, {})
        for op, s in self._op_detail_live.pop(group, {}).items():
            acc = dbase.setdefault(op, {})
            for k, n in s.items():
                if k == "gov_max_run":  # high-water mark, not a sum
                    acc[k] = max(acc.get(k, 0), n)
                else:
                    acc[k] = acc.get(k, 0) + n
        self._op_gauge_live.pop(group, None)
        wbase = self._wire_base.setdefault(group, {})
        for k, n in self._wire_live.pop(group, {}).items():
            wbase[k] = wbase.get(k, 0) + n

    def op_stats(self) -> Dict[str, int]:
        """Cumulative processed-event counters per operator across worker
        incarnations (benchmark instrumentation)."""
        with self.lock:
            out: Dict[str, int] = {}
            for g, ops in self._op_stats_base.items():
                for op, n in ops.items():
                    out[op] = out.get(op, 0) + n
            for g, ops in self._op_stats_live.items():
                for op, n in ops.items():
                    out[op] = out.get(op, 0) + n
            return out

    def op_stats_detail(self) -> Dict[str, Dict[str, int]]:
        """Full per-operator counter dicts (txns, batched_runs/_events,
        recovery_scan_batches, ...) summed across incarnations."""
        with self.lock:
            out: Dict[str, Dict[str, int]] = {}
            for src in (self._op_detail_base, self._op_detail_live):
                for g, ops in src.items():
                    for op, s in ops.items():
                        acc = out.setdefault(op, {})
                        for k, n in s.items():
                            acc[k] = acc.get(k, 0) + n
            return out

    def wire_stats(self) -> Dict[str, float]:
        """Cumulative wire-protocol counters across all workers and
        incarnations (byte transports only; empty under ``routed``):
        superframes, bytes, events and control entries carried, plus the
        derived coalescing ratios the benchmarks report."""
        with self.lock:
            out: Dict[str, float] = {}
            for src in (self._wire_base, self._wire_live):
                for g, w in src.items():
                    for k, n in w.items():
                        out[k] = out.get(k, 0) + n
            if out.get("frames"):
                out["events_per_frame"] = out.get("events", 0) / out["frames"]
            if out.get("ctrl_frames"):
                out["ctrl_per_ctrl_frame"] = (out.get("ctrl", 0)
                                              / out["ctrl_frames"])
            return out

    def metrics_raw(self):
        """Raw material for ``Engine.metrics()``: per-op counter dicts
        summed across incarnations (``gov_max_run`` is a high-water mark
        and MAX-folds), per-op instantaneous queue depths from the live
        gauges, and the summed wire counters without derived ratios."""
        with self.lock:
            counters: Dict[str, Dict[str, int]] = {}
            for src in (self._op_detail_base, self._op_detail_live):
                for g, ops in src.items():
                    for op, s in ops.items():
                        acc = counters.setdefault(op, {})
                        for k, n in s.items():
                            if k == "gov_max_run":
                                acc[k] = max(acc.get(k, 0), n)
                            else:
                                acc[k] = acc.get(k, 0) + n
            qdepth: Dict[str, int] = {}
            for g, ops in self._op_gauge_live.items():
                for op, gauges in ops.items():
                    qdepth[op] = (qdepth.get(op, 0)
                                  + int(gauges.get("g_queue_depth", 0)))
            wire: Dict[str, float] = {}
            for src in (self._wire_base, self._wire_live):
                for g, w in src.items():
                    for k, n in w.items():
                        wire[k] = wire.get(k, 0) + n
            return counters, qdepth, wire

    def wait(self, timeout: float) -> bool:
        deadline = time.time() + timeout
        while time.time() < deadline:
            if self.e._done.is_set():
                return True
            if self._failed.is_set():
                return False
            time.sleep(0.005)
        return False

    def stop(self):
        self._stop.set()
        with self.lock:
            handles = list(self.workers.values())
        for h in handles:
            h.stopping = True
            h.send(("stop",))
        for h in handles:
            if h.proc is not None:
                h.proc.join(timeout=2.0)
                if h.proc.is_alive():
                    h.proc.kill()
                    h.proc.join(timeout=5.0)
            h.alive = False
        if self._supervisor is not None:
            self._supervisor.join(timeout=5.0)
        self.transport.request_stop()
        with self._nodes_cv:
            nodes = list(self._nodes.values())
        for nh in nodes:
            nh.send(("stop",))
        if self.e.cluster is not None:
            self.e.cluster.stop()
        if self._hub is not None:
            self._hub.close()
        for h in handles:
            for conn in (h.rpc_conn, h.tr_conn):
                try:
                    conn.close()
                except OSError:
                    pass
            for t in (h.rpc_thread, h.tr_thread):
                if t is not None:
                    t.join(timeout=5.0)
