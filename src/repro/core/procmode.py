"""Process-per-group execution mode (``Engine(mode="process")``).

Each operator group runs in its own forked OS process — a real pod, not a
thread — so crash = ``kill -9`` is a first-class scenario: a SIGKILL'd
worker takes its volatile operator state with it and the supervisor
warm-restarts only that group while every other worker keeps processing
(the paper's non-blocking recovery property, across actual process
boundaries).

Topology (transport-dependent; see :mod:`repro.core.transport`)::

    parent (supervisor)                      worker (one per group)
    ───────────────────                      ──────────────────────
    SupervisorTransport     ◄─ tr pipe ──►   WorkerTransport
      routed: authoritative Channels           routed: replicas + credits
      socket: address broker + probes          socket: sender-held buffers,
                                                direct worker↔worker sockets
    LogBackend (the one     ◄─── RPC ─────►  StoreClient / ExternalClient /
    sqlite-family store),                    InjectorClient / ScratchClient
    ExternalSystem,
    FailureInjector,
    supervisor + router threads              protocol loop (+ socket threads)

* **Transport** — behind the formal interface in
  :mod:`repro.core.transport.base`.  ``routed`` keeps every authoritative
  buffer in the supervisor and pumps deliveries over pipes; ``socket``
  moves the reliable buffer to the sender-side worker and events bypass
  the supervisor entirely.  Both enforce credit-based back-pressure at
  the channel capacity and both preserve per-port FIFO + ack +
  durability-watermark semantics exactly as in thread mode.
* **Log store** — all workers share the parent's single store through a
  synchronous RPC proxy (:class:`StoreClient`).  Transaction ops are plain
  tuples, so they cross the pipe verbatim; ``TxnAborted`` stays
  synchronous.  Group-commit batching, the durability watermark and the
  global flush-epoch 2PC all run in the parent, shared by every worker.
* **Failure injection** — crash points RPC to the parent's injector (its
  plan must outlive worker restarts); a firing plan entry answers
  ``("crash",)`` and the worker SIGKILLs itself: every injected failure in
  process mode is a genuine ``kill -9``, not an exception.
* **Done detection** — delegated to the transport: the routed supervisor
  cross-checks worker idle reports against its own delivery counters; the
  socket supervisor runs a two-wave activity probe (no central counters
  exist by design).

Workers are forked (``multiprocessing`` "fork" context), so operator
factories need not be picklable; only :class:`~repro.core.events.Event`
payloads and transaction op tuples cross process boundaries.
"""
from __future__ import annotations

import multiprocessing
import os
import signal
import threading
import time
from typing import Any, Dict, List, Optional

from repro.core.builtin import GeneratorSource, ScratchStore
from repro.core.logstore.base import LogBackend, TxnAborted
from repro.core.operator import OperatorRuntime, SimulatedCrash
from repro.core.recovery import recover_operator
from repro.core.transport.base import (make_supervisor_transport,
                                       make_worker_transport)

_CTX = multiprocessing.get_context("fork")

# a group is declared failed (and the run aborted) after this many total
# restarts — a CI hygiene bound against unbounded crash loops, far above
# any finite failure-injection plan; not a protocol constant
MAX_RESTARTS_PER_GROUP = 50


# ---------------------------------------------------------------------------
# Worker-side proxies (everything here runs in the forked child)
# ---------------------------------------------------------------------------

class _Rpc:
    """Synchronous request/response over the worker's RPC pipe. The worker
    runs one protocol thread, so one outstanding request at a time by
    design (socket reader threads never touch the store)."""

    def __init__(self, conn):
        self.conn = conn

    def call(self, *msg):
        self.conn.send(msg)
        reply = self.conn.recv()
        kind = reply[0]
        if kind == "ok":
            return reply[1]
        if kind == "abort":
            raise TxnAborted(reply[1])
        if kind == "crash":
            # an injector plan entry fired: die like a real pod — SIGKILL,
            # no cleanup, no exception propagation
            os.kill(os.getpid(), signal.SIGKILL)
        raise RuntimeError(f"store RPC failed: {reply[1]}")


class StoreClient(LogBackend):
    """LogBackend proxy: forwards commits and recovery/lineage/scaling
    queries to the parent's shared store."""

    def __init__(self, rpc: _Rpc):
        self.rpc = rpc

    def _commit(self, ops):
        return self.rpc.call("txn", ops)

    def _q(self, name, *args):
        return self.rpc.call("store", name, args)

    def is_durable(self, token) -> bool:
        if token is None:
            return True
        return self._q("is_durable", token)

    def flush(self):
        self._q("flush")

    def maybe_flush(self):
        self._q("maybe_flush")

    # -- recovery / scaling / lineage queries ------------------------------
    def fetch_resend_events(self, op_id):
        return self._q("fetch_resend_events", op_id)

    def fetch_ack_events(self, op_id):
        return self._q("fetch_ack_events", op_id)

    def fetch_replay_outputs(self, op_id):
        return self._q("fetch_replay_outputs", op_id)

    def undone_outputs_after(self, op_id, port, min_id):
        return self._q("undone_outputs_after", op_id, port, min_id)

    def get_write_actions(self, op_id):
        return self._q("get_write_actions", op_id)

    def get_state(self, op_id):
        return self._q("get_state", op_id)

    def last_sent_ssn(self, op_id):
        return self._q("last_sent_ssn", op_id)

    def last_acked(self, op_id):
        return self._q("last_acked", op_id)

    def event_status(self, key, rec_op=None):
        return self._q("event_status", key, rec_op)

    def get_read_action(self, op_id, conn_id):
        return self._q("get_read_action", op_id, conn_id)

    def undone_events_from(self, send_op, rec_op):
        return self._q("undone_events_from", send_op, rec_op)

    def lineage_insets_of(self, event_key):
        return self._q("lineage_insets_of", event_key)

    def lineage_events_of_inset(self, rec_op, inset_id):
        return self._q("lineage_events_of_inset", rec_op, inset_id)

    def lineage_outputs_of_inset(self, send_op, inset_id):
        return self._q("lineage_outputs_of_inset", send_op, inset_id)

    def insets_of_event(self, event_key, rec_op):
        return self._q("insets_of_event", event_key, rec_op)

    def consumers_of(self, event_key):
        return self._q("consumers_of", event_key)

    def gc(self, lineage_ops=()):
        return self._q("gc", tuple(lineage_ops))


class ExternalClient:
    """ExternalSystem proxy: write actions must land in the parent's
    durable external system (the ground truth for exactly-once)."""

    def __init__(self, rpc: _Rpc):
        self.rpc = rpc

    def execute(self, op_id, conn_id, event_id, body) -> bool:
        return self.rpc.call("ext", "execute", (op_id, conn_id, event_id,
                                                body))

    def status(self, op_id, conn_id, event_id) -> str:
        return self.rpc.call("ext", "status", (op_id, conn_id, event_id))


class ScratchClient:
    """ScratchStore backend proxy: effects of non-replayable read actions
    must survive worker restarts, so they live in the parent."""

    def __init__(self, rpc: _Rpc):
        self.rpc = rpc

    def put(self, key, value):
        self.rpc.call("scratch", "put", (key, value))

    def get(self, key):
        return self.rpc.call("scratch", "get", (key,))

    def drop(self, key):
        self.rpc.call("scratch", "drop", (key,))


class InjectorClient:
    """crash_point proxy. The injector's plan lives in the parent (it must
    survive worker restarts); a firing entry kills this worker with
    SIGKILL — real process death, not an exception."""

    def __init__(self, rpc: _Rpc):
        self.rpc = rpc

    def __call__(self, op_id: str, point: str):
        self.rpc.call("inj", op_id, point)


def _worker_main(engine, group: str, rpc_conn, tr_conn, recover: bool):
    """The forked worker: rebuild the group's operators against proxy
    store/external/channels, recover if asked, then run the thread-mode
    group loop with deliveries arriving over the transport."""
    rpc = _Rpc(rpc_conn)
    store = StoreClient(rpc)
    external = ExternalClient(rpc)
    injector = InjectorClient(rpc)
    ScratchStore.backend = ScratchClient(rpc)

    wt = make_worker_transport(engine.transport, engine, group, tr_conn)
    pipeline = engine.pipeline
    group_ops = [o for o, g in pipeline.groups.items() if g == group]
    channels = wt.channels
    ops, runtimes = {}, {}
    for op_id in group_ops:
        op = pipeline.factories[op_id]()
        op.state = "restarted" if recover else "running"
        op.in_channels = {}
        op.out_channels = {p: [] for p in op.output_ports}
        for ch in channels.values():
            if ch.rec_op == op_id:
                op.in_channels[ch.rec_port] = ch
            if ch.send_op == op_id:
                op.out_channels.setdefault(ch.send_port, []).append(ch)
        lin_in, lin_out = engine._lineage_ports.get(op_id, (set(), set()))
        ops[op_id] = op
        runtimes[op_id] = OperatorRuntime(
            op, store, lineage_in=lin_in, lineage_out=lin_out,
            external=external, crash_point=injector,
            stop_flag=lambda: wt.stopped,
            replay_mode=op_id in engine.replay_ops,
            keep_state_history=bool(lin_out))

    if recover:
        for op_id in group_ops:
            op = ops[op_id]
            is_source = isinstance(op, GeneratorSource)
            replay_pred_ports = {dp for s, sp, d, dp, _ in
                                 pipeline.connections
                                 if d == op_id and s in engine.replay_ops}
            recover_operator(runtimes[op_id], is_source=is_source,
                             source_driver=GeneratorSource.driver
                             if is_source else None,
                             replay_pred_ports=replay_pred_ports)

    sources = [op for op in ops.values() if isinstance(op, GeneratorSource)]
    last_stats = 0.0

    def step_op(op) -> bool:
        if isinstance(op, GeneratorSource):
            return op.step()
        progressed = False
        for port in op.input_ports:
            ch = op.in_channels.get(port)
            if ch is None:
                continue
            ev = ch.peek()
            if ev is not None:
                runtimes[op.id].handle_input(port, ev)
                progressed = True
        return progressed

    def send_stats():
        wt.send_stats({o: dict(runtimes[o].stats) for o in group_ops})

    while True:
        wt.pump(0)
        if wt.stopped:
            return

        wt.begin_step()
        progressed = False
        for op_id in group_ops:
            progressed |= step_op(ops[op_id])
            progressed |= runtimes[op_id].drain_durable()
        if not progressed and wt.take_force():
            # end of stream (per the supervisor): push the durability
            # watermark so held acks/external writes release
            for op_id in group_ops:
                progressed |= runtimes[op_id].drain_durable(force=True)

        state = {
            "exhausted": all(s.exhausted for s in sources),
            "deferred": sum(len(runtimes[o]._deferred) for o in group_ops),
            "pending": any(ops[o].has_pending() for o in group_ops),
        }
        now = time.time()
        if progressed:
            wt.boundary(state)
            if now - last_stats >= 0.05:
                send_stats()
                last_stats = now
            continue
        if now - last_stats >= 0.05:
            send_stats()
            last_stats = now
        wt.report_idle(state)
        wt.pump(0.005)


def _worker_entry(engine, group, rpc_conn, tr_conn, recover):
    try:
        _worker_main(engine, group, rpc_conn, tr_conn, recover)
    except (EOFError, BrokenPipeError, OSError):
        pass                       # parent stopped / pipe torn down
    finally:
        # skip interpreter teardown: the fork inherited parent resources
        # (sqlite connections, thread locks) that must not be finalized here
        os._exit(0)


# ---------------------------------------------------------------------------
# Parent side
# ---------------------------------------------------------------------------

class _WorkerHandle:
    def __init__(self, group: str):
        self.group = group
        self.proc: Optional[Any] = None
        self.rpc_conn = None
        self.tr_conn = None
        self.rpc_thread: Optional[threading.Thread] = None
        self.tr_thread: Optional[threading.Thread] = None
        self.send_lock = threading.Lock()
        # serializes delivery pumping toward this worker: held for a whole
        # pump loop, and by the restart path while it rewinds cursors, so a
        # stale pump can never interleave with a fresh incarnation
        self.pump_lock = threading.Lock()
        self.sent = 0                  # "ev" deliveries to this incarnation
        self.last_idle: Optional[dict] = None
        self.probe: Optional[Any] = None   # (round, snapshot) — socket
        self.alive = False
        self.stopping = False
        self.restarts = 0              # total for this group (never reset)
        self.incarnation = 0           # bumped on every (re)spawn

    def send(self, msg, incarnation: Optional[int] = None) -> bool:
        """Send to the worker. ``incarnation`` pins the message to the
        incarnation it was computed against: a credit grant derived from
        a buffer pop must not land on a fresh incarnation whose initial
        window already accounts for that pop (double grant)."""
        with self.send_lock:
            if not self.alive:
                return False
            if incarnation is not None and incarnation != self.incarnation:
                return False
            try:
                self.tr_conn.send(msg)
                return True
            except (BrokenPipeError, OSError):
                return False


class ProcessEngineDriver:
    """Supervisor: spawns one forked worker per operator group, owns the
    shared store/external/injector and the transport's supervisor half,
    detects worker death (SIGKILL included) and warm-restarts only the
    failed group while the rest keep processing."""

    def __init__(self, engine):
        self.e = engine
        self.lock = threading.RLock()
        self.workers: Dict[str, _WorkerHandle] = {}
        self.ch_by_name: Dict[str, Any] = {}
        self._stop = threading.Event()
        self._failed = threading.Event()
        self._supervisor: Optional[threading.Thread] = None
        # cumulative per-op event counters across worker incarnations
        # (live worker stats land in _op_stats_live, folded into
        # _op_stats_base when the incarnation dies)
        self._op_stats_base: Dict[str, Dict[str, int]] = {}
        self._op_stats_live: Dict[str, Dict[str, int]] = {}
        with self.lock:
            self.ch_by_name = {ch.name: ch for ch in self.e.channels}
        self.transport = make_supervisor_transport(engine.transport, self)

    # ---- channel bookkeeping --------------------------------------------
    def refresh_channels(self):
        """(Re)index the engine's authoritative channels — called at start
        and after dynamic-scaling topology changes."""
        with self.lock:
            self.ch_by_name = {ch.name: ch for ch in self.e.channels}
        self.transport.sync_channels()

    def record_stats(self, group: str, stats: Dict[str, dict]):
        """Live per-operator counters from a worker (under self.lock)."""
        self._op_stats_live[group] = {
            op: s.get("events_in", 0) + s.get("events_out", 0)
            for op, s in stats.items()}

    def pump_all(self):
        """Re-deliver/rebroadcast after a topology change (scaling)."""
        self.transport.after_rewire()

    # ---- lifecycle -------------------------------------------------------
    def start(self):
        for g in sorted(set(self.e.pipeline.groups.values())):
            self._spawn(g, recover=self.e._resume)
        self._supervisor = threading.Thread(target=self._supervise,
                                            daemon=True, name="proc-super")
        self._supervisor.start()

    def _spawn(self, group: str, recover: bool):
        with self.lock:
            h = self.workers.get(group)
            if h is None:
                h = _WorkerHandle(group)
                self.workers[group] = h
            rpc_parent, rpc_child = _CTX.Pipe()
            tr_parent, tr_child = _CTX.Pipe()
            with h.send_lock:      # serialize with incarnation-pinned sends
                h.rpc_conn, h.tr_conn = rpc_parent, tr_parent
                h.incarnation += 1
            h.sent = 0
            h.last_idle = None
            h.probe = None
            h.stopping = False
            proc = _CTX.Process(target=_worker_entry,
                                args=(self.e, group, rpc_child, tr_child,
                                      recover),
                                daemon=True, name=f"logio-{group}")
            proc.start()
            rpc_child.close()
            tr_child.close()
            h.proc = proc
            h.alive = True
            self.e.group_state[group] = "running"
            h.rpc_thread = threading.Thread(
                target=self._rpc_loop, args=(h,), daemon=True,
                name=f"rpc-{group}")
            h.tr_thread = threading.Thread(
                target=self.transport.tr_loop, args=(h,), daemon=True,
                name=f"tr-{group}")
            h.rpc_thread.start()
            h.tr_thread.start()
            # computed under the driver lock, in the same critical section
            # as the incarnation bump: no concurrent ack-grant can observe
            # a buffer state this initial window has not accounted for
            initial_msgs = self.transport.on_spawn_locked(h)
            inc = h.incarnation
        for m in initial_msgs:         # pipe sends outside the driver lock
            h.send(m, incarnation=inc)
        self.transport.on_spawned(h)

    # ---- parent RPC thread ----------------------------------------------
    def _rpc_loop(self, h: _WorkerHandle):
        store, ext = self.e.store, self.e.external
        conn = h.rpc_conn
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                return
            kind = msg[0]
            try:
                if kind == "txn":
                    try:
                        reply = ("ok", store._commit(msg[1]))
                    except TxnAborted as exc:
                        reply = ("abort", str(exc))
                elif kind == "store":
                    reply = ("ok", getattr(store, msg[1])(*msg[2]))
                elif kind == "ext":
                    reply = ("ok", getattr(ext, msg[1])(*msg[2]))
                elif kind == "scratch":
                    reply = ("ok", getattr(ScratchStore, msg[1])(*msg[2]))
                elif kind == "inj":
                    try:
                        self.e.injector(msg[1], msg[2])
                        reply = ("ok", None)
                    except SimulatedCrash:
                        reply = ("crash",)
                else:
                    reply = ("err", f"unknown RPC {kind!r}")
            except Exception as exc:   # surface store errors in the worker
                reply = ("err", f"{type(exc).__name__}: {exc}")
            try:
                conn.send(reply)
            except (BrokenPipeError, OSError):
                return

    # ---- supervision -----------------------------------------------------
    def _supervise(self):
        while not self._stop.is_set():
            self._check_deaths()
            if not self._failed.is_set() and self.transport.check_done():
                self.e._done.set()
                return
            time.sleep(0.005)

    def _check_deaths(self):
        dead: List[_WorkerHandle] = []
        with self.lock:
            for h in self.workers.values():
                if h.alive and h.proc is not None and not h.proc.is_alive() \
                        and not h.stopping:
                    h.alive = False
                    dead.append(h)
        for h in dead:
            self._on_worker_death(h)

    def _on_worker_death(self, h: _WorkerHandle):
        """A worker died (SIGKILL, injected crash, or error). Volatile
        state is gone; the store and the external system live in this
        process and buffered events are either held by the transport or
        re-derivable from the log — roll back by warm-restarting only
        this group (non-blocking for the others)."""
        group = h.group
        self.e.failures += 1
        self.e.group_state[group] = "dead"
        h.proc.join()
        # drain every message the worker managed to send before dying
        for t in (h.rpc_thread, h.tr_thread):
            if t is not None:
                t.join(timeout=5.0)
        with self.lock:
            base = self._op_stats_base.setdefault(group, {})
            for op, n in self._op_stats_live.pop(group, {}).items():
                base[op] = base.get(op, 0) + n
            h.restarts += 1
            if h.restarts > MAX_RESTARTS_PER_GROUP:
                self.e.group_state[group] = "failed"
                self._failed.set()
                return
        # transport-side rewind (routed: delivery cursors + inflight;
        # socket: stale address/probe state) — takes its own locks so a
        # stale pump of the dead incarnation finishes first
        self.transport.before_respawn(h)
        if self.e.restart_delay > 0:
            time.sleep(self.e.restart_delay)       # warm pod restart
        if self._stop.is_set():
            return
        self.e.restarts += 1
        self._spawn(group, recover=True)

    # ---- external controls ----------------------------------------------
    def kill_group(self, group: str):
        """SIGKILL the group's worker — genuine node failure."""
        with self.lock:
            h = self.workers.get(group)
            pid = h.proc.pid if h is not None and h.alive else None
        if pid is not None:
            try:
                os.kill(pid, signal.SIGKILL)
            except ProcessLookupError:
                pass

    def stop_group(self, group: str, *, remove: bool = False):
        """Stop a worker deliberately (dynamic scaling): not a failure."""
        with self.lock:
            h = self.workers.get(group)
            if h is None:
                return
            h.stopping = True
        h.send(("stop",))
        if h.proc is not None:
            h.proc.join(timeout=2.0)
            if h.proc.is_alive():
                h.proc.kill()
                h.proc.join()
        # drain the router threads BEFORE folding the stats — a buffered
        # final "stats" message would otherwise re-populate the live map
        # after the fold and double-count the incarnation
        for t in (h.rpc_thread, h.tr_thread):
            if t is not None:
                t.join(timeout=5.0)
        with self.lock:
            h.alive = False
            base = self._op_stats_base.setdefault(group, {})
            for op, n in self._op_stats_live.pop(group, {}).items():
                base[op] = base.get(op, 0) + n
            if remove:
                self.workers.pop(group, None)

    def start_group(self, group: str, *, recover: bool):
        """(Re)start a group's worker (dynamic scaling)."""
        self.refresh_channels()
        if recover:
            h = self.workers.get(group)
            if h is not None:
                self.transport.before_respawn(h)
        self._spawn(group, recover=recover)

    def wait_group_drained(self, group: str, timeout: float = 5.0) -> bool:
        """Block until the group's worker has consumed every delivery and
        no event involving its operators is buffered or in flight —
        dynamic scaling must not delete a channel that still buffers a
        logged-and-sent event (nobody would resend it once the endpoint
        is gone)."""
        return self.transport.wait_group_drained(group, timeout)

    def op_stats(self) -> Dict[str, int]:
        """Cumulative processed-event counters per operator across worker
        incarnations (benchmark instrumentation)."""
        with self.lock:
            out: Dict[str, int] = {}
            for g, ops in self._op_stats_base.items():
                for op, n in ops.items():
                    out[op] = out.get(op, 0) + n
            for g, ops in self._op_stats_live.items():
                for op, n in ops.items():
                    out[op] = out.get(op, 0) + n
            return out

    def wait(self, timeout: float) -> bool:
        deadline = time.time() + timeout
        while time.time() < deadline:
            if self.e._done.is_set():
                return True
            if self._failed.is_set():
                return False
            time.sleep(0.005)
        return False

    def stop(self):
        self._stop.set()
        with self.lock:
            handles = list(self.workers.values())
        for h in handles:
            h.stopping = True
            h.send(("stop",))
        for h in handles:
            if h.proc is not None:
                h.proc.join(timeout=2.0)
                if h.proc.is_alive():
                    h.proc.kill()
                    h.proc.join()
            h.alive = False
        if self._supervisor is not None:
            self._supervisor.join(timeout=5.0)
        self.transport.request_stop()
        for h in handles:
            for conn in (h.rpc_conn, h.tr_conn):
                try:
                    conn.close()
                except OSError:
                    pass
            for t in (h.rpc_thread, h.tr_thread):
                if t is not None:
                    t.join(timeout=5.0)
