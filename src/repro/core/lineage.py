"""Data lineage: scopes, path enumeration (Sec. 3.1), and queries (Sec. 7.3).

A scope (start, target) of port ids implicitly defines the data lineage
paths; for every (OP.in, OP.out) subsequence on a path, capture is enabled
for those ports of OP. Queries join EVENT_LINEAGE x EVENT_LOG:

  backward(event)  : output event -> InSet_ID -> input events (recursively)
  forward(event)   : input event -> InSet_IDs it joined -> output events
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import defaultdict
from typing import Dict, FrozenSet, List, Sequence, Set, Tuple

from repro.core.logstore import LogBackend


@dataclasses.dataclass(frozen=True)
class LineageScope:
    start: Tuple[str, str]     # (op_id, output_port)
    target: Tuple[str, str]    # (op_id, output_port)

    def __post_init__(self):
        for name in ("start", "target"):
            val = getattr(self, name)
            if isinstance(val, list):
                val = tuple(val)
                object.__setattr__(self, name, val)
            if not (isinstance(val, tuple) and len(val) == 2
                    and all(isinstance(x, str) and x for x in val)):
                raise ValueError(
                    f"LineageScope.{name} must be an (op_id, port) pair of "
                    f"non-empty strings (got {val!r})")


def _paths(pipeline, start: Tuple[str, str], target: Tuple[str, str]
           ) -> List[List[Tuple[str, str]]]:
    """All port-id paths start -> target over the connection graph."""
    edges = pipeline.edges()    # ((send_op, send_port), (rec_op, rec_port))
    # adjacency: an operator's input port leads to all its output ports
    out_ports: Dict[str, Set[str]] = defaultdict(set)
    for (s, sp), _ in edges:
        out_ports[s].add(sp)
    # the target may be an output port with no outgoing connection (the
    # terminal operator of the scope) — the connection graph alone never
    # mentions it, so declare it or the walk cannot enter it
    out_ports[target[0]].add(target[1])
    results = []

    def walk(port, path, traversed: FrozenSet[Tuple]):
        """``traversed`` carries the path's consecutive (port, port) pairs
        as a set — membership is O(1) instead of rebuilding the full edge
        list per candidate (quadratic in path length on wide diamonds)."""
        if port == target:
            results.append(path)
            return
        # from an output port follow connections to input ports
        for (s, sp), (d, dp) in edges:
            if (s, sp) == port:
                # enter operator d at dp, then leave via each of its outputs
                for op_out in out_ports.get(d, ()):  # (d, op_out)
                    step = ((d, dp), (d, op_out))
                    if step not in traversed:
                        walk((d, op_out), path + [(d, dp), (d, op_out)],
                             traversed | {(port, (d, dp)), step})
                if not out_ports.get(d) and (d, dp) == target:
                    results.append(path + [(d, dp)])

    walk(start, [start], frozenset())
    return results


def enabled_ports(pipeline, scopes: Sequence[LineageScope]
                  ) -> Dict[str, Tuple[Set[str], Set[str]]]:
    """op_id -> (enabled input ports IN, enabled output ports OUT)."""
    out: Dict[str, Tuple[Set[str], Set[str]]] = defaultdict(
        lambda: (set(), set()))
    for scope in scopes:
        for path in _paths(pipeline, scope.start, scope.target):
            # subsequences (OP.in, OP.out)
            for i in range(len(path) - 1):
                (op1, p1), (op2, p2) = path[i], path[i + 1]
                if op1 == op2:      # in -> out inside one operator
                    ins, outs = out[op1]
                    ins.add(p1)
                    outs.add(p2)
        # the start port itself has capture enabled as an output
        sop, sport = scope.start
        out[sop][1].add(sport)
    return dict(out)


# ---------------------------------------------------------------------------
# queries — deprecated free-function surface
# ---------------------------------------------------------------------------
# The walks moved to repro.core.lineagequery.LineageQuery (typed EventKey
# results, scan-time filtering, bounded growth). These shims keep the old
# tuple-list signatures working one release longer.

def backward(store: LogBackend, event_key: Tuple[str, str, int],
             depth: int = 64) -> List[Tuple[str, str, int]]:
    """Deprecated: use ``LineageQuery(store).backward(key)``."""
    warnings.warn(
        "repro.core.lineage.backward is deprecated; use "
        "repro.core.LineageQuery(store).backward(key)",
        DeprecationWarning, stacklevel=2)
    from repro.core.lineagequery import LineageQuery
    return LineageQuery(store).backward(event_key, depth=depth).keys()


def forward(store: LogBackend, event_key: Tuple[str, str, int],
            rec_op: str, depth: int = 64) -> List[Tuple[str, str, int]]:
    """Deprecated: use ``LineageQuery(store).forward(key, rec_op)``."""
    warnings.warn(
        "repro.core.lineage.forward is deprecated; use "
        "repro.core.LineageQuery(store).forward(key, rec_op)",
        DeprecationWarning, stacklevel=2)
    from repro.core.lineagequery import LineageQuery
    return LineageQuery(store).forward(event_key, rec_op, depth=depth).keys()
