"""Data lineage: scopes, path enumeration (Sec. 3.1), and queries (Sec. 7.3).

A scope (start, target) of port ids implicitly defines the data lineage
paths; for every (OP.in, OP.out) subsequence on a path, capture is enabled
for those ports of OP. Queries join EVENT_LINEAGE x EVENT_LOG:

  backward(event)  : output event -> InSet_ID -> input events (recursively)
  forward(event)   : input event -> InSet_IDs it joined -> output events
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Sequence, Set, Tuple

from repro.core.logstore import LogBackend


@dataclasses.dataclass(frozen=True)
class LineageScope:
    start: Tuple[str, str]     # (op_id, output_port)
    target: Tuple[str, str]    # (op_id, output_port)


def _paths(pipeline, start: Tuple[str, str], target: Tuple[str, str]
           ) -> List[List[Tuple[str, str]]]:
    """All port-id paths start -> target over the connection graph."""
    edges = pipeline.edges()    # ((send_op, send_port), (rec_op, rec_port))
    # adjacency: an operator's input port leads to all its output ports
    out_ports: Dict[str, Set[str]] = defaultdict(set)
    for (s, sp), _ in edges:
        out_ports[s].add(sp)
    # the target may be an output port with no outgoing connection (the
    # terminal operator of the scope) — the connection graph alone never
    # mentions it, so declare it or the walk cannot enter it
    out_ports[target[0]].add(target[1])
    results = []

    def walk(port, path):
        if port == target:
            results.append(path)
            return
        op = port[0]
        # from an output port follow connections to input ports
        for (s, sp), (d, dp) in edges:
            if (s, sp) == port:
                # enter operator d at dp, then leave via each of its outputs
                for op_out in out_ports.get(d, ()):  # (d, op_out)
                    if ((d, dp), (d, op_out)) not in [(path[i], path[i + 1])
                                                      for i in range(len(path) - 1)]:
                        walk((d, op_out), path + [(d, dp), (d, op_out)])
                if not out_ports.get(d) and (d, dp) == target:
                    results.append(path + [(d, dp)])

    walk(start, [start])
    return results


def enabled_ports(pipeline, scopes: Sequence[LineageScope]
                  ) -> Dict[str, Tuple[Set[str], Set[str]]]:
    """op_id -> (enabled input ports IN, enabled output ports OUT)."""
    out: Dict[str, Tuple[Set[str], Set[str]]] = defaultdict(
        lambda: (set(), set()))
    for scope in scopes:
        for path in _paths(pipeline, scope.start, scope.target):
            # subsequences (OP.in, OP.out)
            for i in range(len(path) - 1):
                (op1, p1), (op2, p2) = path[i], path[i + 1]
                if op1 == op2:      # in -> out inside one operator
                    ins, outs = out[op1]
                    ins.add(p1)
                    outs.add(p2)
        # the start port itself has capture enabled as an output
        sop, sport = scope.start
        out[sop][1].add(sport)
    return dict(out)


# ---------------------------------------------------------------------------
# queries
# ---------------------------------------------------------------------------

def backward(store: LogBackend, event_key: Tuple[str, str, int],
             depth: int = 64) -> List[Tuple[str, str, int]]:
    """Input events (transitively) used to produce ``event_key`` =
    (send_op, send_port, event_id). Returns source-most event keys plus all
    intermediate contributors, ordered."""
    seen: Set[Tuple] = set()
    frontier = [event_key]
    contributors: List[Tuple[str, str, int]] = []
    for _ in range(depth):
        nxt = []
        for ev in frontier:
            op = ev[0]
            for inset in store.lineage_insets_of(ev):
                for ik in store.lineage_events_of_inset(op, inset):
                    if ik not in seen:
                        seen.add(ik)
                        contributors.append(ik)
                        nxt.append(ik)
        if not nxt:
            break
        frontier = nxt
    return contributors


def forward(store: LogBackend, event_key: Tuple[str, str, int],
            rec_op: str, depth: int = 64) -> List[Tuple[str, str, int]]:
    """Output events (transitively) derived from ``event_key`` as consumed
    by ``rec_op``."""
    seen: Set[Tuple] = set()
    results: List[Tuple[str, str, int]] = []
    frontier = [(event_key, rec_op)]
    for _ in range(depth):
        nxt = []
        for ev, op in frontier:
            for inset in store.insets_of_event(ev, op):
                for ok in store.lineage_outputs_of_inset(op, inset):
                    if ok not in seen:
                        seen.add(ok)
                        results.append(ok)
                        for consumer in store.consumers_of(ok):
                            if consumer != op:
                                nxt.append((ok, consumer))
        if not nxt:
            break
        frontier = nxt
    return results
