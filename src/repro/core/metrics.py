"""Unified typed metrics plane — the single sensing surface for the
adaptive recovery controller (and benchmarks, and humans).

Historically the engine exposed five incompatible ad-hoc stats dicts:
``op_stats`` / ``op_stats_detail`` / ``wire_stats`` / ``process_stats``
on the engine, ``query_stats`` on the store backends and ``stats()`` on
the batch governor.  This module folds all of them into one frozen,
documented schema:

  * :class:`OpMetrics`        — per-operator runtime counters + gauges
  * :class:`TransportMetrics` — wire-protocol counters (byte transports)
  * :class:`StoreMetrics`     — log-backend scan/commit effort
  * :class:`MetricsSnapshot`  — one coherent point-in-time view

``Engine.metrics()`` is the only entry point; it returns the same typed
snapshot in thread, step and process mode.  The legacy accessors remain
as DeprecationWarning shims (see docs/metrics.md for the field-by-field
mapping).

All counters are cumulative (monotone) across worker incarnations;
gauges (``queue_depth``) are instantaneous and never folded across
incarnations.  Consumers that want rates diff two snapshots — see
``repro.core.controller`` for the canonical delta loop.
"""
from __future__ import annotations

import dataclasses
import time
from types import MappingProxyType
from typing import Any, Dict, Mapping, Optional, Tuple


def _frozen(d: Optional[Mapping]) -> Mapping:
    return MappingProxyType(dict(d or {}))


@dataclasses.dataclass(frozen=True)
class OpMetrics:
    """Cumulative counters + instantaneous gauges for one operator.

    Counters come from the operator runtime (``rt.stats``) and its batch
    governor; in process mode they are summed across worker incarnations
    by the supervisor (``gov_max_run`` folds by max, ``queue_depth`` is a
    live gauge of the current incarnation only).
    """

    op_id: str
    group: str = ""
    # -- event flow ------------------------------------------------------
    events_in: int = 0
    events_out: int = 0
    txns: int = 0
    # -- latency/stall accounting (microseconds, cumulative) -------------
    commit_us: int = 0          # time spent inside store txn commits
    send_stall_us: int = 0      # time blocked in credit-gated channel puts
    # -- backlog gauge ---------------------------------------------------
    queue_depth: int = 0        # unprocessed events buffered at the inputs
    # -- micro-batching --------------------------------------------------
    batched_runs: int = 0
    batched_events: int = 0
    gov_runs: int = 0
    gov_events: int = 0
    gov_max_run: int = 0
    # -- recovery replay accounting --------------------------------------
    recovered_resends: int = 0
    recovered_inputs: int = 0
    recovery_scan_batches: int = 0

    @property
    def processed(self) -> int:
        """The legacy ``process_stats`` collapse: events in + out."""
        return self.events_in + self.events_out

    @property
    def avg_commit_us(self) -> float:
        return self.commit_us / self.txns if self.txns else 0.0

    @property
    def avg_run_length(self) -> float:
        runs = self.gov_runs or self.batched_runs
        events = self.gov_events or self.batched_events
        return events / runs if runs else 0.0


@dataclasses.dataclass(frozen=True)
class TransportMetrics:
    """Wire-protocol counters, summed across workers and incarnations.
    Zero-valued under the ``local``/``routed`` transports (no byte wire)."""

    frames: int = 0
    bytes: int = 0
    events: int = 0
    ctrl: int = 0
    ctrl_frames: int = 0
    extra: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: _frozen(None))

    @property
    def events_per_frame(self) -> float:
        return self.events / self.frames if self.frames else 0.0

    @property
    def ctrl_per_ctrl_frame(self) -> float:
        return self.ctrl / self.ctrl_frames if self.ctrl_frames else 0.0


@dataclasses.dataclass(frozen=True)
class StoreMetrics:
    """Log-backend effort counters: lineage-query scan counters plus any
    backend-specific keys (segment skip counts, commit totals) in
    ``extra``."""

    rows_scanned: int = 0
    rows_returned: int = 0
    commits: int = 0
    bytes_written: int = 0
    extra: Mapping[str, int] = dataclasses.field(
        default_factory=lambda: _frozen(None))


@dataclasses.dataclass(frozen=True)
class MetricsSnapshot:
    """One coherent point-in-time view of the whole engine.

    ``ops`` maps operator id -> :class:`OpMetrics`; ``transport`` and
    ``store`` aggregate the wire and log layers.  ``ts`` is
    ``time.monotonic()`` at capture, so two snapshots diff into rates.
    """

    ts: float
    mode: str
    protocol: str
    failures: int = 0
    restarts: int = 0
    ops: Mapping[str, OpMetrics] = dataclasses.field(
        default_factory=lambda: _frozen(None))
    transport: TransportMetrics = dataclasses.field(
        default_factory=TransportMetrics)
    store: StoreMetrics = dataclasses.field(default_factory=StoreMetrics)
    recovery_modes: Mapping[str, str] = dataclasses.field(
        default_factory=lambda: _frozen(None))

    def op(self, op_id: str) -> OpMetrics:
        return self.ops.get(op_id) or OpMetrics(op_id)

    def group_total(self, attr: str, group: Optional[str] = None) -> int:
        """Sum one counter over all ops (optionally one group)."""
        return sum(getattr(m, attr) for m in self.ops.values()
                   if group is None or m.group == group)


# ---------------------------------------------------------------------------
# builders (internal plumbing for Engine.metrics())
# ---------------------------------------------------------------------------

#: rt.stats / detail-dict keys folded straight into OpMetrics fields
_OP_COUNTER_KEYS: Tuple[str, ...] = (
    "events_in", "events_out", "txns", "commit_us", "send_stall_us",
    "batched_runs", "batched_events", "gov_runs", "gov_events",
    "gov_max_run", "recovered_resends", "recovered_inputs",
    "recovery_scan_batches")


def op_metrics_from_counters(op_id: str, counters: Mapping[str, Any], *,
                             group: str = "", queue_depth: int = 0
                             ) -> OpMetrics:
    """Build one :class:`OpMetrics` from a raw runtime counter dict (the
    ``rt.stats`` shape, optionally extended with ``gov_*`` keys)."""
    kw = {k: int(counters.get(k, 0)) for k in _OP_COUNTER_KEYS}
    return OpMetrics(op_id=op_id, group=group, queue_depth=int(queue_depth),
                     **kw)


def transport_metrics_from_wire(wire: Mapping[str, float]
                                ) -> TransportMetrics:
    """Fold a raw wire-counter dict (the legacy ``wire_stats`` shape) into
    a :class:`TransportMetrics`; unknown keys land in ``extra``."""
    known = ("frames", "bytes", "events", "ctrl", "ctrl_frames")
    extra = {k: v for k, v in wire.items()
             if k not in known
             and k not in ("events_per_frame", "ctrl_per_ctrl_frame")}
    return TransportMetrics(
        frames=int(wire.get("frames", 0)),
        bytes=int(wire.get("bytes", 0)),
        events=int(wire.get("events", 0)),
        ctrl=int(wire.get("ctrl", 0)),
        ctrl_frames=int(wire.get("ctrl_frames", 0)),
        extra=_frozen(extra))


def store_metrics_from_backend(store) -> StoreMetrics:
    """Read a backend's scan counters (the non-deprecated path — backends'
    public ``query_stats()`` is a DeprecationWarning shim)."""
    q: Dict[str, int] = dict(store._query_stats())
    return StoreMetrics(
        rows_scanned=int(q.pop("rows_scanned", 0)),
        rows_returned=int(q.pop("rows_returned", 0)),
        commits=int(getattr(store, "commits", 0)),
        bytes_written=int(getattr(store, "bytes_written", 0)),
        extra=_frozen(q))


def build_snapshot(*, mode: str, protocol: str, failures: int, restarts: int,
                   op_counters: Mapping[str, Mapping[str, Any]],
                   groups: Mapping[str, str],
                   queue_depths: Mapping[str, int],
                   wire: Mapping[str, float], store,
                   recovery_modes: Mapping[str, str]) -> MetricsSnapshot:
    ops = {op: op_metrics_from_counters(
               op, counters, group=groups.get(op, op),
               queue_depth=queue_depths.get(op, 0))
           for op, counters in op_counters.items()}
    return MetricsSnapshot(
        ts=time.monotonic(), mode=mode, protocol=protocol,
        failures=failures, restarts=restarts, ops=_frozen(ops),
        transport=transport_metrics_from_wire(wire),
        store=store_metrics_from_backend(store),
        recovery_modes=_frozen(recovery_modes))
