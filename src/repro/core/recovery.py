"""LOG.io rollback recovery (Algorithms 6-9) + replay mode (Algorithms 10-11).

Recovery of an operator OP (state "restarted"):
  1. recover output events: resend everything "undone" + unacknowledged
     (InSet null) in increasing event_id order (Alg 6 step 1 / Alg 7 step 1);
     replay operators regenerate instead of resending (Alg 10).
  2. recover pending write actions (Alg 8) — exactly-once via checkable
     writes.
  3. recover processing: restore global state + LOG.io context from STATE,
     re-process "undone"+acknowledged input events against ONLY their
     assigned Input Set (Alg 9 step 2), trigger generation as it fires.
  4. resume normal processing.

Replay mode (Sec. 5): a *replay operator* (deterministic + lineage on all
ports) does not log output payloads. On failure its outputs are regenerated
from their Input Sets (EVENT_LINEAGE gives output -> InSet). When a consumer
of a replay operator fails, it marks the inputs it needs as "replay"; the
engine restarts the replay predecessors in state "replay" and they
regenerate those outputs (recursively up chains of replay operators).

Transport interaction (repro.core.transport): step 1's resends flow
through ordinary credit-gated ``put``s, so a recovering operator is
back-pressured like any sender (it blocks, abortably, while a receiver's
window is exhausted — deliveries and credit grants keep flowing
underneath).  The transport itself rewinds the per-channel windows on a
warm restart: the routed supervisor re-grants the fresh sender incarnation
``capacity - len(buffer)`` credits and rewinds the receiver's delivery
cursor; the socket transport rebuilds the sender-held buffer from the
resends themselves, so the window resets implicitly and a SIGKILL'd
receiver never strands a sender.
"""
from __future__ import annotations

from typing import Dict, Set, Tuple

from repro.core.events import DONE, REPLAY, UNDONE
from repro.core.operator import OperatorRuntime


def recover_operator(rt: OperatorRuntime, *, is_source: bool = False,
                     source_driver=None,
                     replay_pred_ports: Set[str] = frozenset(),
                     include_done: bool = False):
    """Full recovery sequence for one restarted operator.

    replay_pred_ports: input ports whose senders are replay operators (their
    payloads are not in EVENT_DATA; regenerated events arrive via channels).

    include_done: the operator's group runs (or recently ran) in "epoch"
    recovery mode, so the restored snapshot may be up to ``state_interval``
    generate-transactions stale.  The ack-events scan then includes DONE
    rows: their global-state contributions replay through the
    ``global_updated`` guard (triggers and event state are NOT rebuilt for
    them — their Input Sets completed, regenerating would duplicate
    outputs).  Recovery ends by persisting a fresh snapshot so the next
    restart is re-bounded.
    """
    op = rt.op
    # Alg 9 step 1 / Alg 6 step 2: restore global state + context, advance SSNs
    rt.restore_state()

    # ---- recover output events --------------------------------------------
    if rt.replay_mode:
        _prepare_replay(rt)
    else:
        # one range scan per operator (single sqlite query / sequential
        # segment-image read), never per-event round trips
        rt.stats["recovery_scan_batches"] += 1
        for ev, status in rt.store.fetch_resend_events(op.id):
            rt._send(ev)
            rt.stats["recovered_resends"] += 1
    rt.crash_point(op.id, "recovery_post_resend")

    # ---- write actions (Alg 8) -------------------------------------------
    rt.recover_writes()

    # ---- source: resume its read action (Alg 6 steps 3-4) ----------------
    if is_source and source_driver is not None:
        source_driver.resume(rt)
        op.state = "running"
        return

    # ---- recover processing (Alg 9 step 2 / Alg 11) ----------------------
    replay_out = getattr(op, "_replay_pending", {})
    if rt.replay_mode:
        # rewind SSNs so regenerated events reuse their original ids
        for (port, eid) in replay_out:
            rt.ctx.ssn[port] = min(rt.ctx.ssn.get(port, eid), eid)
    op._awaiting_replay = set()
    op._replay_pred_ports = set(replay_pred_ports)
    mark_txn = rt.store.begin()
    marks = []
    # Alg 9 step 1 analogue for Input Set ids: a batched input transaction
    # durably assigns freshly minted ids without snapshotting state (the
    # counter only rides generate transactions), so after a crash between
    # the two the restored counter can trail ids already bound to logged
    # events.  Ride the ack-events scan below to advance past them — a
    # reissued id would silently merge two unrelated Input Sets and cross
    # their lineage.
    inset_prefix = op.id + ":"
    rt.stats["recovery_scan_batches"] += 1      # one ack-events range scan
    ack_rows = list(rt.store.fetch_ack_events(op.id,
                                              include_done=include_done))
    for _ev, inset_id, _status in ack_rows:
        if inset_id and inset_id.startswith(inset_prefix):
            suffix = inset_id[len(inset_prefix):]
            if suffix.isdigit() and int(suffix) > rt.ctx.inset_counter:
                rt.ctx.inset_counter = int(suffix)
    for ev, inset_id, status in ack_rows:
        rt.stats["recovered_inputs"] += 1
        port = ev.rec_port
        if status == DONE:
            # stale-snapshot catch-up only: the guard skips contributions
            # the snapshot already holds
            if ev.event_id > rt.ctx.global_updated.get(port, -1):
                op.update_global(ev)
                rt.ctx.global_updated[port] = ev.event_id
            continue
        if port in replay_pred_ports and not rt.replay_mode:
            # Alg 11 step 3: payload unavailable — mark "replay" and await
            # the regenerated event from the replay predecessor.
            marks.append(((ev.send_op, ev.send_port, ev.event_id),
                          REPLAY, "*", op.id, None))
            op._awaiting_replay.add((port, ev.event_id, inset_id))
            continue
        if ev.event_id > rt.ctx.global_updated.get(port, -1):
            op.update_global(ev)
            rt.ctx.global_updated[port] = ev.event_id
        # Alg 9 step 2.c: update ONLY the event state for this Input Set
        op.on_event(ev, recovery_inset=inset_id)
        for inset in op.triggers():
            rt.generate(inset, replay_events=replay_out or None)
    if marks:
        # one vectored status flip for the whole awaited-replay set
        mark_txn.set_status_many(marks)
        mark_txn.commit()
    op._replay_pending = {}
    if rt.replay_mode:
        # regeneration rewound the SSN counters to reuse original ids;
        # re-advance past everything logged before resuming (Alg 10 step 3
        # only applies to the replayed range)
        for port, last in rt.store.last_sent_ssn(op.id).items():
            if port in rt.ctx.ssn:
                rt.ctx.ssn[port] = max(rt.ctx.ssn[port], last + 1)
    if include_done:
        # the state just rebuilt is current — persist it so the next
        # restart replays from here instead of re-scanning the DONE backlog
        txn = rt.store.begin()
        txn.put_state(op.id, rt.new_state_id(), rt._state_blob(),
                      keep_history=rt.keep_state_history)
        txn.commit()
        rt._since_state = 0
    rt.crash_point(op.id, "recovery_post_processing")
    op.state = "running"


def _prepare_replay(rt: OperatorRuntime):
    """Algorithm 10: determine Input Sets to replay; mark inputs/outputs."""
    op = rt.op
    store = rt.store
    replay_out: Dict[Tuple[str, int], str] = {}
    insets: Set[str] = set()
    if op.state == "replay":
        # outputs marked REPLAY by consumers + UNDONE ones sent after them
        marked = store.fetch_replay_outputs(op.id)
        min_per_port: Dict[str, int] = {}
        for eid, port, _status in marked:
            min_per_port[port] = min(min_per_port.get(port, eid), eid)
            replay_out[(port, eid)] = None
        for port, mn in min_per_port.items():
            for eid in store.undone_outputs_after(op.id, port, mn):
                replay_out[(port, eid)] = None
    # restarted (or replay): also regenerate own unacked undone outputs
    rt.stats["recovery_scan_batches"] += 1      # one resend range scan
    for ev, status in store.fetch_resend_events(op.id):
        replay_out[(ev.send_port, ev.event_id)] = None
    # map each output to its Input Set via EVENT_LINEAGE (the filtered
    # query op: indexed on backends with pushdown, same full scan otherwise)
    for (port, eid) in list(replay_out):
        ins = store.query_lineage_insets((op.id, port, eid))
        if ins:
            replay_out[(port, eid)] = ins[0]
            insets.add(ins[0])
        else:
            del replay_out[(port, eid)]     # no lineage -> nothing to do
    if not replay_out:
        op._replay_pending = {}
        return
    # Alg 10 step 4: atomically mark inputs of those Input Sets as "replay"
    txn = store.begin()
    for ins in insets:
        txn.set_inset_status(op.id, ins, REPLAY)
    # one vectored flip for the whole replay set (only still-undone
    # receiver rows flip — done consumers keep DONE)
    txn.set_status_many([((op.id, port, eid), REPLAY, "*", None, UNDONE)
                         for (port, eid) in replay_out])
    txn.put_state(op.id, rt.new_state_id(), rt._state_blob(),
                  keep_history=rt.keep_state_history)
    txn.commit()
    op._replay_pending = dict(replay_out)
