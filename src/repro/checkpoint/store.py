"""Checkpointing: checkable, durable write actions on a checkpoint store.

A checkpoint is a *write action* in the LOG.io sense: durable (fsync'd file
with a step id) and checkable (``status`` reads the step id back), so the
recovery protocol guarantees exactly-once commits even if the trainer dies
mid-save. Restart = load latest complete checkpoint + let the LOG.io data
pipeline replay the batches after it (deterministic feed ⇒ bit-identical
resume up to hardware nondeterminism).

Supports elastic re-sharding: checkpoints are stored unsharded (gathered
pytree) and re-split according to the restart mesh.
"""
from __future__ import annotations

import os
import pickle
import tempfile
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


class CheckpointStore:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.lock = threading.Lock()

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.pkl")

    def save(self, state: Any, step: int) -> str:
        """Durable write: temp file + atomic rename (the 'success response'
        of Sec. 2.2 — once renamed, the write is durable)."""
        host_state = jax.tree.map(np.asarray, state)
        path = self._path(step)
        with self.lock:
            fd, tmp = tempfile.mkstemp(dir=self.dir)
            with os.fdopen(fd, "wb") as f:
                pickle.dump({"step": step, "state": host_state}, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        return path

    def status(self, step: int) -> str:
        """Checkable write action (Alg 8 step 2.a)."""
        return "success" if os.path.exists(self._path(step)) else "unknown"

    def latest(self) -> Tuple[Optional[int], Optional[Any]]:
        with self.lock:
            steps = sorted(int(f[5:13]) for f in os.listdir(self.dir)
                           if f.startswith("ckpt_") and f.endswith(".pkl"))
        if not steps:
            return None, None
        with open(self._path(steps[-1]), "rb") as f:
            d = pickle.load(f)
        return d["step"], d["state"]

    def gc(self, keep: int = 2):
        with self.lock:
            steps = sorted(int(f[5:13]) for f in os.listdir(self.dir)
                           if f.startswith("ckpt_") and f.endswith(".pkl"))
            for s in steps[:-keep]:
                os.remove(self._path(s))
