from repro.serving.decode import SlotServer, make_serve_step, serve_step
