"""Serving substrate: one-token serve_step + slot-based batched server."""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import model as M


def serve_step(params, cache, tokens: jax.Array, pos: jax.Array, *,
               cfg, rt: M.Runtime, temperature: float = 0.0,
               rng: jax.Array | None = None):
    """One decode step for a batch of request slots.

    tokens: [B] int32 current token per slot; pos: [B] int32 positions.
    Returns (next_tokens [B], logits [B,V], new_cache).
    """
    logits, new_cache = M.decode_step(params, cache, tokens, pos, cfg, rt)
    if temperature > 0.0 and rng is not None:
        nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
    else:
        nxt = jnp.argmax(logits, axis=-1)
    return nxt.astype(jnp.int32), logits, new_cache


def make_serve_step(cfg, rt: M.Runtime, temperature: float = 0.0):
    return functools.partial(serve_step, cfg=cfg, rt=rt,
                             temperature=temperature)


class SlotServer:
    """Minimal continuous-batching server: fixed B slots, per-slot position,
    requests queue in when slots free up. Used by examples/serve_batched.py
    (CPU, reduced configs) — the dry-run lowers serve_step itself."""

    def __init__(self, params, cfg, rt: M.Runtime, n_slots: int,
                 max_len: int, bos: int = 1):
        self.params, self.cfg, self.rt = params, cfg, rt
        self.n_slots, self.max_len, self.bos = n_slots, max_len, bos
        self.cache = M.init_cache(cfg, n_slots, max_len, jnp.float32,
                                  cross_len=rt.cross_len)
        self.tokens = jnp.full((n_slots,), bos, jnp.int32)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.active = [False] * n_slots
        self.outputs: Dict[int, list] = {}
        self._step = jax.jit(make_serve_step(cfg, rt))
        self._next_req = 0

    def submit(self, prompt_token: int) -> int:
        rid = self._next_req
        self._next_req += 1
        for s in range(self.n_slots):
            if not self.active[s]:
                self.active[s] = True
                self.tokens = self.tokens.at[s].set(prompt_token)
                self.pos = self.pos.at[s].set(0)
                self.outputs[rid] = []
                self._slot_req = getattr(self, "_slot_req", {})
                self._slot_req[s] = rid
                return rid
        raise RuntimeError("no free slot")

    def step(self):
        nxt, _, self.cache = self._step(self.params, self.cache,
                                        self.tokens, self.pos)
        self.pos = self.pos + jnp.asarray([1 if a else 0 for a in self.active],
                                          jnp.int32)
        self.tokens = jnp.where(jnp.asarray(self.active), nxt, self.tokens)
        for s in range(self.n_slots):
            if self.active[s]:
                rid = self._slot_req[s]
                self.outputs[rid].append(int(nxt[s]))

    def finish(self, rid: int):
        for s, r in getattr(self, "_slot_req", {}).items():
            if r == rid:
                self.active[s] = False
        return self.outputs.pop(rid)
