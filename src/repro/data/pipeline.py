"""Training data pipeline as a LOG.io-protected operator dataflow.

Topology (parallelisable with Dispatcher/Merger replicas):

    corpus source -> tokenize/pack -> batcher -> TrainFeedSink
        (replayable read)  (map)      (window)     (train loop)

The TrainFeedSink hands batches to the training loop and acknowledges them
through LOG.io: a batch event's Input Set is marked done only when the train
step consuming it has committed its *checkpoint write action* (checkable on
the checkpoint store), so a crash anywhere in pipeline-or-trainer replays
exactly the unconsumed batches — the paper's exactly-once guarantee applied
to training, with EVENT_LINEAGE linking every checkpoint to the exact source
shards it was trained on.
"""
from __future__ import annotations

import queue
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.builtin import CountWindowOperator, MapOperator
from repro.core.events import Event
from repro.core.operator import Operator, ReadSource


class SyntheticCorpus(ReadSource):
    """Deterministic seeded corpus: shard i is a block of token ids.
    Replayable by construction (same seed => same shards)."""

    def __init__(self, n_shards: int, shard_tokens: int, vocab: int,
                 seed: int = 0):
        self.n_shards, self.shard_tokens = n_shards, shard_tokens
        self.vocab, self.seed = vocab, seed
        super().__init__([], replayable=True)

    def effect(self, desc: str, from_offset: int = 0) -> List[Any]:
        out = []
        for i in range(from_offset, self.n_shards):
            rng = np.random.default_rng(self.seed * 100_003 + i)
            out.append({"shard": i,
                        "tokens": rng.integers(0, self.vocab,
                                               self.shard_tokens,
                                               dtype=np.int32)})
        return out


def pack_fn(seq_len: int) -> Callable[[dict], dict]:
    """Tokenize/pack stub: chops a shard into seq_len+1 sequences."""
    def fn(body):
        toks = body["tokens"]
        n = len(toks) // (seq_len + 1)
        seqs = toks[: n * (seq_len + 1)].reshape(n, seq_len + 1)
        return {"shard": body["shard"], "seqs": seqs}
    return fn


class BatchOperator(CountWindowOperator):
    """Accumulates ``per_batch`` packed shards into one training batch
    (tokens [B, S+1]); the Input Set is the shard window (lineage unit)."""

    def __init__(self, op_id: str, per_batch: int, batch_size: int,
                 **kw):
        def agg(bodies):
            seqs = np.concatenate([b["seqs"] for b in bodies], axis=0)
            return {"tokens": seqs[:batch_size],
                    "shards": sorted(b["shard"] for b in bodies)}
        super().__init__(op_id, window=per_batch, agg=agg, **kw)


class TrainFeedSink(Operator):
    """Hands batches to the train loop; marks a batch's Input Set done only
    when the training step's checkpoint write action commits."""
    output_ports: Tuple[str, ...] = ()

    def __init__(self, op_id: str, *, max_buffer: int = 4):
        super().__init__(op_id)
        self.buffer: "queue.Queue" = queue.Queue(maxsize=max_buffer)
        self._pending: Dict[str, Any] = {}
        self.seen = 0

    def update_global(self, event: Event):
        self.seen += 1

    def global_state(self):
        return {"seen": self.seen}

    def restore_global(self, blob):
        if blob:
            self.seen = blob["seen"]

    def on_event(self, event: Event, *, recovery_inset=None) -> List[str]:
        inset = recovery_inset or self.runtime.new_inset_id()
        self._pending[inset] = event.body
        return [inset]

    def triggers(self) -> List[str]:
        self.requeue()
        return []    # generation is driven by complete()

    def requeue(self):
        """Move pending (acknowledged, not yet consumed) batches into the
        hand-off queue as capacity frees up. Called by the engine thread
        (via triggers) and by the train driver between steps."""
        for inset, body in list(self._pending.items()):
            if body is not None:
                try:
                    self.buffer.put_nowait((inset, body))
                    self._pending[inset] = None      # queued
                except queue.Full:
                    break

    def has_pending(self) -> bool:
        return bool(self._pending)

    def complete(self, inset: str, step: int, ckpt_ref: Optional[str]):
        """Called by the train driver after the step (and any checkpoint
        write) committed: the generation for this Input Set emits the
        checkpoint write action and marks the batch events done."""
        self._finish_args = (step, ckpt_ref)
        self.runtime.generate(inset)
        self.requeue()

    def generate(self, inset_id: str):
        step, ckpt_ref = getattr(self, "_finish_args", (None, None))
        writes = []
        if ckpt_ref is not None:
            writes.append(("ckpt", {"step": step, "ref": ckpt_ref}))
        return [], writes

    def clear_inset(self, inset_id: str):
        self._pending.pop(inset_id, None)


def build_data_pipeline(*, seq_len: int, batch_size: int, vocab: int,
                        n_shards: int = 64, shard_tokens: int = 4096,
                        per_batch: int = 2, seed: int = 0):
    """Returns (Pipeline, sink_id) for the standard training feed."""
    from repro.core.engine import Pipeline
    from repro.core.builtin import GeneratorSource

    corpus = SyntheticCorpus(n_shards, shard_tokens, vocab, seed)
    p = Pipeline()
    p.add(lambda: GeneratorSource("corpus", corpus, desc="corpus-read"))
    p.add(lambda: MapOperator("pack", fn=pack_fn(seq_len)))
    p.add(lambda: BatchOperator("batch", per_batch, batch_size))
    p.add(lambda: TrainFeedSink("feed"))
    p.connect("corpus", "out", "pack", "in")
    p.connect("pack", "out", "batch", "in")
    p.connect("batch", "out", "feed", "in")
    return p, "feed"
