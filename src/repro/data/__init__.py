from repro.data.pipeline import (BatchOperator, SyntheticCorpus,
                                 TrainFeedSink, build_data_pipeline, pack_fn)
