"""repro — LOG.io (unified rollback recovery + data lineage) on a multi-pod
JAX training/serving framework. See README.md / DESIGN.md."""
__version__ = "1.0.0"
