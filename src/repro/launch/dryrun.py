import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import: jax locks the device
count on first init, and the production meshes need 512 placeholder host
devices (single-pod 16x16 uses the first 256).

Per cell this prints/records:
  * compiled.memory_analysis()  — proves the cell fits 16 GB/chip HBM,
  * compiled.cost_analysis()    — XLA's per-shard FLOPs/bytes (reference),
  * loop-aware HLO analysis     — dot FLOPs / HBM bytes / collective bytes
                                  per chip (repro.parallel.hlo_analysis),
  * the three roofline terms against TPU v5e constants.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all --out results/dryrun   # full sweep
"""
import argparse
import dataclasses
import functools
import json
import subprocess
import sys
import time
import traceback

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link


def _cell_result(arch_name: str, shape_name: str, multi_pod: bool,
                 overrides: dict):
    import jax

    from repro.configs import ALL_SHAPES, get_config
    from repro.launch import input_specs as ispec
    from repro.launch.mesh import make_production_mesh
    from repro.launch.presets import preset_for
    from repro.models import model as M
    from repro.parallel import hlo_analysis
    from repro.parallel import sharding as S
    from repro.serving.decode import serve_step
    from repro.training.optimizer import OptHParams
    from repro.training.step import train_step

    cfg = get_config(arch_name)
    shape = ALL_SHAPES[shape_name]
    preset = preset_for(arch_name)
    for k, v in (overrides or {}).items():
        if v is not None and hasattr(preset, k):
            preset = dataclasses.replace(preset, **{k: v})
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    esplit = overrides.get("expert_split") or preset.expert_split
    if esplit and esplit > 1 and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, expert_split=esplit))
    if overrides.get("dp_only") or (preset.dp_only_train
                                    and shape.kind == "train"):
        # small models: no TP — params FSDP-sharded over ALL chips, batch
        # data-parallel over the largest mesh-axis suffix dividing the
        # global batch (multi-pod: 512 chips > 256 sequences, so the batch
        # shards over (data, model)=256 while FSDP spans all 512)
        flat = tuple(mesh.axis_names)
        sizes_ = dict(zip(mesh.axis_names, mesh.devices.shape))
        bt = flat
        while bt:
            n = 1
            for a in bt:
                n *= sizes_[a]
            if shape.global_batch % n == 0:
                break
            bt = bt[1:]
        strat = S.ShardingStrategy(fsdp=True, tp=False, ep=False,
                                   seq_shard_decode=False,
                                   fsdp_axes=flat, dp_axes=bt or ("data",))
    else:
        strat = S.ShardingStrategy.for_mesh(
            mesh, fsdp=preset.fsdp, ep=preset.ep,
            fsdp_over_pod=overrides.get("fsdp_over_pod", False))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if strat.tp:
        cfg = cfg.padded_for_tp(sizes[strat.tp_axis])
    ep_active = (cfg.moe is not None and strat.ep and strat.tp
                 and (cfg.moe.n_experts * cfg.moe.expert_split)
                 % sizes[strat.tp_axis] == 0)
    dp_axes = strat.dp_axes
    if shape.kind in ("prefill", "decode"):
        from repro.launch.input_specs import dp_total
        if shape.global_batch % dp_total(mesh, strat) != 0:
            dp_axes = ()     # long_500k B=1: batch unshardable
    rt = M.Runtime(remat=preset.remat, q_chunk=preset.q_chunk,
                   shard_activations=True, dp_axes=dp_axes, ep=ep_active,
                   tp_axis=(strat.tp_axis if strat.tp else ""))
    hp = OptHParams(moment_dtype=preset.moment_dtype,
                    grad_accum_dtype=preset.grad_accum_dtype)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            st_shapes, b_shapes, st_sh, b_sh = ispec.train_specs(
                cfg, shape, mesh, strat, preset, hp)
            fn = functools.partial(train_step, cfg=cfg, hp=hp, rt=rt,
                                   compress_grads=overrides.get(
                                       "compress_grads", False))
            # explicit out_shardings: GSPMD output propagation breaks through
            # the int8 quant reshape path (measured: replicated outputs =>
            # 7.6TB/chip temp on arctic); donation = in-place state update.
            lowered = jax.jit(fn, in_shardings=(st_sh, b_sh),
                              out_shardings=(st_sh, None),
                              donate_argnums=(0,)).lower(
                st_shapes, b_shapes)
            tokens = shape.global_batch * shape.seq_len
            model_flops = 6.0 * cfg.active_param_count() * tokens
        elif shape.kind == "prefill":
            p_shapes, b_shapes, p_sh, b_sh = ispec.prefill_specs(
                cfg, shape, mesh, strat)

            def prefill(params, batch):
                logits, _ = M.forward(params, batch, cfg, rt)
                return logits

            lowered = jax.jit(prefill, in_shardings=(p_sh, b_sh)).lower(
                p_shapes, b_shapes)
            tokens = shape.global_batch * shape.seq_len
            model_flops = 2.0 * cfg.active_param_count() * tokens
        else:   # decode
            (p_shapes, c_shapes, t_shapes,
             p_sh, c_sh, t_sh) = ispec.decode_specs(cfg, shape, mesh, strat)
            fn = functools.partial(serve_step, cfg=cfg, rt=rt)
            lowered = jax.jit(fn, in_shardings=(
                p_sh, c_sh, t_sh["tokens"], t_sh["pos"]),
                out_shardings=(None, None, c_sh),
                donate_argnums=(1,)).lower(
                p_shapes, c_shapes, t_shapes["tokens"], t_shapes["pos"])
            tokens = shape.global_batch     # one new token per slot
            model_flops = 2.0 * cfg.active_param_count() * tokens
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    if overrides.get("dump_hlo"):
        with open(overrides["dump_hlo"], "w") as f:
            f.write(hlo_text)
    hlo = hlo_analysis.analyze(hlo_text)

    # roofline terms (per chip; hlo numbers are already per-device SPMD)
    compute_s = hlo["dot_flops"] / PEAK_FLOPS
    memory_s = hlo["memory_bytes"] / HBM_BW
    collective_s = hlo["collective_bytes"] / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    total_flops = hlo["dot_flops"] * n_chips
    result = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_hbm_bytes": mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes
                              - mem.alias_size_in_bytes,
            "fits_16GB": (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                          - mem.alias_size_in_bytes) < 16e9,
        },
        "cost_analysis": {k: cost.get(k) for k in ("flops", "bytes accessed")},
        "hlo": {k: hlo[k] for k in ("dot_flops", "memory_bytes",
                                    "collective_bytes", "collective_count",
                                    "collectives", "n_whiles", "trips")},
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / total_flops
                               if total_flops else None),
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
            "step_time_s_lower_bound": max(compute_s, memory_s, collective_s),
            "mfu_upper_bound": (model_flops / n_chips / PEAK_FLOPS
                                / max(compute_s, memory_s, collective_s)
                                if max(compute_s, memory_s,
                                       collective_s) > 0 else None),
        },
        "preset": dataclasses.asdict(preset),
        "overrides": {k: v for k, v in (overrides or {}).items()
                      if v not in (None, False)},
    }
    return result


def run_cell(arch, shape, multi_pod, out_path=None, **overrides):
    try:
        res = _cell_result(arch, shape, multi_pod, overrides)
    except Exception as e:   # a failing cell is a bug — record it loudly
        res = {"arch": arch, "shape": shape,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    if out_path:
        with open(out_path, "w") as f:
            json.dump(res, f, indent=1)
    return res


def all_cells():
    from repro.configs import ARCHS, shapes_for
    for name, cfg in ARCHS.items():
        for shp in shapes_for(cfg):
            for multi in (False, True):
                yield name, shp.name, multi


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=1800)
    # hillclimb overrides
    ap.add_argument("--remat", choices=["none", "block", "full"])
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false", default=None)
    ap.add_argument("--no-ep", dest="ep", action="store_false", default=None)
    ap.add_argument("--microbatch", type=int)
    ap.add_argument("--moment-dtype", dest="moment_dtype",
                    choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--grad-accum-dtype", dest="grad_accum_dtype",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--compress-grads", action="store_true", default=False)
    ap.add_argument("--fsdp-over-pod", action="store_true", default=False)
    ap.add_argument("--dump-hlo", dest="dump_hlo", default=None)
    ap.add_argument("--dp-only", dest="dp_only", action="store_true",
                    default=False)
    ap.add_argument("--expert-split", dest="expert_split", type=int,
                    default=None)
    args = ap.parse_args()
    overrides = {k: getattr(args, k) for k in
                 ("remat", "fsdp", "ep", "microbatch", "moment_dtype",
                  "grad_accum_dtype", "compress_grads", "fsdp_over_pod",
                  "dump_hlo", "dp_only", "expert_split")}

    if args.all:
        outdir = args.out or "results/dryrun"
        os.makedirs(outdir, exist_ok=True)
        for arch, shp, multi in all_cells():
            tag = f"{arch}__{shp}__{'2x16x16' if multi else '16x16'}"
            path = os.path.join(outdir, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"SKIP {tag} (exists)")
                continue
            # subprocess per cell: isolates compile memory + device state
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shp, "--out", path]
            if multi:
                cmd.append("--multi-pod")
            print(f"RUN  {tag}", flush=True)
            try:
                subprocess.run(cmd, timeout=args.timeout, check=False)
            except subprocess.TimeoutExpired:
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shp,
                               "mesh": "2x16x16" if multi else "16x16",
                               "status": "timeout"}, f)
        return

    res = run_cell(args.arch, args.shape, args.multi_pod, args.out, **overrides)
    if res["status"] == "ok":
        m, r = res["memory_analysis"], res["roofline"]
        print(f"== {res['arch']} x {res['shape']} @ {res['mesh']} ==")
        print(f"memory_analysis: args={m['argument_bytes']/1e9:.2f}GB "
              f"temp={m['temp_bytes']/1e9:.2f}GB peak={m['peak_hbm_bytes']/1e9:.2f}GB "
              f"fits_16GB={m['fits_16GB']}")
        print(f"cost_analysis:   {res['cost_analysis']}")
        print(f"hlo(loop-aware): flops/chip={res['hlo']['dot_flops']:.3e} "
              f"bytes/chip={res['hlo']['memory_bytes']:.3e} "
              f"coll_bytes/chip={res['hlo']['collective_bytes']:.3e}")
        print(f"roofline: compute={r['compute_s']*1e3:.2f}ms "
              f"memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms "
              f"dominant={r['dominant']} "
              f"MFU_ub={r['mfu_upper_bound'] and round(r['mfu_upper_bound'],3)}")
        print(f"useful_flops_ratio(6ND/HLO)="
              f"{res['useful_flops_ratio'] and round(res['useful_flops_ratio'],3)}")
    else:
        print(f"FAILED {res['arch']} x {res['shape']}: {res.get('error')}")
        print(res.get("traceback", "")[-2000:])
        sys.exit(1)


if __name__ == "__main__":
    main()
