"""Per-architecture dry-run presets: dtypes, accumulation, strategy knobs.

These are the BASELINE choices recorded in EXPERIMENTS.md §Roofline; §Perf
hillclimbs override them via dryrun.py flags.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Preset:
    moment_dtype: str = "float32"
    grad_accum_dtype: str = "float32"
    remat: str = "block"
    fsdp: bool = True
    ep: bool = True
    # microbatch sequences per accumulation step; None => one seq per DP shard
    microbatch: Optional[int] = None
    q_chunk: int = 1024
    # §Perf winners: pure-DP+FSDP training for small models (removes the
    # per-token TP activation all-reduces; train shapes only) and
    # expert-splitting so grok's 8 experts EP-shard the 16-way axis.
    dp_only_train: bool = False
    expert_split: int = 1


# >=300B configs: bf16 moments + bf16 accumulation to fit 256 x 16GB HBM.
_BIG = Preset(moment_dtype="bfloat16", grad_accum_dtype="bfloat16",
              remat="full")

PRESETS = {
    # >=30B dense: full remat (checkpoint-dots pushed chameleon/qwen3 train
    # past 16GB/chip at baseline)
    "chameleon-34b": Preset(remat="full"),
    "starcoder2-7b": Preset(dp_only_train=True, remat="full"),
    "internlm2-1.8b": Preset(dp_only_train=True, remat="full"),
    "qwen3-32b": Preset(remat="full"),
    "gemma2-9b": Preset(),
    "jamba-1.5-large-398b": _BIG,
    "seamless-m4t-large-v2": Preset(dp_only_train=True, remat="full"),
    # grok: 8 experts split 2-way => 16-way EP (2.2x collective win, §Perf)
    "grok-1-314b": dataclasses.replace(_BIG, expert_split=2),
    # 480B: blockwise-int8 AdamW moments (bf16 moments left 25.8GB/chip)
    "arctic-480b": dataclasses.replace(_BIG, moment_dtype="int8"),
    "falcon-mamba-7b": Preset(),
}


def preset_for(arch_name: str) -> Preset:
    return PRESETS.get(arch_name, Preset())
