"""ShapeDtypeStruct stand-ins + shardings for every model input.

No device allocation happens here: train state, batches, and decode caches
are all ``jax.eval_shape`` / ``ShapeDtypeStruct`` trees, matched with
``NamedSharding`` trees for ``jit(..., in_shardings=...)``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ArchConfig, ShapeSpec
from repro.launch.presets import Preset
from repro.models import model as M
from repro.parallel import sharding as S
from repro.training.optimizer import OptHParams
from repro.training.step import init_train_state


def dp_total(mesh: Mesh, strat: S.ShardingStrategy) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in strat.dp_axes:
        n *= sizes[a]
    return n


def train_batch_layout(shape: ShapeSpec, mesh: Mesh,
                       strat: S.ShardingStrategy, preset: Preset
                       ) -> Tuple[int, int]:
    """(accum, microbatch) with accum*microbatch == global_batch."""
    dp = dp_total(mesh, strat)
    mb = preset.microbatch or dp
    mb = min(mb, shape.global_batch)
    while shape.global_batch % mb != 0:
        mb -= 1
    return shape.global_batch // mb, mb


def train_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                strat: S.ShardingStrategy, preset: Preset,
                hp: OptHParams):
    """Returns (state_shapes, batch_shapes, state_shardings, batch_shardings)."""
    rules = S.make_rules(cfg, mesh, strat)
    accum, mb = train_batch_layout(shape, mesh, strat, preset)
    Ssq = shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((accum, mb, Ssq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((accum, mb, Ssq), jnp.int32),
    }
    bspec = {
        "tokens": P(None, strat.dp_axes, None),
        "labels": P(None, strat.dp_axes, None),
    }
    if cfg.enc_dec:
        batch["frames"] = jax.ShapeDtypeStruct((accum, mb, Ssq, cfg.d_model),
                                               jnp.bfloat16)
        bspec["frames"] = P(None, strat.dp_axes, None, None)
    state_shapes = jax.eval_shape(
        functools.partial(init_train_state, cfg=cfg, hp=hp),
        jax.random.PRNGKey(0))
    sspec = S.state_pspecs(cfg, rules, hp.moment_dtype)
    return (state_shapes, batch,
            S.named(mesh, sspec), S.named(mesh, bspec))


def prefill_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                  strat: S.ShardingStrategy):
    rules = S.make_rules(cfg, mesh, strat)
    B, Ssq = shape.global_batch, shape.seq_len
    shardable = B % dp_total(mesh, strat) == 0
    dp = strat.dp_axes if shardable else None
    batch = {"tokens": jax.ShapeDtypeStruct((B, Ssq), jnp.int32)}
    bspec = {"tokens": P(dp, None)}
    if cfg.enc_dec:
        batch["frames"] = jax.ShapeDtypeStruct((B, Ssq, cfg.d_model),
                                               jnp.bfloat16)
        bspec["frames"] = P(dp, None, None)
    pshapes = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    pspec = S.param_pspecs(cfg, rules)
    return pshapes, batch, S.named(mesh, pspec), S.named(mesh, bspec)


def decode_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                 strat: S.ShardingStrategy, cross_len: int = 4096):
    rules = S.make_rules(cfg, mesh, strat)
    B, Ssq = shape.global_batch, shape.seq_len
    shardable = B % dp_total(mesh, strat) == 0
    dp = strat.dp_axes if shardable else None
    cache_shapes = jax.eval_shape(
        lambda: M.init_cache(cfg, B, Ssq, jnp.bfloat16, cross_len=cross_len))
    cspec = S.cache_pspecs(cfg, rules, shardable)
    toks = {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((B,), jnp.int32)}
    tspec = {"tokens": P(dp), "pos": P(dp)}
    pshapes = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    pspec = S.param_pspecs(cfg, rules)
    return (pshapes, cache_shapes, toks,
            S.named(mesh, pspec), S.named(mesh, cspec), S.named(mesh, tspec))
