"""Serving driver: batched decode over any assigned architecture.

CPU demo (reduced config):
    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b \
        --requests 8 --tokens 16
On TPU the same ``serve_step`` is what the decode_32k / long_500k dry-run
cells lower for the production mesh (params TP/FSDP-sharded, KV caches
sequence-sharded — see launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.serving import SlotServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    full = get_config(args.arch)
    cfg = reduced(full, d_model=args.d_model,
                  n_layers=2 * len(full.block) if len(full.block) == 1
                  else len(full.block))
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rt = M.Runtime(q_chunk=16, cross_len=16)
    server = SlotServer(params, cfg, rt, n_slots=args.slots,
                        max_len=args.max_len)

    t0 = time.time()
    pending = list(range(args.requests))
    active, done = {}, {}
    while pending or active:
        while pending and len(active) < server.n_slots:
            req = pending.pop(0)
            active[server.submit(prompt_token=req + 2)] = req
        server.step()
        for rid in list(active):
            if len(server.outputs.get(rid, [])) >= args.tokens:
                done[active.pop(rid)] = server.finish(rid)
    dt = time.time() - t0
    total = args.requests * args.tokens
    print(f"served {args.requests} requests x {args.tokens} tokens "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s, {args.slots} slots, "
          f"arch={args.arch} reduced)")


if __name__ == "__main__":
    main()
