"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). The dry-run process forces 512 host devices via XLA_FLAGS
(set as the first lines of dryrun.py only); the single-pod mesh then uses the
first 256 of them.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devs)} present — "
            "run via launch/dryrun.py which forces 512 host devices")
    try:
        return jax.make_mesh(shape, axes, devices=devs[:n])
    except TypeError:   # older jax without devices kwarg
        from jax.sharding import Mesh
        return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_local_mesh(axes=("data", "model")):
    """1x1 (or 1xN) mesh over whatever devices exist — smoke tests/examples."""
    import jax
    devs = jax.devices()
    from jax.sharding import Mesh
    shape = (1, len(devs)) if len(axes) == 2 else (len(devs),)
    return Mesh(np.asarray(devs).reshape(shape), axes)
