"""End-to-end training driver: LOG.io-protected data pipeline + SPMD train
step + checkable checkpoint write actions.

CPU (this container): reduced configs, local 1-device mesh —
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --steps 60 --kill-worker-at 15 --kill-trainer-at 30
TPU: pass --full; the same driver shards via the production mesh rules.

Exactly-once training semantics: consumed batches are acknowledged (their
Input Sets marked done, with the checkpoint as the covering *write action*)
only at checkpoint boundaries, so after ANY crash the pipeline re-delivers
exactly the batches after the last checkpoint, in order — the restarted
trainer replays the identical trajectory (asserted by tests).
  * --kill-worker-at N  : crash a pipeline worker group after ~N batches;
    LOG.io recovers it non-blocking while training keeps running.
  * --kill-trainer-at N : drop the train state at step N, restore from the
    latest checkpoint, and crash the feed group (simulating the trainer pod
    dying with its buffered batches).
"""
from __future__ import annotations

import argparse
import queue as _queue
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointStore
from repro.configs import get_config, reduced
from repro.core.engine import Engine, FailureInjector
from repro.data import build_data_pipeline
from repro.models import model as M
from repro.training.optimizer import OptHParams
from repro.training.step import init_train_state, make_train_step


def run_training(*, arch: str = "internlm2-1.8b", use_reduced: bool = True,
                 steps: int = 60, seq_len: int = 128, batch_size: int = 4,
                 ckpt_every: int = 10, ckpt_dir: str = "/tmp/repro_ckpt",
                 kill_worker_at: Optional[int] = None,
                 kill_trainer_at: Optional[int] = None,
                 lr: float = 1e-3, seed: int = 0, log_every: int = 10,
                 d_model: int = 256, n_layers: int = 4, verbose: bool = True):
    cfg = get_config(arch)
    if use_reduced:
        nl = n_layers - n_layers % len(cfg.block) or len(cfg.block)
        cfg = reduced(cfg, d_model=d_model, n_layers=nl, vocab=2048,
                      d_ff=4 * d_model, n_heads=4)
    hp = OptHParams(lr=lr, warmup=20)
    rt = M.Runtime(remat="none", q_chunk=min(seq_len, 128),
                   shard_activations=False)

    # ---- data pipeline (LOG.io-protected) --------------------------------
    pipeline, feed_id = build_data_pipeline(
        seq_len=seq_len, batch_size=batch_size, vocab=cfg.vocab,
        n_shards=2 * steps + 32,
        shard_tokens=(batch_size // 2) * (seq_len + 1),
        per_batch=2, seed=seed)
    plan = []
    if kill_worker_at is not None:
        plan.append(("pack", "post_log", 2 * kill_worker_at))
    engine = Engine(pipeline, injector=FailureInjector(plan),
                    mode="thread", restart_delay=0.01)
    store = CheckpointStore(ckpt_dir)

    # ---- train state (restore-or-init) -----------------------------------
    def fresh_state():
        return init_train_state(jax.random.PRNGKey(seed), cfg, hp,
                                dtype=jnp.float32)

    _, restored = store.latest()
    state = (jax.tree.map(jnp.asarray, restored) if restored is not None
             else fresh_state())
    train_step = jax.jit(make_train_step(cfg, hp, rt))

    def next_batch(deadline=30.0):
        t_end = time.time() + deadline
        while time.time() < t_end:
            feed = engine.ops[feed_id]
            feed.requeue()
            try:
                return feed, feed.buffer.get(timeout=0.2)
            except _queue.Empty:
                continue
        raise TimeoutError("no batch from the data pipeline")

    engine.start()
    losses, crash_steps = [], []
    pending_insets = []
    killed_trainer = False
    t0 = time.time()
    while int(state["step"]) < steps:
        feed, (inset, body) = next_batch()
        toks = jnp.asarray(body["tokens"][:batch_size])
        batch = {"tokens": toks[None, :, :-1],
                 "labels": toks[None, :, 1:].astype(jnp.int32)}
        state, metrics = train_step(state, batch)
        step = int(state["step"])
        losses.append(float(metrics["loss"]))
        pending_insets.append(inset)

        if step % ckpt_every == 0 or step >= steps:
            ref = store.save(state, step)
            feed_now = engine.ops[feed_id]
            for ins in pending_insets:
                feed_now.complete(ins, step, ref)
            pending_insets = []

        if verbose and (step % log_every == 0 or step >= steps):
            print(f"step {step:4d} loss {metrics['loss']:.4f} "
                  f"gnorm {metrics['grad_norm']:.3f} "
                  f"({time.time()-t0:.1f}s)", flush=True)

        if (kill_trainer_at is not None and step >= kill_trainer_at
                and not killed_trainer):
            killed_trainer = True
            crash_steps.append(step)
            if verbose:
                print(f"!! trainer crash at step {step}: dropping state, "
                      f"restoring from checkpoint", flush=True)
            old_feed = engine.ops[feed_id]
            engine.kill_group(engine.pipeline.groups[feed_id])
            _, restored = store.latest()
            state = (jax.tree.map(jnp.asarray, restored)
                     if restored is not None else fresh_state())
            pending_insets = []
            # wait for the feed group to be rebuilt (fresh buffer)
            t_end = time.time() + 10
            while engine.ops[feed_id] is old_feed and time.time() < t_end:
                time.sleep(0.01)

    engine.stop()
    return {"losses": losses, "crash_steps": crash_steps, "engine": engine,
            "final_state": state, "store": store,
            "steps": int(state["step"])}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--full", dest="reduced", action="store_false",
                    default=True)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--kill-worker-at", type=int, default=None)
    ap.add_argument("--kill-trainer-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = run_training(arch=args.arch, use_reduced=args.reduced,
                       steps=args.steps, seq_len=args.seq_len,
                       batch_size=args.batch_size, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir,
                       kill_worker_at=args.kill_worker_at,
                       kill_trainer_at=args.kill_trainer_at,
                       d_model=args.d_model, n_layers=args.n_layers,
                       seed=args.seed)
    print(f"finished at step {out['steps']}; "
          f"pipeline failures={out['engine'].failures} "
          f"restarts={out['engine'].restarts}")


if __name__ == "__main__":
    main()
