"""qwen3-32b [dense] — qk_norm, GQA(kv=8) [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ArchConfig, AttnSpec, LayerSpec

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=25600, vocab=151936,
    block=(LayerSpec(mixer="attn", ffn="dense", attn=AttnSpec(qk_norm=True)),),
    rope_theta=1_000_000.0,
    source="[hf:Qwen/Qwen3-8B; hf]",
)
