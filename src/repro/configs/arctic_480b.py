"""arctic-480b [moe] — 128 experts top-2 + parallel dense residual FFN
[hf:Snowflake/snowflake-arctic-base; hf]. 128 experts shard 8-per-chip over
the 16-way model axis (EP). Optimizer moments default to bf16 for this config
(fits 256 x 16GB; see EXPERIMENTS.md §Dry-run)."""
from repro.configs.base import ArchConfig, AttnSpec, LayerSpec, MoESpec

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=4864, vocab=32000,
    block=(LayerSpec(mixer="attn", ffn="moe_dense", attn=AttnSpec()),),
    moe=MoESpec(n_experts=128, top_k=2),
    source="[hf:Snowflake/snowflake-arctic-base; hf]",
)
