"""starcoder2-7b [dense] — GQA(kv=4), RoPE [arXiv:2402.19173; hf]."""
from repro.configs.base import ArchConfig, AttnSpec, LayerSpec

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_head=128,
    d_ff=18432, vocab=49152,
    block=(LayerSpec(mixer="attn", ffn="dense", attn=AttnSpec()),),
    source="[arXiv:2402.19173; hf]",
)
