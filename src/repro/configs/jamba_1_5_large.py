"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2 on
every other layer [arXiv:2403.19887; hf]. The repeating scan block is the
8-layer Jamba block: attention at in-block index 3 (1:7 ratio), MoE on odd
in-block indices. Sub-quadratic (Mamba + a single GQA layer per 8) => runs
long_500k."""
from repro.configs.base import (ArchConfig, AttnSpec, LayerSpec, MambaSpec,
                                MoESpec)


def _layer(i: int) -> LayerSpec:
    mixer = "attn" if i == 3 else "mamba"
    ffn = "moe" if i % 2 == 1 else "dense"
    return LayerSpec(mixer=mixer, ffn=ffn, attn=AttnSpec())


CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=24576, vocab=65536,
    block=tuple(_layer(i) for i in range(8)),
    moe=MoESpec(n_experts=16, top_k=2),
    mamba=MambaSpec(d_state=16, d_conv=4, expand=2),
    subquadratic=True,
    source="[arXiv:2403.19887; hf]",
)
