"""internlm2-1.8b [dense] — GQA(kv=8) [arXiv:2403.17297; hf]. Also the family
used (at reduced width) by the ~100M end-to-end training example."""
from repro.configs.base import ArchConfig, AttnSpec, LayerSpec

CONFIG = ArchConfig(
    name="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=92544,
    block=(LayerSpec(mixer="attn", ffn="dense", attn=AttnSpec()),),
    source="[arXiv:2403.17297; hf]",
)
