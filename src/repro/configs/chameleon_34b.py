"""chameleon-34b [vlm] — early-fusion LM over interleaved text + VQ image
tokens [arXiv:2405.09818; unverified]. The modality frontend is a stub: the VQ
tokenizer output is precomputed ids inside the shared 65536 vocab, so the
backbone is a plain GQA decoder."""
from repro.configs.base import ArchConfig, AttnSpec, LayerSpec

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22016, vocab=65536,
    block=(LayerSpec(mixer="attn", ffn="dense", attn=AttnSpec()),),
    source="[arXiv:2405.09818; unverified]",
)
