"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal
[arXiv:2308.11596; hf]. The speech/text frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings [B, S_src, d_model] for the encoder; the
24-layer decoder (self+cross attention) is the assigned backbone."""
from repro.configs.base import ArchConfig, AttnSpec, LayerSpec

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=8192, vocab=256206,
    block=(LayerSpec(mixer="attn", ffn="dense", attn=AttnSpec()),),
    enc_dec=True, n_enc_layers=24,
    source="[arXiv:2308.11596; hf]",
)
