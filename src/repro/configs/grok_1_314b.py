"""grok-1-314b [moe] — 8 experts top-2, GQA(kv=8) [hf:xai-org/grok-1;
unverified]. With only 8 experts on a 16-way model axis, the sharding rules
use tensor-parallel-within-expert (shard expert d_ff) instead of EP — see
parallel/sharding.py."""
from repro.configs.base import ArchConfig, AttnSpec, LayerSpec, MoESpec

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=32768, vocab=131072,
    block=(LayerSpec(mixer="attn", ffn="moe", attn=AttnSpec()),),
    moe=MoESpec(n_experts=8, top_k=2),
    source="[hf:xai-org/grok-1; unverified]",
)
