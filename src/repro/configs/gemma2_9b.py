"""gemma2-9b [dense] — alternating local(4096)/global attention, logit
softcaps (attn 50, final 30) [arXiv:2408.00118; hf]. The repeating scan block
is the (local, global) pair. Global layers are quadratic => long_500k skipped
(see DESIGN.md §Arch-applicability)."""
from repro.configs.base import ArchConfig, AttnSpec, LayerSpec

CONFIG = ArchConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_head=256,
    d_ff=14336, vocab=256000,
    block=(
        LayerSpec(mixer="attn", ffn="dense",
                  attn=AttnSpec(window=4096, softcap=50.0)),
        LayerSpec(mixer="attn", ffn="dense",
                  attn=AttnSpec(window=None, softcap=50.0)),
    ),
    final_softcap=30.0,
    tie_embeddings=True,
    act="gelu",
    source="[arXiv:2408.00118; hf]",
)
