"""Architecture configuration dataclasses.

Every assigned architecture is expressed as an ``ArchConfig``: a repeating
*block* of per-layer specs (mixer kind + ffn kind + attention flavour) that the
model stacks ``n_blocks`` times with ``jax.lax.scan`` (homogeneous blocks keep
the HLO small, which keeps 256/512-way GSPMD compiles fast).

Shape sets (``train_4k`` etc.) are defined in ``shapes.py``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Attention flavour for one layer position within a block."""
    window: Optional[int] = None      # sliding-window size; None = global
    softcap: Optional[float] = None   # tanh logit soft-capping (gemma2)
    qk_norm: bool = False             # RMSNorm on q/k heads (qwen3)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer position inside the repeating block."""
    mixer: str = "attn"               # "attn" | "mamba"
    ffn: str = "dense"                # "dense" | "moe" | "moe_dense" (parallel dense residual) | "none"
    attn: AttnSpec = AttnSpec()


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    # d_ff of each expert defaults to ArchConfig.d_ff
    # expert_split s > 1 splits each expert's ffn into s shards stored as
    # s separate "experts" [E*s, d, f/s] so E*s can EP-shard a wider model
    # axis than E allows (grok: 8 experts * 2 = 16-way EP). Semantics are
    # identical: a token routed to expert e runs on shards e*s..e*s+s-1 and
    # the halves sum in the combine scatter. §Perf lever.
    expert_split: int = 1


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                  # 0 => ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    block: Tuple[LayerSpec, ...]      # repeating pattern; len(block) | n_layers
    moe: Optional[MoESpec] = None
    mamba: Optional[MambaSpec] = None
    # encoder-decoder (seamless)
    enc_dec: bool = False
    n_enc_layers: int = 0
    # attention-free archs have n_heads==0 semantics handled by block specs
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    final_softcap: Optional[float] = None   # gemma2 final-logit capping
    tie_embeddings: bool = False
    act: str = "silu"                 # mlp activation
    # whether long (>=128k) decode is supported (sub-quadratic path exists)
    subquadratic: bool = False
    # citation / provenance tag, e.g. "[arXiv:2402.19173; hf]"
    source: str = ""
    # TP padding (set by padded_for_tp; zero-masked => semantics unchanged):
    # jit in_shardings require dims divisible by the mesh axis, so heads/vocab
    # that don't divide the 16-way model axis are padded (starcoder2 36->48,
    # arctic 56->64 heads; seamless vocab 256206->256208).
    pad_heads_to: Optional[int] = None
    pad_vocab_to: Optional[int] = None

    @property
    def eff_heads(self) -> int:
        return self.pad_heads_to or self.n_heads

    @property
    def eff_vocab(self) -> int:
        return self.pad_vocab_to or self.vocab

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % len(self.block) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by block "
            f"pattern length {len(self.block)}")
        return self.n_layers // len(self.block)

    @property
    def dt_rank(self) -> int:
        if self.mamba is None:
            return 0
        return self.mamba.dt_rank or math.ceil(self.d_model / 16)

    @property
    def d_inner(self) -> int:
        return 0 if self.mamba is None else self.mamba.expand * self.d_model

    # ---- analytic parameter / FLOP accounting (used by roofline) ----------
    def layer_kinds(self) -> Sequence[LayerSpec]:
        """Full per-layer spec list (block repeated)."""
        return list(self.block) * self.n_blocks

    def param_count(self) -> int:
        """Analytic total parameter count (matches the jax init exactly)."""
        d, h, kv, dh, f, V = (self.d_model, self.n_heads, self.n_kv_heads,
                              self.d_head, self.d_ff, self.vocab)
        total = V * d                                  # embed
        if not self.tie_embeddings:
            total += V * d                             # unembed
        total += d                                     # final norm

        def attn_params() -> int:
            return d * h * dh + 2 * d * kv * dh + h * dh * d

        def dense_ffn(ff: int) -> int:
            return 3 * d * ff                          # gated mlp (w1,w3,w2)

        def moe_ffn() -> int:
            m = self.moe
            return d * m.n_experts + m.n_experts * 3 * d * f

        def mamba_params() -> int:
            di, ds, dc, dr = (self.d_inner, self.mamba.d_state,
                              self.mamba.d_conv, self.dt_rank)
            return (d * 2 * di            # in_proj (x & z)
                    + di * dc             # depthwise conv
                    + di * (dr + 2 * ds)  # x -> dt,B,C
                    + dr * di + di        # dt_proj (+bias)
                    + di * ds + di        # A_log, D
                    + di * d)             # out_proj

        def one_layer(spec: LayerSpec) -> int:
            p = d if spec.ffn == "none" else 2 * d     # rmsnorms
            if spec.mixer == "attn":
                p += attn_params()
                if spec.attn.qk_norm:
                    p += 2 * dh
            elif spec.mixer == "mamba":
                p += mamba_params()
            if spec.ffn == "dense":
                p += dense_ffn(f)
            elif spec.ffn == "moe":
                p += moe_ffn()
            elif spec.ffn == "moe_dense":
                p += moe_ffn() + dense_ffn(f)
            return p

        dec_layers = sum(one_layer(s) for s in self.layer_kinds())
        total += dec_layers
        if self.enc_dec:
            # encoder: self-attn + dense ffn per layer; decoder adds cross-attn
            enc = self.n_enc_layers * (2 * d + attn_params() + dense_ffn(f)) + d
            cross = self.n_layers * (d + attn_params())
            total += enc + cross
        return total

    def padded_for_tp(self, tp: int) -> "ArchConfig":
        """Return a config with head/vocab padding for a tp-way model axis."""
        def up(n):
            return -(-n // tp) * tp
        kw = {}
        if self.n_heads and self.n_heads % tp != 0:
            # padded head count must stay a multiple of kv groups
            ph = up(self.n_heads)
            while ph % self.n_kv_heads != 0:
                ph += tp
            kw["pad_heads_to"] = ph
        if self.vocab % tp != 0:
            kw["pad_vocab_to"] = up(self.vocab)
        return dataclasses.replace(self, **kw) if kw else self

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        n_moe_layers = sum(1 for s in self.layer_kinds()
                           if s.ffn in ("moe", "moe_dense"))
        inactive = n_moe_layers * (m.n_experts - m.top_k) * 3 * self.d_model * self.d_ff
        return full - inactive
