"""Architecture config registry + reduced-size variants for CPU smoke tests."""
from __future__ import annotations

import dataclasses

from repro.configs.base import (ArchConfig, AttnSpec, LayerSpec, MambaSpec,
                                MoESpec)
from repro.configs.shapes import (ALL_SHAPES, DECODE_32K, LONG_500K,
                                  PREFILL_32K, TRAIN_4K, ShapeSpec, shapes_for)

from repro.configs.chameleon_34b import CONFIG as CHAMELEON_34B
from repro.configs.starcoder2_7b import CONFIG as STARCODER2_7B
from repro.configs.internlm2_1_8b import CONFIG as INTERNLM2_1_8B
from repro.configs.qwen3_32b import CONFIG as QWEN3_32B
from repro.configs.gemma2_9b import CONFIG as GEMMA2_9B
from repro.configs.jamba_1_5_large import CONFIG as JAMBA_1_5_LARGE
from repro.configs.seamless_m4t_large import CONFIG as SEAMLESS_M4T_LARGE
from repro.configs.grok_1_314b import CONFIG as GROK_1_314B
from repro.configs.arctic_480b import CONFIG as ARCTIC_480B
from repro.configs.falcon_mamba_7b import CONFIG as FALCON_MAMBA_7B

ARCHS = {c.name: c for c in (
    CHAMELEON_34B, STARCODER2_7B, INTERNLM2_1_8B, QWEN3_32B, GEMMA2_9B,
    JAMBA_1_5_LARGE, SEAMLESS_M4T_LARGE, GROK_1_314B, ARCTIC_480B,
    FALCON_MAMBA_7B,
)}

# short aliases for --arch flags
ALIASES = {
    "chameleon-34b": "chameleon-34b",
    "starcoder2-7b": "starcoder2-7b",
    "internlm2-1.8b": "internlm2-1.8b",
    "qwen3-32b": "qwen3-32b",
    "gemma2-9b": "gemma2-9b",
    "jamba-1.5-large-398b": "jamba-1.5-large-398b",
    "jamba": "jamba-1.5-large-398b",
    "seamless-m4t-large-v2": "seamless-m4t-large-v2",
    "seamless": "seamless-m4t-large-v2",
    "grok-1-314b": "grok-1-314b",
    "grok": "grok-1-314b",
    "arctic-480b": "arctic-480b",
    "arctic": "arctic-480b",
    "falcon-mamba-7b": "falcon-mamba-7b",
    "falcon-mamba": "falcon-mamba-7b",
}


def get_config(name: str) -> ArchConfig:
    return ARCHS[ALIASES.get(name, name)]


def reduced(cfg: ArchConfig, *, d_model: int = 128, n_layers: int | None = None,
            vocab: int = 512, d_ff: int = 256, n_heads: int = 4,
            n_kv_heads: int | None = None) -> ArchConfig:
    """A tiny same-family variant of ``cfg`` for CPU smoke tests.

    Keeps the block pattern (so gemma2 still alternates local/global, jamba
    still interleaves mamba/attn/moe) but shrinks every dimension.
    """
    n_layers = n_layers if n_layers is not None else len(cfg.block)
    if n_layers % len(cfg.block) != 0:
        n_layers = len(cfg.block)
    kv = n_kv_heads if n_kv_heads is not None else max(1, n_heads // 2)
    if cfg.n_heads == 0:   # attention-free
        n_heads, kv, d_head = 0, 0, 0
    else:
        d_head = max(8, d_model // n_heads)
    moe = None
    if cfg.moe is not None:
        moe = MoESpec(n_experts=min(cfg.moe.n_experts, 4),
                      top_k=min(cfg.moe.top_k, 2),
                      capacity_factor=cfg.moe.capacity_factor)
    mamba = None
    if cfg.mamba is not None:
        mamba = MambaSpec(d_state=8, d_conv=4, expand=2)
    # shrink local windows so they are exercised at tiny seq lens
    block = tuple(
        dataclasses.replace(
            s, attn=dataclasses.replace(
                s.attn, window=(8 if s.attn.window else None)))
        for s in cfg.block)
    return dataclasses.replace(
        cfg, name=cfg.name + "-reduced", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=kv, d_head=d_head,
        d_ff=(0 if cfg.d_ff == 0 else d_ff), vocab=vocab, block=block,
        moe=moe, mamba=mamba,
        n_enc_layers=(2 if cfg.enc_dec else 0),
    )
