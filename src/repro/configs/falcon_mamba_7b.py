"""falcon-mamba-7b [ssm] — attention-free Mamba-1, ssm_state=16
[arXiv:2410.05355; unverified]. Mamba layers ARE the mixer+ffn (no separate
MLP), matching the mamba1 architecture. Fully sub-quadratic => long_500k."""
from repro.configs.base import ArchConfig, LayerSpec, MambaSpec

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab=65024,
    block=(LayerSpec(mixer="mamba", ffn="none"),),
    mamba=MambaSpec(d_state=16, d_conv=4, expand=2),
    subquadratic=True,
    source="[arXiv:2410.05355; unverified]",
)
