"""Assigned input-shape sets for the LM-family architectures.

``train_*`` / ``prefill_*`` lower ``train_step`` / prefill forward;
``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV /
SSM cache of ``seq_len``), NOT ``train_step``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str             # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    # microbatch accumulation for training (tuned per arch in dryrun)
    accum: int = 1


TRAIN_4K = ShapeSpec("train_4k", "train", seq_len=4_096, global_batch=256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", seq_len=32_768, global_batch=32)
DECODE_32K = ShapeSpec("decode_32k", "decode", seq_len=32_768, global_batch=128)
LONG_500K = ShapeSpec("long_500k", "decode", seq_len=524_288, global_batch=1)

ALL_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shapes_for(arch) -> list:
    """Applicable shape cells for an arch (long_500k needs sub-quadratic)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if arch.subquadratic:
        out.append(LONG_500K)
    return out
