"""Pure-JAX model layers shared by all 10 assigned architectures.

Every ``init_*`` returns ``(params, logical_specs)`` where ``logical_specs``
is a pytree of tuples of logical axis names (mapped to mesh axes by
``repro.parallel.sharding``). Every ``apply_*`` is shape-polymorphic and used
for train/prefill (full-sequence) and decode (single-token + cache) paths.

Attention uses a *query-chunked* XLA path by default (memory-safe for 32k
prefill without materialising the full S x S score matrix); on TPU the Pallas
``flash_attention`` kernel from ``repro.kernels`` can be selected via
``attn_impl="pallas"``.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, AttnSpec

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True)}[name]


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs       # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                             # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _init(key, shape, scale, dtype):
    if key is None:   # specs-only mode: no allocation (used by logical_specs)
        return jax.ShapeDtypeStruct(shape, dtype)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# activation-sharding context: GSPMD propagation from the param shardings
# alone replicates attention heads through scan/map bodies (measured 5.5x
# compute blow-up), so the model inserts explicit constraints when a context
# is set (by forward()/decode_step() from Runtime; off for 1-device tests).
# Tokens: "dp" -> data axes, "tp" -> tensor axis, None -> unsharded.
# ---------------------------------------------------------------------------

_SHARD_CTX: dict | None = None


def set_shard_ctx(ctx: dict | None):
    global _SHARD_CTX
    _SHARD_CTX = ctx


def _cs(x: jax.Array, *axes):
    """Apply with_sharding_constraint if a sharding context is active."""
    if _SHARD_CTX is None:
        return x
    from jax.sharding import PartitionSpec as P
    resolved = tuple(
        _SHARD_CTX["dp"] if a == "dp" else
        (_SHARD_CTX["tp"] if a == "tp" else None)
        for a in axes)
    return jax.lax.with_sharding_constraint(x, P(*resolved))


def _cs_ep(x: jax.Array, *axes):
    """Like _cs but the 'ep' token maps to tp only when EP is active."""
    if _SHARD_CTX is None:
        return x
    from jax.sharding import PartitionSpec as P
    resolved = tuple(
        _SHARD_CTX["tp"] if (a == "ep" and _SHARD_CTX.get("ep")) else
        (_SHARD_CTX["dp"] if a == "dp" else
         (_SHARD_CTX["tp"] if a == "tp" else None))
        for a in axes)
    return jax.lax.with_sharding_constraint(x, P(*resolved))


def _split(key, n):
    return jax.random.split(key, n) if key is not None else [None] * n


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, spec: AttnSpec, dtype) -> Tuple[Params, Params]:
    d, h, kv, dh = cfg.d_model, cfg.eff_heads, cfg.n_kv_heads, cfg.d_head
    ks = _split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": _init(ks[0], (d, h, dh), s, dtype),
        "wk": _init(ks[1], (d, kv, dh), s, dtype),
        "wv": _init(ks[2], (d, kv, dh), s, dtype),
        "wo": _init(ks[3], (h, dh, d), 1.0 / math.sqrt(h * dh), dtype),
    }
    l = {
        "wq": ("embed", "heads", "head"),
        "wk": ("embed", "kv_heads", "head"),
        "wv": ("embed", "kv_heads", "head"),
        "wo": ("heads", "head", "embed"),
    }
    if spec.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), dtype)
        p["k_norm"] = jnp.zeros((dh,), dtype)
        l["q_norm"] = (None,)
        l["k_norm"] = (None,)
    return p, l


def _attn_mask(q_pos, k_pos, window: Optional[int]):
    """causal (+ optional sliding window) mask: [*, Sq, Sk] bool (True=keep)."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def apply_attention(p: Params, x: jax.Array, spec: AttnSpec, cfg: ArchConfig,
                    positions: jax.Array, *, kv_override: Optional[Tuple] = None,
                    kv_positions: Optional[jax.Array] = None,
                    causal: bool = True, q_chunk: int = 1024,
                    attn_impl: str = "xla") -> jax.Array:
    """Full-sequence attention (train / prefill / encoder / cross).

    x: [B, S, d].  kv_override: (k_src, v_src) already projected (cross-attn
    passes encoder memory through wk/wv itself via this fn with x_kv).
    """
    B, S, _ = x.shape
    h, kv, dh = cfg.eff_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        k_pos = positions
    else:
        xkv, k_pos = kv_override
        k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"])
    if spec.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if causal or kv_override is None:   # self-attn gets RoPE; cross does not
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, k_pos, cfg.rope_theta)
    # GQA via repeat: kv heads -> q heads BEFORE the score einsum. The repeat
    # keeps the head dim shardable over "model" (a reshape h->(kv,groups)
    # would break GSPMD head sharding and replicate attention compute).
    groups = h // kv
    Sk = k.shape[1]
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
    q = _cs(q, "dp", None, "tp", None)
    k = _cs(k, "dp", None, "tp", None)
    v = _cs(v, "dp", None, "tp", None)

    if attn_impl == "pallas":
        return _pallas_attn(p, q, k, v, spec, positions, k_pos, causal)

    scale = 1.0 / math.sqrt(dh)
    n_chunks = max(1, S // q_chunk) if S % q_chunk == 0 else 1
    qs = q.reshape(B, n_chunks, S // n_chunks, h, dh)
    pos_b = jnp.broadcast_to(positions, (B, S)) if positions.ndim == 2 \
        else jnp.broadcast_to(positions[None, :], (B, S))
    kpos_b = jnp.broadcast_to(k_pos, (B, Sk)) if k_pos.ndim == 2 \
        else jnp.broadcast_to(k_pos[None, :], (B, Sk))
    qpos = pos_b.reshape(B, n_chunks, S // n_chunks)

    def one_chunk(args):
        qc, qp = args   # [B, Sq, h, dh], [B, Sq]
        sc = jnp.einsum("bqhd,bshd->bhqs", qc.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
        sc = _cs(sc, "dp", "tp", None, None)
        if spec.softcap is not None:
            sc = spec.softcap * jnp.tanh(sc / spec.softcap)
        if causal:
            m = _attn_mask(qp, kpos_b, spec.window)
        else:
            m = jnp.ones((B, qc.shape[1], Sk), bool)
        sc = jnp.where(m[:, None], sc, -1e30)
        pr = jax.nn.softmax(sc, axis=-1)
        return jnp.einsum("bhqs,bshd->bqhd", pr.astype(v.dtype), v)

    out = lax.map(one_chunk, (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(qpos, 1, 0)))
    out = _cs(jnp.moveaxis(out, 0, 1).reshape(B, S, h, dh),
              "dp", None, "tp", None)
    if h != cfg.n_heads:   # zero TP-padded heads (blocks grads into wo pad)
        out = out * (jnp.arange(h) < cfg.n_heads)[None, None, :, None
                                                  ].astype(out.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def _pallas_attn(p, q, k, v, spec, q_pos, k_pos, causal):
    from repro.kernels import ops as kops
    B, S, h, dh = q.shape
    out = kops.flash_attention(q, k, v, causal=causal, window=spec.window,
                               softcap=spec.softcap)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def apply_attention_decode(p: Params, x: jax.Array, spec: AttnSpec,
                           cfg: ArchConfig, cache_k: jax.Array,
                           cache_v: jax.Array, pos: jax.Array,
                           *, cross: bool = False,
                           cross_len: Optional[jax.Array] = None):
    """One-token decode. x: [B, 1, d]; cache_k/v: [B, S, kv, dh]; pos: [B].

    Returns (out [B,1,d], new_cache_k, new_cache_v). For cross-attn the cache
    holds the (pre-projected) encoder memory K/V and is not updated.
    """
    B, _, _ = x.shape
    h, kv, dh = cfg.eff_heads, cfg.n_kv_heads, cfg.d_head
    S = cache_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if spec.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    if not cross:
        k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        if spec.qk_norm:
            k_new = rms_norm(k_new, p["k_norm"], cfg.norm_eps)
        q = rope(q, pos[:, None], cfg.rope_theta)
        k_new = rope(k_new, pos[:, None], cfg.rope_theta)
        # insert at position pos (ring-buffer for windowed layers handled by mask)
        oh = jax.nn.one_hot(pos % S, S, dtype=cache_k.dtype)       # [B,S]
        cache_k = cache_k * (1 - oh)[:, :, None, None] + oh[:, :, None, None] * k_new
        cache_v = cache_v * (1 - oh)[:, :, None, None] + oh[:, :, None, None] * v_new
    # Decode keeps the cache at kv heads with the SEQUENCE dim sharded (SP);
    # q is tiny, so its heads are gathered and grouped instead of repeating
    # K/V to h heads (the repeat materialised a 2x cache copy — measured
    # +11GB/chip on gemma2 decode_32k).
    groups = h // kv
    kk = _cs(cache_k, "dp", "tp", None, None)   # SP: cache seq stays sharded
    vv = _cs(cache_v, "dp", "tp", None, None)
    qh = _cs(q, "dp", None, None, None)[:, 0].reshape(B, kv, groups, dh)
    sc = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                    kk.astype(jnp.float32)) / math.sqrt(dh)
    sc = _cs(sc, "dp", None, None, "tp")
    if spec.softcap is not None:
        sc = spec.softcap * jnp.tanh(sc / spec.softcap)
    kpos = jnp.arange(S)[None, :]
    if cross:
        valid = kpos < (cross_len[:, None] if cross_len is not None
                        else jnp.full((B, 1), S))
    else:
        valid = kpos <= pos[:, None]
        if spec.window is not None:
            valid &= kpos > (pos[:, None] - spec.window)
    sc = jnp.where(valid[:, None, None], sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", pr.astype(vv.dtype), vv)
    out = out.reshape(B, h, dh)
    if h != cfg.n_heads:
        out = out * (jnp.arange(h) < cfg.n_heads)[None, :, None
                                                  ].astype(out.dtype)
    out = out[:, None]                                      # [B, 1, h, dh]
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache_k, cache_v


# ---------------------------------------------------------------------------
# dense gated MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ArchConfig, dtype) -> Tuple[Params, Params]:
    d, f = cfg.d_model, cfg.d_ff
    ks = _split(key, 3)
    p = {
        "w1": _init(ks[0], (d, f), 1 / math.sqrt(d), dtype),
        "w3": _init(ks[1], (d, f), 1 / math.sqrt(d), dtype),
        "w2": _init(ks[2], (f, d), 1 / math.sqrt(f), dtype),
    }
    l = {"w1": ("embed", "mlp"), "w3": ("embed", "mlp"), "w2": ("mlp", "embed")}
    return p, l


def apply_mlp(p: Params, x: jax.Array, act: str) -> jax.Array:
    g = _act(act)(_cs(jnp.einsum("bsd,df->bsf", x, p["w1"]), "dp", None, "tp"))
    u = _cs(jnp.einsum("bsd,df->bsf", x, p["w3"]), "dp", None, "tp")
    return jnp.einsum("bsf,fd->bsd", g * u, p["w2"])


# ---------------------------------------------------------------------------
# MoE — top-k routing with capacity, gather/scatter dispatch (no O(T·E·C)
# one-hot einsums; see DESIGN.md).  TPU-idiomatic: sort-based slotting.
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ArchConfig, dtype) -> Tuple[Params, Params]:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    sp = cfg.moe.expert_split
    E2, f2 = E * sp, f // sp
    ks = _split(key, 4)
    p = {
        "router": _init(ks[0], (d, E), 1 / math.sqrt(d), jnp.float32),
        "w1": _init(ks[1], (E2, d, f2), 1 / math.sqrt(d), dtype),
        "w3": _init(ks[2], (E2, d, f2), 1 / math.sqrt(d), dtype),
        "w2": _init(ks[3], (E2, f2, d), 1 / math.sqrt(f), dtype),
    }
    l = {
        "router": ("embed", None),
        "w1": ("expert", "embed", "expert_mlp"),
        "w3": ("expert", "embed", "expert_mlp"),
        "w2": ("expert", "expert_mlp", "embed"),
    }
    return p, l


def moe_capacity(n_tokens: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(m.capacity_factor * n_tokens * m.top_k / m.n_experts))
    # multiple of 32 so the capacity dim shards evenly over a 16-way axis
    return max(32, -(-c // 32) * 32)


MOE_TOKEN_CHUNK = 65_536


def apply_moe(p: Params, x: jax.Array, cfg: ArchConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B,S,d], aux_loss scalar).

    Token-chunked: the gather-based dispatch all-gathers the token block, so
    blocks are capped at MOE_TOKEN_CHUNK tokens (capacity is per-block —
    equivalent to microbatching the router)."""
    B, S, d = x.shape
    T = B * S
    if T > MOE_TOKEN_CHUNK and T % MOE_TOKEN_CHUNK == 0:
        n = T // MOE_TOKEN_CHUNK
        xc = x.reshape(n, -1, d)                # [n_chunks, chunk_tokens, d]
        outs, auxes = lax.map(lambda xi: _moe_block(p, xi[None], cfg), xc)
        return outs.reshape(B, S, d), jnp.mean(auxes)
    return _moe_block(p, x, cfg)


def _moe_block(p: Params, x: jax.Array, cfg: ArchConfig
               ) -> Tuple[jax.Array, jax.Array]:
    B, S, d = x.shape
    m = cfg.moe
    sp = m.expert_split
    T, E, K = B * S, m.n_experts * sp, m.top_k * sp
    C = moe_capacity(B * S, cfg)
    xf = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(probs, m.top_k)                # [T,k]
    top_w = top_w / (jnp.sum(top_w, -1, keepdims=True) + 1e-9)
    if sp > 1:
        # expert e -> split shards e*sp..e*sp+sp-1 (outputs sum in combine)
        top_e = (top_e[..., None] * sp
                 + jnp.arange(sp)[None, None, :]).reshape(T, K)
        top_w = jnp.repeat(top_w, sp, axis=-1)

    # ---- slotting: rank of each assignment within its expert ----
    flat_e = top_e.reshape(-1)                              # [T*K]
    order = jnp.argsort(flat_e, stable=True)                # token-order within expert
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)                 # tokens per expert
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * K) - starts[sorted_e]             # pos within expert
    keep = rank < C                                         # dropped beyond capacity
    slot = sorted_e * C + jnp.where(keep, rank, 0)          # [T*K]
    tok = order // K                                        # source token id

    slot_tok = jnp.zeros((E * C,), jnp.int32).at[slot].set(
        jnp.where(keep, tok, 0), mode="drop")
    slot_valid = jnp.zeros((E * C,), jnp.bool_).at[slot].set(keep, mode="drop")
    slot_w = jnp.zeros((E * C,), jnp.float32).at[slot].set(
        jnp.where(keep, top_w.reshape(-1)[order], 0.0), mode="drop")

    xe = jnp.take(xf, slot_tok, axis=0)                     # [E*C, d]  (gather)
    xe = jnp.where(slot_valid[:, None], xe, 0).reshape(E, C, d)
    ep = _SHARD_CTX is not None and _SHARD_CTX.get("ep")
    # EP: experts -> model AND capacity -> data (leaving C unsharded
    # replicates each expert's compute across the whole data axis — measured
    # 16x useful-flops waste on grok/arctic); non-EP: capacity -> data with
    # the expert ffn dim -> model.
    xe = _cs(xe, "tp" if ep else None, "dp", None)
    hidden_spec = ("tp", "dp", None) if ep else (None, "dp", "tp")
    g = _act(cfg.act)(_cs(jnp.einsum("ecd,edf->ecf", xe, p["w1"]), *hidden_spec))
    u = _cs(jnp.einsum("ecd,edf->ecf", xe, p["w3"]), *hidden_spec)
    ye = _cs(jnp.einsum("ecf,efd->ecd", g * u, p["w2"]),
             "tp" if ep else None, "dp", None).reshape(E * C, d)

    out = jnp.zeros((T, d), ye.dtype).at[slot_tok].add(
        ye * (slot_w * slot_valid)[:, None].astype(ye.dtype), mode="drop")

    # load-balance aux loss (Switch-style, on the un-split router)
    frac_tokens = jnp.bincount(flat_e // sp if sp > 1 else flat_e,
                               length=m.n_experts).astype(jnp.float32) / (T * K)
    mean_prob = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(frac_tokens * mean_prob)
    return out.reshape(B, S, d), aux


def apply_moe_decode(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Decode-path MoE for tiny T (B tokens): dense top-k gather of experts."""
    B, S, d = x.shape
    m = cfg.moe
    xf = x.reshape(B * S, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(probs, m.top_k)
    top_w = top_w / (jnp.sum(top_w, -1, keepdims=True) + 1e-9)
    w1 = jnp.take(p["w1"], top_e, axis=0)    # [T,K,d,f]
    w3 = jnp.take(p["w3"], top_e, axis=0)
    w2 = jnp.take(p["w2"], top_e, axis=0)    # [T,K,f,d]
    g = _act(cfg.act)(jnp.einsum("td,tkdf->tkf", xf, w1))
    u = jnp.einsum("td,tkdf->tkf", xf, w3)
    y = jnp.einsum("tkf,tkfd->tkd", g * u, w2)
    out = jnp.einsum("tkd,tk->td", y, top_w.astype(y.dtype))
    return out.reshape(B, S, d)


# ---------------------------------------------------------------------------
# Mamba-1 mixer (conv + selective scan)
# ---------------------------------------------------------------------------


def init_mamba(key, cfg: ArchConfig, dtype) -> Tuple[Params, Params]:
    d = cfg.d_model
    ms = cfg.mamba
    di, ds, dc, dr = cfg.d_inner, ms.d_state, ms.d_conv, cfg.dt_rank
    ks = _split(key, 6)
    p = {
        "in_proj": _init(ks[0], (d, 2 * di), 1 / math.sqrt(d), dtype),
        "conv_w": _init(ks[1], (dc, di), 1 / math.sqrt(dc), dtype),
        "x_proj": _init(ks[2], (di, dr + 2 * ds), 1 / math.sqrt(di), dtype),
        "dt_proj": _init(ks[3], (dr, di), 1 / math.sqrt(dr), dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32) + jnp.log(jnp.expm1(0.01)),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))).astype(jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _init(ks[5], (di, d), 1 / math.sqrt(di), dtype),
    }
    l = {
        "in_proj": ("embed", "inner"),
        "conv_w": (None, "inner"),
        "x_proj": ("inner", None),
        "dt_proj": (None, "inner"),
        "dt_bias": ("inner",),
        "A_log": ("inner", None),
        "D": ("inner",),
        "out_proj": ("inner", "embed"),
    }
    return p, l


def _mamba_pre(p: Params, x: jax.Array, cfg: ArchConfig,
               conv_state: Optional[jax.Array] = None):
    """Shared projection + causal depthwise conv. x: [B,S,d].

    Returns (u [B,S,di] post-conv+silu, z gate [B,S,di], dt, Bc, Cc, new_conv_tail).
    """
    ms = cfg.mamba
    di, ds, dc, dr = cfg.d_inner, ms.d_state, ms.d_conv, cfg.dt_rank
    xz = _cs(jnp.einsum("bsd,de->bse", x, p["in_proj"]), "dp", None, "tp")
    u, z = jnp.split(xz, 2, axis=-1)                       # [B,S,di] each
    # causal depthwise conv via shifted adds (k = d_conv, tiny)
    if conv_state is None:
        pad = jnp.zeros((u.shape[0], dc - 1, di), u.dtype)
    else:
        pad = conv_state.astype(u.dtype)                   # [B, dc-1, di]
    up = jnp.concatenate([pad, u], axis=1)                 # [B, S+dc-1, di]
    conv = sum(up[:, i:i + u.shape[1], :] * p["conv_w"][i][None, None]
               for i in range(dc))
    new_tail = up[:, up.shape[1] - (dc - 1):, :]
    u = jax.nn.silu(conv)
    dbc = jnp.einsum("bsi,ie->bse", u, p["x_proj"])
    dt, Bc, Cc = jnp.split(dbc, [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt, p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"])
    return u, z, dt, Bc, Cc, new_tail


MAMBA_CHUNK = 256


def apply_mamba(p: Params, x: jax.Array, cfg: ArchConfig,
                scan_impl: str = "chunked") -> jax.Array:
    """Full-sequence selective scan. x: [B,S,d] -> [B,S,d].

    Default path is CHUNKED+FUSED: a sequential lax.scan over
    S/MAMBA_CHUNK chunks carrying the SSM state; the [*, chunk, di, ds]
    expansion a_t=exp(dt A), b_t=dt*B*u AND the contraction y=h.C happen
    INSIDE the chunk body, so no [B,S,di,ds] tensor ever exists — only
    [B,chunk,di,ds] working sets (the same blocking the Pallas
    selective_scan kernel keeps in VMEM). §Perf P5/P8.
    """
    ms = cfg.mamba
    S = x.shape[1]
    u, z, dt, Bc, Cc, _ = _mamba_pre(p, x, cfg)
    A = -jnp.exp(p["A_log"])                               # [di, ds]

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    def expand(u_c, dt_c, B_c):
        a = jnp.exp(dt_c[..., None] * A)                   # [.., di, ds] f32
        a = _cs(a, "dp", None, "tp", None)
        b = (dt_c * u_c.astype(jnp.float32))[..., None] * \
            B_c.astype(jnp.float32)[:, :, None, :]
        return a, _cs(b, "dp", None, "tp", None)

    if scan_impl == "pallas":
        from repro.kernels import ops as kops
        a, b = expand(u, dt, Bc)
        h = kops.selective_scan(a, b)
        y = jnp.einsum("bsin,bsn->bsi", h, Cc.astype(jnp.float32))
    elif scan_impl == "chunked" and S > MAMBA_CHUNK and S % MAMBA_CHUNK == 0:
        n = S // MAMBA_CHUNK
        Bsz, di, ds = x.shape[0], cfg.d_inner, ms.d_state

        def to_chunks(t):
            return jnp.moveaxis(
                t.reshape(Bsz, n, MAMBA_CHUNK, t.shape[-1]), 1, 0)

        def chunk_step(h0, args):
            u_c, dt_c, B_c, C_c = args        # [B, chunk, ...]
            a, b = expand(u_c, dt_c, B_c)
            cum_a, hin = lax.associative_scan(combine, (a, b), axis=1)
            hi = hin + cum_a * h0[:, None]    # fold in carried state
            y_c = jnp.einsum("bsin,bsn->bsi", hi, C_c.astype(jnp.float32))
            return hi[:, -1], y_c

        _, yc = lax.scan(chunk_step, jnp.zeros((Bsz, di, ds), jnp.float32),
                         (to_chunks(u), to_chunks(dt), to_chunks(Bc),
                          to_chunks(Cc)))
        y = jnp.moveaxis(yc, 0, 1).reshape(Bsz, S, di)
    else:
        a, b = expand(u, dt, Bc)
        _, h = lax.associative_scan(combine, (a, b), axis=1)
        y = jnp.einsum("bsin,bsn->bsi", h, Cc.astype(jnp.float32))
    y = y + u.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"])


def apply_mamba_decode(p: Params, x: jax.Array, cfg: ArchConfig,
                       conv_state: jax.Array, ssm_state: jax.Array):
    """One-token step. x: [B,1,d]; conv_state [B,dc-1,di]; ssm_state [B,di,ds]."""
    u, z, dt, Bc, Cc, new_tail = _mamba_pre(p, x, cfg, conv_state=conv_state)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * A)                     # [B,di,ds]
    b = (dt[:, 0] * u[:, 0].astype(jnp.float32))[..., None] * \
        Bc[:, 0].astype(jnp.float32)[:, None, :]
    h = a * ssm_state + b
    y = jnp.einsum("bin,bn->bi", h, Cc[:, 0].astype(jnp.float32))
    y = y + u[:, 0].astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"])[:, None, :]
    return out, new_tail, h
