"""Model assembly: decoder LM (all archs) + encoder-decoder (seamless).

Layers repeat as *blocks* (cfg.block pattern) stacked with ``lax.scan`` so the
HLO contains one block body regardless of depth — critical for fast GSPMD
compiles at 256/512 devices. Params for in-block position ``i`` live in
``params["blocks"][i]`` with every leaf stacked over ``n_blocks`` on axis 0.

Public API:
    init_params(key, cfg, dtype)        -> params
    logical_specs(cfg)                  -> pytree of logical-axis tuples
    forward(params, batch, cfg, rt)     -> logits (train/prefill; enc-dec aware)
    init_cache(cfg, B, S, dtype, ...)   -> decode cache pytree (+ specs)
    decode_step(params, cache, tokens, pos, cfg, rt) -> (logits, new_cache)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import layers as L

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Static runtime knobs (hashable; passed as static arg to jit)."""
    attn_impl: str = "xla"        # "xla" | "pallas"
    scan_impl: str = "chunked"    # mamba scan: "chunked" | "assoc" | "pallas"
    remat: str = "block"          # "none" | "block" | "full"
    q_chunk: int = 1024
    aux_loss_weight: float = 0.01
    cross_len: int = 4096         # encoder memory length for enc-dec decode
    # activation sharding (GSPMD propagation alone replicates heads through
    # scan bodies — see layers._cs). Empty dp_axes => batch unsharded.
    shard_activations: bool = False
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    ep: bool = True

    def shard_ctx(self):
        if not self.shard_activations:
            return None
        return {"dp": self.dp_axes if self.dp_axes else None,
                "tp": self.tp_axis or None, "ep": self.ep}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ArchConfig, spec: LayerSpec, dtype,
                with_cross: bool) -> Tuple[Params, Params]:
    ks = L._split(key, 8)
    p: Params = {"norm1": jnp.zeros((cfg.d_model,), dtype)}
    l: Params = {"norm1": (None,)}
    if spec.mixer == "attn":
        p["attn"], l["attn"] = L.init_attention(ks[0], cfg, spec.attn, dtype)
    else:
        p["mamba"], l["mamba"] = L.init_mamba(ks[0], cfg, dtype)
    if with_cross:
        p["norm_cross"] = jnp.zeros((cfg.d_model,), dtype)
        l["norm_cross"] = (None,)
        p["cross"], l["cross"] = L.init_attention(ks[1], cfg, spec.attn, dtype)
    if spec.ffn != "none":
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        l["norm2"] = (None,)
    if spec.ffn == "dense":
        p["mlp"], l["mlp"] = L.init_mlp(ks[2], cfg, dtype)
    elif spec.ffn == "moe":
        p["moe"], l["moe"] = L.init_moe(ks[3], cfg, dtype)
    elif spec.ffn == "moe_dense":
        p["moe"], l["moe"] = L.init_moe(ks[3], cfg, dtype)
        p["mlp"], l["mlp"] = L.init_mlp(ks[4], cfg, dtype)
    return p, l


def _stacked_layer_init(key, cfg, spec, dtype, n, with_cross=False):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_layer(k, cfg, spec, dtype, with_cross)[0])(keys)


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 8 + len(cfg.block))
    d, V = cfg.d_model, cfg.eff_vocab
    p: Params = {
        "embed": (jax.random.normal(ks[0], (V, d), jnp.float32)).astype(dtype),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(ks[1], (d, V), jnp.float32)
                        / math.sqrt(d)).astype(dtype)
    p["blocks"] = [
        _stacked_layer_init(ks[8 + i], cfg, spec, dtype, cfg.n_blocks,
                            with_cross=cfg.enc_dec)
        for i, spec in enumerate(cfg.block)
    ]
    if cfg.enc_dec:
        enc_spec = LayerSpec(mixer="attn", ffn="dense")
        p["encoder"] = {
            "layers": _stacked_layer_init(ks[2], cfg, enc_spec, dtype,
                                          cfg.n_enc_layers),
            "final_norm": jnp.zeros((d,), dtype),
        }
    return p


def logical_specs(cfg: ArchConfig) -> Params:
    """Pytree matching init_params with logical-axis tuples at leaves."""
    def _init_layer_specs(spec, with_cross):
        # key=None puts the init fns in specs-only mode: large tensors come
        # back as ShapeDtypeStructs, so nothing real is allocated even for
        # the 480B config.
        _, l = _init_layer(None, cfg, spec, jnp.bfloat16, with_cross)
        return l

    out: Params = {"embed": ("vocab", "embed"), "final_norm": (None,)}
    if not cfg.tie_embeddings:
        out["unembed"] = ("embed", "vocab")

    def stack(l):   # scanned leaves gain a leading "layers" axis
        return jax.tree.map(lambda ax: ("layers",) + tuple(ax), l,
                            is_leaf=lambda x: isinstance(x, tuple))

    out["blocks"] = [stack(_init_layer_specs(spec, cfg.enc_dec))
                     for spec in cfg.block]
    if cfg.enc_dec:
        enc_spec = LayerSpec(mixer="attn", ffn="dense")
        out["encoder"] = {
            "layers": stack(_init_layer_specs(enc_spec, False)),
            "final_norm": (None,),
        }
    return out


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _apply_layer(p: Params, spec: LayerSpec, x, positions, cfg, rt: Runtime,
                 memory=None, mem_positions=None):
    aux = jnp.zeros((), jnp.float32)
    x = L._cs(x, "dp", None, None)
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.mixer == "attn":
        mix = L.apply_attention(p["attn"], h, spec.attn, cfg, positions,
                                q_chunk=rt.q_chunk, attn_impl=rt.attn_impl)
    else:
        mix = L.apply_mamba(p["mamba"], h, cfg, scan_impl=rt.scan_impl)
    x = x + mix
    if memory is not None:
        h = L.rms_norm(x, p["norm_cross"], cfg.norm_eps)
        cross = L.apply_attention(
            p["cross"], h, spec.attn, cfg, positions,
            kv_override=(memory, mem_positions), causal=False,
            q_chunk=rt.q_chunk, attn_impl="xla")
        x = x + cross
    if spec.ffn != "none":
        h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        f = jnp.zeros_like(x)
        if spec.ffn in ("moe", "moe_dense"):
            mo, a = L.apply_moe(p["moe"], h, cfg)
            f = f + mo
            aux = aux + a
        if spec.ffn in ("dense", "moe_dense"):
            f = f + L.apply_mlp(p["mlp"], h, cfg.act)
        x = x + f
    return x, aux


def _block_fn(block_params, x, positions, cfg, rt, memory, mem_positions):
    aux = jnp.zeros((), jnp.float32)
    for i, spec in enumerate(cfg.block):
        x, a = _apply_layer(block_params[i], spec, x, positions, cfg, rt,
                            memory=memory if cfg.enc_dec else None,
                            mem_positions=mem_positions)
        aux = aux + a
    return x, aux


def _run_blocks(params, x, positions, cfg, rt, memory=None, mem_positions=None):
    def body(carry, xs):
        x, aux = carry
        x, a = _block_fn(xs, x, positions, cfg, rt, memory, mem_positions)
        return (x, aux + a), None

    body_fn = body
    if rt.remat in ("block", "full"):
        policy = (jax.checkpoint_policies.nothing_saveable if rt.remat == "full"
                  else jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        body_fn = jax.checkpoint(body, policy=policy, prevent_cse=False)
    (x, aux), _ = lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                           tuple(params["blocks"]))
    return x, aux


def _embed(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _logits(params, x, cfg):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    logits = logits.astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    if cfg.eff_vocab != cfg.vocab:   # mask TP-padded vocab rows
        logits = jnp.where(jnp.arange(cfg.eff_vocab) < cfg.vocab,
                           logits, -1e30)
    return logits


def _encode(params, frames, cfg, rt):
    """frames: [B, Ss, d] precomputed frontend embeddings (stub frontend).

    Bidirectional self-attention encoder, scanned over layers.
    """
    Ss = frames.shape[1]
    positions = jnp.arange(Ss)[None, :]
    enc_spec = LayerSpec(mixer="attn", ffn="dense")

    def enc_layer(x, lp):
        h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
        mix = L.apply_attention(lp["attn"], h, enc_spec.attn, cfg, positions,
                                causal=False, q_chunk=rt.q_chunk)
        x = x + mix
        h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + L.apply_mlp(lp["mlp"], h, cfg.act)
        return x, None

    enc_fn = (jax.checkpoint(enc_layer, prevent_cse=False)
              if rt.remat != "none" else enc_layer)
    x, _ = lax.scan(enc_fn, frames, params["encoder"]["layers"])
    return L.rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps), positions


def forward(params: Params, batch: Dict[str, jax.Array], cfg: ArchConfig,
            rt: Runtime = Runtime()) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits [B,S,V], moe_aux scalar).

    batch: {"tokens": [B,S] int32}  (+ "frames": [B,Ss,d] for enc-dec).
    """
    L.set_shard_ctx(rt.shard_ctx())
    try:
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.arange(S)[None, :]
        x = L._cs(_embed(params, tokens, cfg), "dp", None, None)
        memory = mem_pos = None
        if cfg.enc_dec:
            memory, mem_pos = _encode(params, batch["frames"].astype(x.dtype),
                                      cfg, rt)
        x, aux = _run_blocks(params, x, positions, cfg, rt, memory, mem_pos)
        return L._cs(_logits(params, x, cfg), "dp", None, "tp"), aux
    finally:
        L.set_shard_ctx(None)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, B: int, S: int, dtype=jnp.bfloat16,
               cross_len: int = 4096):
    """Decode cache: per in-block position, stacked over n_blocks (axis 0)."""
    n = cfg.n_blocks
    cache = []
    for spec in cfg.block:
        if spec.mixer == "attn":
            c = {"k": jnp.zeros((n, B, S, cfg.n_kv_heads, cfg.d_head), dtype),
                 "v": jnp.zeros((n, B, S, cfg.n_kv_heads, cfg.d_head), dtype)}
        else:
            ms = cfg.mamba
            c = {"conv": jnp.zeros((n, B, ms.d_conv - 1, cfg.d_inner), dtype),
                 "ssm": jnp.zeros((n, B, cfg.d_inner, ms.d_state), jnp.float32)}
        if cfg.enc_dec:
            c["xk"] = jnp.zeros((n, B, cross_len, cfg.n_kv_heads, cfg.d_head), dtype)
            c["xv"] = jnp.zeros((n, B, cross_len, cfg.n_kv_heads, cfg.d_head), dtype)
        cache.append(c)
    return cache


def cache_logical_specs(cfg: ArchConfig):
    """Sharding: batch->data, kv seq->model (SP), mamba inner->model."""
    specs = []
    for spec in cfg.block:
        if spec.mixer == "attn":
            c = {"k": ("layers", "batch", "kv_seq", None, None),
                 "v": ("layers", "batch", "kv_seq", None, None)}
        else:
            c = {"conv": ("layers", "batch", None, "inner"),
                 "ssm": ("layers", "batch", "inner", None)}
        if cfg.enc_dec:
            c["xk"] = ("layers", "batch", "kv_seq", None, None)
            c["xv"] = ("layers", "batch", "kv_seq", None, None)
        specs.append(c)
    return specs


def decode_step(params: Params, cache, tokens: jax.Array, pos: jax.Array,
                cfg: ArchConfig, rt: Runtime = Runtime()):
    """One decode step. tokens: [B] int32; pos: [B] current positions.

    Returns (logits [B,V], new_cache).
    """
    L.set_shard_ctx(rt.shard_ctx())
    try:
        return _decode_step_inner(params, cache, tokens, pos, cfg, rt)
    finally:
        L.set_shard_ctx(None)


def _decode_step_inner(params, cache, tokens, pos, cfg, rt):
    x = _embed(params, tokens[:, None], cfg)      # [B,1,d]

    def body(x, xs):
        new_cache = []
        x = L._cs(x, "dp", None, None)
        for i, spec in enumerate(cfg.block):
            lp, c = xs[0][i], xs[1][i]
            h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
            if spec.mixer == "attn":
                mix, nk, nv = L.apply_attention_decode(
                    lp["attn"], h, spec.attn, cfg, c["k"], c["v"], pos)
                nc = {"k": nk, "v": nv}
            else:
                mix, nconv, nssm = L.apply_mamba_decode(
                    lp["mamba"], h, cfg, c["conv"], c["ssm"])
                nc = {"conv": nconv, "ssm": nssm}
            x = x + mix
            if cfg.enc_dec:
                h = L.rms_norm(x, lp["norm_cross"], cfg.norm_eps)
                cross, _, _ = L.apply_attention_decode(
                    lp["cross"], h, spec.attn, cfg, c["xk"], c["xv"], pos,
                    cross=True)
                x = x + cross
                nc["xk"], nc["xv"] = c["xk"], c["xv"]
            if spec.ffn != "none":
                h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
                f = jnp.zeros_like(x)
                if spec.ffn in ("moe", "moe_dense"):
                    mo, _ = L.apply_moe(lp["moe"], h, cfg)
                    f = f + mo
                if spec.ffn in ("dense", "moe_dense"):
                    f = f + L.apply_mlp(lp["mlp"], h, cfg.act)
                x = x + f
            new_cache.append(nc)
        return x, tuple(new_cache)

    x, new_cache = lax.scan(body, x, (tuple(params["blocks"]), tuple(cache)))
    logits = _logits(params, x, cfg)[:, 0, :]
    return logits, list(new_cache)
