from repro.models.model import (Runtime, decode_step, forward, init_cache,
                                init_params, logical_specs,
                                cache_logical_specs)
