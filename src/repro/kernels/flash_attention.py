"""Pallas TPU flash attention (blockwise online softmax).

Target: TPU v5e MXU — q/k/v tiles stream HBM->VMEM in (block_q x block_k)
steps; scores/normalisers never touch HBM (this removes the dominant
memory-roofline term of the XLA attention path: the [B,H,S,S_chunk] f32
score tensors). Supports causal + sliding-window masks and tanh soft-capping
(gemma2). GQA is handled by the caller (kv expanded to q heads — the repeat
is free inside the kernel index_map: kv head index = h // group).

Validated on CPU via ``interpret=True`` against ``ref.flash_attention_ref``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], block_q: int, block_k: int,
            n_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # [bq, D]
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # [bk, D]
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]                               # [bq]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_new = alpha * l_scr[:, 0] + jnp.sum(p, axis=1)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
    m_scr[...] = m_new[:, None]
    l_scr[...] = l_new[:, None]

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_scr[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = True) -> jax.Array:
    """q,k,v: [B, S, H, D] (H = q heads; kv pre-expanded). -> [B, S, H, D]."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    n_q, n_k = Sq // block_q, Sk // block_k
    grid = (B, H, n_q, n_k)

    kern = functools.partial(
        _kernel, scale=1.0 / math.sqrt(D), causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, n_k=n_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, iq, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, iq, ik: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            _vmem((block_q, 1), jnp.float32),   # running max  m
            _vmem((block_q, 1), jnp.float32),   # running sum  l
            _vmem((block_q, D), jnp.float32),   # accumulator
        ],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel",
                                             "parallel", "arbitrary"))
        ) if not interpret else None,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
