"""Pallas TPU decode attention: one query token against a long KV cache.

Used by decode_32k / long_500k serving: for each (batch slot, head) the
kernel streams KV blocks HBM->VMEM and maintains the online-softmax
normaliser in VMEM, so the [B,H,S] score tensor never exists in HBM.
Per-slot valid lengths mask the tail; optional sliding window (gemma2 local
layers) and soft-capping.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, softcap: Optional[float], window: Optional[int],
            block_k: int, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :].astype(jnp.float32)               # [D]
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bk, D]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jnp.sum(k * q[None, :], axis=1) * scale          # [bk]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    length = len_ref[0]
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k, 1),
                                                   0)[:, 0]
    valid = kpos < length
    if window is not None:
        valid &= kpos >= (length - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)        # [bk]
    l_new = alpha * l_scr[0, 0] + jnp.sum(p)
    acc_scr[...] = acc_scr[...] * alpha + jnp.sum(
        p[:, None] * v, axis=0, keepdims=True)
    m_scr[0, 0] = m_new
    l_scr[0, 0] = l_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_scr[0, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :] = (acc_scr[0, :] / safe).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *,
                     softcap: Optional[float] = None,
                     window: Optional[int] = None,
                     block_k: int = 1024, interpret: bool = True) -> jax.Array:
    """q: [B,H,D]; k,v: [B,S,H,D]; lengths: [B] -> [B,H,D]."""
    B, S, H, D = k.shape
    block_k = min(block_k, S)
    assert S % block_k == 0
    n_k = S // block_k
    grid = (B, H, n_k)

    kern = functools.partial(_kernel, scale=1.0 / math.sqrt(D),
                             softcap=softcap, window=window,
                             block_k=block_k, n_k=n_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ik: (b,)),
            pl.BlockSpec((1, 1, D), lambda b, h, ik: (b, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, ik: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            _vmem((1, 1), jnp.float32),
            _vmem((1, 1), jnp.float32),
            _vmem((1, D), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel",
                                             "arbitrary"))
        ) if not interpret else None,
    )(lengths, q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
