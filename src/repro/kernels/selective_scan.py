"""Pallas TPU chunked selective scan (Mamba-1 recurrence).

h_t = a_t * h_{t-1} + b_t, elementwise over the flattened (d_inner x d_state)
feature dim. The kernel keeps the per-chunk [chunk, block_f] tiles plus the
carried state in VMEM; a_t/b_t never round-trip to HBM between timesteps —
this is the memory-roofline fix for the falcon-mamba/jamba train cells
(the XLA associative-scan path materialises [B,S,di,ds] f32 intermediates).

Grid: (B, F/block_f, S/chunk); the chunk axis is sequential ("arbitrary"),
carrying h in a VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref, h_scr, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, h):
        h = a_ref[0, t, :] * h + b_ref[0, t, :]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[0, :])
    h_scr[0, :] = h


def selective_scan(a: jax.Array, b: jax.Array, *, chunk: int = 256,
                   block_f: int = 1024, interpret: bool = True) -> jax.Array:
    """a, b: [B, S, DI, DS] f32 -> h [B, S, DI, DS] (see ref.py oracle)."""
    B, S, DI, DS = a.shape
    F = DI * DS
    af = a.reshape(B, S, F)
    bf = b.reshape(B, S, F)
    chunk = min(chunk, S)
    block_f = min(block_f, F)
    assert S % chunk == 0 and F % block_f == 0, (S, F, chunk, block_f)
    grid = (B, F // block_f, S // chunk)

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_f), lambda b_, jf, ic: (b_, ic, jf)),
            pl.BlockSpec((1, chunk, block_f), lambda b_, jf, ic: (b_, ic, jf)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_f),
                               lambda b_, jf, ic: (b_, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((B, S, F), a.dtype),
        scratch_shapes=[_vmem((1, block_f), jnp.float32)],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel",
                                             "arbitrary"))
        ) if not interpret else None,
    )(af, bf)
    return out.reshape(B, S, DI, DS)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
