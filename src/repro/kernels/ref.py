"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None) -> jax.Array:
    """q,k,v: [B, S, H, D] (kv already expanded to H heads). -> [B, S, H, D]."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    sc = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(D)
    if softcap is not None:
        sc = softcap * jnp.tanh(sc / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
    sc = jnp.where(mask, sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", pr, v.astype(jnp.float32)
                      ).astype(q.dtype)


def selective_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Linear recurrence h_t = a_t * h_{t-1} + b_t over axis 1.

    a, b: [B, S, DI, DS] f32 -> h: [B, S, DI, DS]."""
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2
    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array, *,
                         softcap: Optional[float] = None,
                         window: Optional[int] = None) -> jax.Array:
    """Single-query attention over a long KV cache.

    q: [B, H, D]; k,v: [B, S, H, D]; lengths: [B] (valid prefix per slot).
    -> [B, H, D]."""
    B, S, H, D = k.shape
    sc = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(D)
    if softcap is not None:
        sc = softcap * jnp.tanh(sc / softcap)
    kpos = jnp.arange(S)[None, :]
    valid = kpos < lengths[:, None]
    if window is not None:
        valid &= kpos >= (lengths[:, None] - window)
    sc = jnp.where(valid[:, None, :], sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", pr, v.astype(jnp.float32)
                      ).astype(q.dtype)
