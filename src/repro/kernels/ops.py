"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (the kernel
body executes in Python per grid step — correctness only); on TPU set
``REPRO_PALLAS_INTERPRET=0`` (or pass interpret=False) to compile via Mosaic.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax

from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import selective_scan as _ss


def _interpret_default() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: Optional[bool] = None):
    """q,k,v: [B,S,H,D]; kv heads must be pre-expanded to H (GQA repeat)."""
    interpret = _interpret_default() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, block_q=block_q,
                               block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "block_f", "interpret"))
def selective_scan(a, b, *, chunk: int = 256, block_f: int = 1024,
                   interpret: Optional[bool] = None):
    """Linear recurrence h_t = a_t h_{t-1} + b_t; a,b [B,S,DI,DS] f32."""
    interpret = _interpret_default() if interpret is None else interpret
    return _ss.selective_scan(a, b, chunk=chunk, block_f=block_f,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("softcap", "window", "block_k",
                                             "interpret"))
def decode_attention(q, k, v, lengths, *, softcap: Optional[float] = None,
                     window: Optional[int] = None, block_k: int = 1024,
                     interpret: Optional[bool] = None):
    """q [B,H,D]; k,v [B,S,H,D]; lengths [B] -> [B,H,D]."""
    interpret = _interpret_default() if interpret is None else interpret
    return _da.decode_attention(q, k, v, lengths, softcap=softcap,
                                window=window, block_k=block_k,
                                interpret=interpret)
