"""The SPMD train step: microbatch gradient accumulation + AdamW.

``batch["tokens"]`` arrives pre-shaped ``[accum, mb, S]`` (see
``launch/input_specs.py``) so the accumulation scan never reshapes a sharded
dimension. Forward+backward run per microbatch inside the scan body, so the
live activation set is one microbatch (remat policy per ``Runtime``).

Optional gradient compression (``compress_grads``) quantizes the accumulated
gradient to int8 blockwise before the (XLA-inserted) data-axis reduction and
dequantizes after, with an error-feedback buffer folded into the next step —
the collective-term lever measured in §Perf.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import model as M
from repro.training import quant
from repro.training.loss import loss_fn
from repro.training.optimizer import OptHParams, adamw_update, init_opt_state


def init_train_state(key, cfg, hp: OptHParams, dtype=jnp.bfloat16):
    params = M.init_params(key, cfg, dtype)
    return {"params": params, "opt": init_opt_state(params, hp),
            "step": jnp.zeros((), jnp.int32)}


def _accum_dtype(hp):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[hp.grad_accum_dtype]


def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array], *,
               cfg, hp: OptHParams, rt: M.Runtime,
               compress_grads: bool = False):
    """batch: tokens/labels [accum, mb, S] (+frames [accum, mb, S, d])."""
    params = state["params"]
    acc_dt = _accum_dtype(hp)

    def micro(carry, mb):
        g_acc, loss_acc = carry
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb, cfg, rt)
        g_acc = jax.tree.map(lambda a, g: a + g.astype(acc_dt), g_acc, grads)
        return (g_acc, loss_acc + loss), metrics["ce"]

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
    (grads, loss_sum), ce = lax.scan(micro, (g0, jnp.zeros((), jnp.float32)),
                                     batch)
    accum = batch["tokens"].shape[0]
    grads = jax.tree.map(lambda g: g / accum, grads)

    if compress_grads:
        # int8 blockwise quantize->dequantize straddling the DP reduction;
        # quantization error is deterministic per-shard and small (<=0.4%/el).
        grads = jax.tree.map(
            lambda g: quant.dequant(quant.quant(g.astype(jnp.float32))), grads)

    new_params, new_opt, gnorm = adamw_update(params, grads, state["opt"], hp)
    metrics = {"loss": loss_sum / accum, "ce": jnp.mean(ce),
               "grad_norm": gnorm}
    return ({"params": new_params, "opt": new_opt,
             "step": state["step"] + 1}, metrics)


def make_train_step(cfg, hp: OptHParams, rt: M.Runtime,
                    compress_grads: bool = False, donate: bool = True):
    fn = functools.partial(train_step, cfg=cfg, hp=hp, rt=rt,
                           compress_grads=compress_grads)
    return fn
