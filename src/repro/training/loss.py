"""Next-token cross-entropy loss (+ z-loss + MoE aux)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as M


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 1e-4):
    """logits [.., S, V] f32, labels [.., S] int32 (-1 = masked)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels.clip(0)[..., None],
                               axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - gold) * mask
    zl = z_loss * jnp.square(lse) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll.sum() + zl.sum()) / denom


def loss_fn(params, batch, cfg, rt: M.Runtime):
    """batch: tokens [B,S], labels [B,S] (+frames for enc-dec)."""
    logits, aux = M.forward(params, batch, cfg, rt)
    ce = cross_entropy(logits, batch["labels"])
    total = ce + rt.aux_loss_weight * aux
    return total, {"ce": ce, "moe_aux": aux}
