from repro.training.loss import cross_entropy, loss_fn
from repro.training.optimizer import OptHParams, adamw_update, init_opt_state
from repro.training.step import init_train_state, make_train_step, train_step
