"""Shape-preserving int8 quantization for optimizer moments and gradient
compression.

``QTensor`` keeps the int8 payload in the ORIGINAL parameter shape with one
f32 scale per last-dim row (shape[:-1] + (1,)). Shape preservation is the
point: the quantized buffers inherit the parameter's sharding unchanged, so
no reshape-induced resharding/all-gather appears in the update (a flat
[rows, 256] layout measured +3.8TB/chip of temp on arctic-480B from GSPMD
re-sharding the flat<->param reshapes).

Used for (a) int8 AdamW moments (memory-term lever: 1.25B/el vs 2B bf16 /
4B f32) and (b) int8 gradient all-reduce with bounded error
(collective-term lever). §Perf.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    q: jax.Array          # int8, original param shape
    scale: jax.Array      # f32, shape[:-1] + (1,)

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1])

    @property
    def shape(self):
        return self.q.shape


def is_qtensor(x) -> bool:
    return isinstance(x, QTensor)


def quant(x32: jax.Array, like: "QTensor | None" = None) -> QTensor:
    x32 = x32.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32), axis=-1, keepdims=True) / 127.0 + 1e-20
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale)


def dequant(t: QTensor) -> jax.Array:
    return t.q.astype(jnp.float32) * t.scale


def qzeros_like(p) -> QTensor:
    shape = p.shape
    return QTensor(jnp.zeros(shape, jnp.int8),
                   jnp.zeros(shape[:-1] + (1,), jnp.float32))
