"""Custom AdamW with configurable moment dtypes.

``moment_dtype=bf16`` halves optimizer-state HBM (used for the >=300B MoE
configs to fit 256 x 16GB); ``moment_dtype=int8`` enables the blockwise-
quantized (bnb-style) moments implemented in ``quant.py`` — a beyond-paper
memory-term optimization evaluated in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.training import quant


@dataclasses.dataclass(frozen=True)
class OptHParams:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    moment_dtype: str = "float32"       # "float32" | "bfloat16" | "int8"
    grad_accum_dtype: str = "float32"   # "float32" | "bfloat16"


def _moment_init(p, dtype_name):
    if dtype_name == "int8":
        return quant.qzeros_like(p)
    return jnp.zeros(p.shape, jnp.dtype(
        {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[dtype_name]))


def init_opt_state(params, hp: OptHParams) -> Dict[str, Any]:
    return {
        "m": jax.tree.map(lambda p: _moment_init(p, hp.moment_dtype), params),
        "v": jax.tree.map(lambda p: _moment_init(p, hp.moment_dtype), params),
        "count": jnp.zeros((), jnp.int32),
    }


def _read_moment(x, hp):
    if hp.moment_dtype == "int8":
        return quant.dequant(x)
    return x.astype(jnp.float32)


def _write_moment(x32, hp, like):
    if hp.moment_dtype == "int8":
        return quant.quant(x32, like)
    return x32.astype(like.dtype)


def schedule(count, hp: OptHParams):
    warm = jnp.minimum(count.astype(jnp.float32) / max(hp.warmup, 1), 1.0)
    return hp.lr * warm


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, hp: OptHParams):
    count = opt_state["count"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, hp.clip_norm / (gn + 1e-9))
    lr = schedule(count, hp)
    b1c = 1.0 - hp.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - hp.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = hp.b1 * _read_moment(m, hp) + (1 - hp.b1) * g
        v32 = hp.b2 * _read_moment(v, hp) + (1 - hp.b2) * jnp.square(g)
        mh = m32 / b1c
        vh = v32 / b2c
        step = mh / (jnp.sqrt(vh) + hp.eps) + hp.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return newp, _write_moment(m32, hp, m), _write_moment(v32, hp, v)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"], is_leaf=quant.is_qtensor)
    flat_v = jax.tree.leaves(opt_state["v"], is_leaf=quant.is_qtensor)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, gn
