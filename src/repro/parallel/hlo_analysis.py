"""Loop-aware static HLO analysis for the roofline.

XLA's ``compiled.cost_analysis()`` on this backend is (a) per-shard and
(b) NOT loop-aware — a ``lax.scan`` body is counted once, so a 64-layer
scanned model would be undercounted 64x (and the grad-accumulation loop on
top of that). This module parses ``compiled.as_text()`` (the post-SPMD,
per-device HLO) and computes, with while-loop trip-count multipliers:

  * ``dot_flops``       — 2 * prod(result) * prod(contracting dims), the MXU
                          work (elementwise flops are ignored: they are
                          bandwidth-, not compute-, limited).
  * ``memory_bytes``    — sum of (operand + result) bytes of every top-level
                          instruction (post-fusion => a fair HBM-traffic
                          model; fused subcomputations are internal).
  * ``collective_bytes``— wire bytes per chip with ring conventions:
                          all-gather / reduce-scatter / all-to-all:
                          (n-1)/n * full bytes; all-reduce: 2*(n-1)/n;
                          collective-permute: 1x.

Trip counts come from the ``backend_config={"known_trip_count":{"n":"K"}}``
tag that lax.scan lowering attaches, with a fallback to the constant in the
loop condition's ``compare``.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ring-wire factor given group size n
_RING = {
    "all-gather": lambda n: (n - 1) / max(n, 1),
    "reduce-scatter": lambda n: (n - 1) / max(n, 1),
    "all-to-all": lambda n: (n - 1) / max(n, 1),
    "all-reduce": lambda n: 2 * (n - 1) / max(n, 1),
    "collective-permute": lambda n: 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             "iota", "rng-bit-generator"}

# control/bookkeeping ops whose "operands" are whole carried states, not
# per-iteration HBM traffic
_NO_TRAFFIC = {"while", "conditional", "call", "tuple", "get-tuple-element",
               "parameter", "constant", "iota", "after-all",
               "optimization-barrier", "bitcast", "partition-id",
               "replica-id"}

# ops TPU fuses into their (single) consumer: intermediates stay in
# VMEM/registers
_FUSABLE = {"fusion", "convert", "broadcast", "multiply", "add", "subtract",
            "divide", "maximum", "minimum", "exponential", "tanh", "negate",
            "compare", "select", "and", "or", "not", "transpose", "reshape",
            "copy", "log", "rsqrt", "sqrt", "power", "abs", "sign", "clamp",
            "floor", "ceil", "slice", "reverse", "concatenate", "pad",
            "reduce", "dynamic-slice", "exponential-minus-one", "expm1",
            "log-plus-one"}


def _shapes_of(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of_type(type_str: str) -> int:
    total = 0
    for dt, dims in _shapes_of(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


class Instr:
    __slots__ = ("name", "comp", "opcode", "type_str", "rhs", "operands")

    def __init__(self, name, comp, opcode, type_str, rhs, operands):
        self.name, self.comp = name, comp
        self.opcode, self.type_str, self.rhs = opcode, type_str, rhs
        self.operands = operands

    @property
    def result_bytes(self) -> int:
        return _bytes_of_type(self.type_str)

    @property
    def result_dims(self) -> List[int]:
        s = _shapes_of(self.type_str)
        return s[0][1] if s else []


class HloModule:
    def __init__(self, text: str):
        self.instrs: Dict[Tuple[str, str], Instr] = {}
        self.comp_instrs: Dict[str, List[str]] = defaultdict(list)
        self.whiles: List[dict] = []
        self.calls: List[Tuple[str, str]] = []
        self._parse(text)
        self.multiplier = self._multipliers()

    # -- parsing ---------------------------------------------------------
    def _parse(self, text: str):
        comp = None
        for raw in text.splitlines():
            line = raw.strip()
            if not line:
                continue
            if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line)
                if m:
                    comp = m.group(1)
                continue
            if line == "}" or comp is None:
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            # result type: tuple "(...)" (may contain /*index=N*/ comments)
            # or array "f32[...]{layout}" — balanced-paren scan for tuples.
            if rhs.startswith("("):
                depth = 0
                end = -1
                for i, ch in enumerate(rhs):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            end = i
                            break
                if end < 0:
                    continue
                type_str, rest = rhs[:end + 1], rhs[end + 1:]
            else:
                tm = re.match(r"^([a-z0-9]+\[[0-9,]*\][^\s]*)", rhs)
                if not tm:
                    continue
                type_str, rest = tm.group(1), rhs[tm.end():]
            om = re.match(r"\s*([\w\-]+)\(", rest)
            if not om:
                continue
            opcode = om.group(1)
            # operand names: %foo refs inside the opcode's (...) group
            args = rest[rest.find("("):]
            depth = 0
            end = 0
            for i, ch in enumerate(args):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operands = re.findall(r"%([\w.\-]+)", args[:end + 1])
            ins = Instr(name, comp, opcode, type_str, rhs, operands)
            self.instrs[(comp, name)] = ins
            self.comp_instrs[comp].append(name)
            if opcode == "while":
                body = re.search(r"body=%?([\w.\-]+)", rhs)
                cond = re.search(r"condition=%?([\w.\-]+)", rhs)
                trip = None
                tc = re.search(r'known_trip_count[^0-9]*(\d+)', rhs)
                if tc:
                    trip = int(tc.group(1))
                if body and cond:
                    self.whiles.append({"comp": comp, "body": body.group(1),
                                        "cond": cond.group(1), "trip": trip})
            elif opcode == "fusion":
                to = re.search(r"calls=%?([\w.\-]+)", rhs)
                if to:
                    self.calls.append((comp, to.group(1), "fusion"))
            elif opcode in ("call", "custom-call", "async-start"):
                to = re.search(r"to_apply=%?([\w.\-]+)|called_computations=\{%?([\w.\-]+)\}", rhs)
                if to:
                    self.calls.append((comp, to.group(1) or to.group(2), "call"))
            elif opcode == "conditional":
                for t in re.finditer(r"branch_computations=\{([^}]*)\}|"
                                     r"(?:true|false)_computation=%?([\w.\-]+)", rhs):
                    tgt = t.group(1) or t.group(2)
                    if tgt:
                        for c in tgt.split(","):
                            self.calls.append((comp, c.strip().lstrip("%"), "call"))
            elif opcode in ("reduce", "map", "scatter", "select-and-scatter",
                            "sort", "reduce-window", "all-reduce"):
                to = re.search(r"to_apply=%?([\w.\-]+)", rhs)
                if to:
                    self.calls.append((comp, to.group(1), "apply"))

        # fill missing trip counts from condition constants
        for w in self.whiles:
            if w["trip"] is None:
                w["trip"] = self._cond_trip(w["cond"]) or 1

    def _cond_trip(self, cond: str) -> Optional[int]:
        consts = []
        for n in self.comp_instrs.get(cond, []):
            line = self.instrs[(cond, n)].rhs
            cm = re.search(r"constant\((\d+)\)", line)
            if cm:
                consts.append(int(cm.group(1)))
        return max(consts) if consts else None

    def _multipliers(self) -> Dict[str, int]:
        edges: List[Tuple[str, str, int]] = []
        for w in self.whiles:
            edges.append((w["comp"], w["body"], w["trip"]))
            edges.append((w["comp"], w["cond"], w["trip"]))
        for a, b, _kind in self.calls:
            edges.append((a, b, 1))
        callees = {b for _, b, _ in edges}
        work = {c: 1 for c in self.comp_instrs if c not in callees}
        for _ in range(128):
            changed = False
            for a, b, k in edges:
                if a in work:
                    val = work[a] * k
                    if work.get(b, 0) < val:
                        work[b] = val
                        changed = True
            if not changed:
                break
        return work

    def _operand_bytes(self, ins: Instr) -> int:
        total = 0
        for op in ins.operands:
            src = self.instrs.get((ins.comp, op))
            if src is not None:
                total += src.result_bytes
        return total

    def _operand_dims(self, ins: Instr, idx: int) -> Optional[List[int]]:
        if idx >= len(ins.operands):
            return None
        src = self.instrs.get((ins.comp, ins.operands[idx]))
        return src.result_dims if src is not None else None

    # -- metrics ---------------------------------------------------------
    def dot_flops(self) -> float:
        total = 0.0
        for (comp, _), ins in self.instrs.items():
            if ins.opcode != "dot":
                continue
            res = ins.result_dims
            n = 1
            for d in res:
                n *= d
            contract = 1
            cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rhs)
            lhs = self._operand_dims(ins, 0)
            if cm and lhs:
                for ci in cm.group(1).split(","):
                    if ci:
                        contract *= lhs[int(ci)]
            total += 2.0 * n * contract * self.multiplier.get(comp, 1)
        return total

    def _consumer_counts(self, comp: str) -> Dict[str, int]:
        counts: Dict[str, int] = defaultdict(int)
        for n in self.comp_instrs.get(comp, []):
            for op in self.instrs[(comp, n)].operands:
                counts[op] += 1
        return counts

    def memory_bytes(self) -> float:
        """Fusion-aware HBM traffic model.

        The CPU backend fuses far less than TPU, so raw per-instruction
        operand+result accounting overcounts ~10x. We model TPU producer
        fusion: an instruction whose opcode is fusable and that has exactly
        one consumer is *absorbed* into it — its intermediate never touches
        HBM; traffic is counted at non-absorbed ops as result bytes plus the
        transitive external inputs of their absorbed producer trees.
        """
        total = 0.0
        for comp in self.comp_instrs:
            if "fused_computation" in comp:
                continue   # internal to a fusion already
            counts = self._consumer_counts(comp)
            mul = self.multiplier.get(comp, 1)

            def absorbed(name: str) -> bool:
                ins = self.instrs.get((comp, name))
                return (ins is not None and ins.opcode in _FUSABLE
                        and counts[name] == 1)

            def external_inputs(ins: Instr, seen: set) -> float:
                b = 0.0
                for opn in ins.operands:
                    if opn in seen:
                        continue
                    seen.add(opn)
                    src = self.instrs.get((comp, opn))
                    if src is None:
                        continue
                    if absorbed(opn):
                        b += external_inputs(src, seen)
                    elif src.opcode not in _NO_TRAFFIC:
                        b += src.result_bytes
                return b

            for n in self.comp_instrs[comp]:
                ins = self.instrs[(comp, n)]
                if ins.opcode in _SKIP_OPS or ins.opcode in _NO_TRAFFIC \
                        or absorbed(n):
                    continue
                total += (ins.result_bytes + external_inputs(ins, set())) * mul
        return total

    def collective_bytes(self) -> Dict[str, float]:
        out = {k: 0.0 for k in COLLECTIVES}
        count = 0
        for (comp, _), ins in self.instrs.items():
            kind = None
            op = ins.opcode
            if op.endswith("-start"):
                op = op[:-6]
            if op.endswith("-done"):
                continue
            if op in COLLECTIVES:
                kind = op
            if kind is None:
                continue
            gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", ins.rhs)
            n = int(gm.group(2)) if gm else 1
            # bytes on the wire per chip
            if kind in ("all-gather", "all-to-all"):
                base = ins.result_bytes     # gathered/global size
            elif kind == "reduce-scatter":
                base = self._operand_bytes(ins)
            else:
                base = max(ins.result_bytes, self._operand_bytes(ins))
            mul = self.multiplier.get(comp, 1)
            out[kind] += _RING[kind](n) * base * mul
            count += mul
        out["count"] = count
        out["total"] = sum(out[k] for k in COLLECTIVES)
        return out

    def summary(self) -> Dict[str, float]:
        coll = self.collective_bytes()
        return {
            "dot_flops": self.dot_flops(),
            "memory_bytes": self.memory_bytes(),
            "collective_bytes": coll["total"],
            "collective_count": coll["count"],
            "collectives": {k: coll[k] for k in COLLECTIVES},
            "n_whiles": len(self.whiles),
            "trips": [w["trip"] for w in self.whiles],
        }


def analyze(hlo_text: str) -> Dict[str, float]:
    return HloModule(hlo_text).summary()
