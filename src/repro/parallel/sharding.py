"""Sharding rules: logical axes -> mesh axes, per strategy.

The model annotates params/caches with *logical* axis names
("embed", "heads", "expert", "kv_seq", ...). A ``ShardingStrategy`` maps
those to the production mesh:

  * TP   — heads / mlp / inner / vocab / expert(-internal) -> "model"
  * FSDP — the "embed" contraction dim -> "data" (+"pod") => ZeRO-3: XLA
           all-gathers params on use and reduce-scatters grads, overlapping
           with the layer scan.
  * EP   — experts -> "model" when n_experts divides the axis; otherwise
           TP-within-expert (expert_mlp -> "model"), e.g. grok's 8 experts
           on a 16-way axis.
  * SP   — decode KV caches shard their sequence dim over "model".
  * DP   — batch dims -> ("data",) or ("pod","data").
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.training import quant


@dataclasses.dataclass(frozen=True)
class ShardingStrategy:
    fsdp: bool = True
    tp: bool = True
    ep: bool = True
    seq_shard_decode: bool = True
    fsdp_axes: Tuple[str, ...] = ("data",)
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"

    @staticmethod
    def for_mesh(mesh: Mesh, *, fsdp: bool = True, ep: bool = True,
                 fsdp_over_pod: bool = False,
                 seq_shard_decode: bool = True) -> "ShardingStrategy":
        multi = "pod" in mesh.axis_names
        dp = ("pod", "data") if multi else ("data",)
        fa = (("pod", "data") if (multi and fsdp_over_pod) else ("data",))
        return ShardingStrategy(fsdp=fsdp, ep=ep, dp_axes=dp, fsdp_axes=fa,
                                seq_shard_decode=seq_shard_decode)


def make_rules(cfg, mesh: Mesh, strat: ShardingStrategy) -> dict:
    model_n = dict(zip(mesh.axis_names, mesh.devices.shape))[strat.tp_axis]
    rules = {
        None: None,
        "vocab": strat.tp_axis if strat.tp else None,
        "embed": strat.fsdp_axes if strat.fsdp else None,
        "heads": strat.tp_axis if strat.tp else None,
        "kv_heads": None,
        "head": None,
        "mlp": strat.tp_axis if strat.tp else None,
        "inner": strat.tp_axis if strat.tp else None,
        "layers": None,
        "batch": strat.dp_axes,
        "kv_seq": strat.tp_axis if strat.seq_shard_decode else None,
        "expert": None,
        "expert_mlp": None,
    }
    if cfg.moe is not None and strat.tp:
        if strat.ep and cfg.moe.n_experts % model_n == 0:
            rules["expert"] = strat.tp_axis          # true EP
        else:
            rules["expert_mlp"] = strat.tp_axis      # TP-within-expert
    return rules


def _to_pspec(axes: Tuple, rules: dict) -> P:
    return P(*(rules.get(a) for a in axes))


def logical_to_pspecs(logical_tree, rules: dict):
    return jax.tree.map(lambda ax: _to_pspec(ax, rules), logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def param_pspecs(cfg, rules: dict):
    return logical_to_pspecs(M.logical_specs(cfg), rules)


def opt_pspecs(cfg, rules: dict, moment_dtype: str):
    """Moment trees mirror params; int8 moments are shape-preserving
    (QTensor: q inherits the param spec; the per-row scale drops the last
    axis), so the state tree gets QTensor-structured spec nodes."""
    ps = param_pspecs(cfg, rules)
    if moment_dtype != "int8":
        return ps

    def to_q(spec: P):
        axes = tuple(spec)
        scale_axes = axes[:-1] + (None,) if axes else (None,)
        return quant.QTensor(spec, P(*scale_axes))

    return jax.tree.map(to_q, ps, is_leaf=lambda x: isinstance(x, P))


def state_pspecs(cfg, rules: dict, moment_dtype: str = "float32"):
    ps = param_pspecs(cfg, rules)
    return {
        "params": ps,
        "opt": {"m": opt_pspecs(cfg, rules, moment_dtype),
                "v": opt_pspecs(cfg, rules, moment_dtype),
                "count": P()},
        "step": P(),
    }


def cache_pspecs(cfg, rules: dict, batch_shardable: bool):
    r = dict(rules)
    if not batch_shardable:
        r["batch"] = None
    return logical_to_pspecs(M.cache_logical_specs(cfg), r)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def bytes_of(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))
