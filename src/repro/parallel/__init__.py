from repro.parallel.sharding import (ShardingStrategy, bytes_of, cache_pspecs,
                                     logical_to_pspecs, make_rules, named,
                                     opt_pspecs, param_pspecs, state_pspecs)
