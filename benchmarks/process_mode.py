"""Process-per-group vs thread-per-group on the UC1 straggler pipeline.

Two measurements:

  * **normal-processing overhead** — the UC1 pipeline (OP3 the straggler)
    run to completion in ``mode="thread"`` and ``mode="process"``; the
    derived column is the process-mode overhead %% vs thread mode.  The
    price of real process isolation is the pipe transport + store RPC per
    event; the straggler hides most of it, exactly like the paper's
    pessimistic logging hides behind OP3 (Sec. 9.3).
  * **recovery latency, non-blocking** — kill -9 the straggler's worker
    mid-run and poll the supervisor's cumulative per-operator counters:
    time from SIGKILL until OP3 processes again (warm restart + rollback
    recovery), and how many events the source pushed *while OP3 was dead*
    (> 0 == the paper's non-blocking property across real processes).

Run:  PYTHONPATH=src:. python benchmarks/process_mode.py [--quick]
                       [--json BENCH_process.json]
CSV:  name,us_per_call,derived
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

from benchmarks.uc1 import build_uc1
from repro.core import Engine
from repro.core.logstore import build_store


def _mk_store(spec: str, tag: str):
    if spec.startswith("sqlite"):
        d = tempfile.mkdtemp(prefix=f"procbench_{tag}_")
        return build_store(spec, path=os.path.join(d, "log.db"), shards=4,
                           batch_size=32, interval=0.005)
    return build_store(spec, shards=4, batch_size=32, interval=0.005)


def _run_once(build, mode: str, spec: str, timeout: float = 300.0) -> float:
    eng = Engine(build(), mode=mode, store=_mk_store(spec, mode))
    t0 = time.time()
    eng.start()
    ok = eng.wait(timeout)
    dt = time.time() - t0
    eng.stop()
    if not ok:
        raise TimeoutError(f"UC1 did not finish in mode={mode}")
    return dt


def normal_overhead(rows, *, n_events: int, repeats: int,
                    spec: str = "sqlite+sharded+group"):
    build = build_uc1(n_events=n_events, rate_s=0.1, op2_pt=0.05,
                      op3_pt=0.5, op3_window=2, op4_window=10, kb=4.0)
    base = None
    for mode in ("thread", "process"):
        best = min(_run_once(build, mode, spec) for _ in range(repeats))
        if mode == "thread":
            base = best
        over = 100.0 * (best - base) / base if base else float("nan")
        row = (f"process_mode/normal/{mode}", best * 1e6, round(over, 1))
        rows.append(row)
        print(f"{row[0]},{row[1]:.0f},{row[2]}", flush=True)


def recovery_latency(rows, *, n_events: int,
                     spec: str = "sqlite+sharded+group",
                     restart_delay: float = 0.25):
    build = build_uc1(n_events=n_events, rate_s=0.1, op2_pt=0.05,
                      op3_pt=0.5, op3_window=2, op4_window=10, kb=4.0)
    eng = Engine(build(), mode="process", store=_mk_store(spec, "rec"),
                 restart_delay=restart_delay)
    eng.start()
    # let the pipeline reach steady state, then kill the straggler's pod
    warmup_deadline = time.time() + 120.0
    while eng.process_stats().get("OP3", 0) < n_events // 8:
        if time.time() > warmup_deadline:
            eng.stop()
            raise TimeoutError("OP3 never reached steady state")
        time.sleep(0.01)
    at_kill = eng.process_stats()
    t_kill = time.time()
    eng.kill_group("OP3")
    # poll until OP3 processes events again (restart + rollback recovery)
    recovered_at = None
    src_during = 0
    while time.time() - t_kill < 60.0:
        stats = eng.process_stats()
        if stats.get("OP3", 0) > at_kill.get("OP3", 0):
            recovered_at = time.time()
            src_during = stats.get("OP1", 0) - at_kill.get("OP1", 0)
            break
        time.sleep(0.005)
    ok = eng.wait(300.0)
    eng.stop()
    if recovered_at is None or not ok:
        raise TimeoutError("OP3 never recovered")
    latency = recovered_at - t_kill
    rows.append(("process_mode/recovery/latency", latency * 1e6,
                 round(latency * 1e3, 1)))
    rows.append(("process_mode/recovery/src_events_during_outage",
                 float(src_during), src_during))
    assert eng.failures >= 1
    print(f"process_mode/recovery/latency,{latency * 1e6:.0f},"
          f"{latency * 1e3:.1f}ms", flush=True)
    print(f"process_mode/recovery/src_events_during_outage,"
          f"{src_during},{src_during}", flush=True)
    if src_during == 0:
        print("# WARNING: source made no progress during the outage",
              flush=True)


def run(rows, repeats: int = 2, full: bool = False, quick: bool = False):
    n = 80 if quick else (400 if full else 200)
    normal_overhead(rows, n_events=n, repeats=1 if quick else repeats)
    recovery_latency(rows, n_events=max(n, 160))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (seconds, not minutes)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--json", default=None,
                    help="also write rows as JSON (perf trajectory artifact)")
    args = ap.parse_args()
    rows = []
    print("name,us_per_call,derived")
    run(rows, repeats=args.repeats, full=args.full, quick=args.quick)
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us_per_call": u, "derived": d}
                       for n, u, d in rows], f, indent=2)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
