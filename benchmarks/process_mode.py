"""Process-per-group vs thread-per-group on the UC1 straggler pipeline,
plus the transport back-pressure sweep.

Measurements:

  * **normal-processing overhead** — the UC1 pipeline (OP3 the straggler)
    run to completion in ``mode="thread"`` and ``mode="process"`` (both
    transports); the derived column is the overhead %% vs thread mode.
    The price of real process isolation is the transport + store RPC per
    event; the straggler hides most of it, exactly like the paper's
    pessimistic logging hides behind OP3 (Sec. 9.3).  ``socket`` must be
    no worse than the ``routed`` hub-and-spoke baseline (events cross one
    socket instead of two supervisor pipes).
  * **recovery latency, non-blocking** — kill -9 the straggler's worker
    mid-run and poll the supervisor's cumulative per-operator counters:
    time from SIGKILL until OP3 processes again (warm restart + rollback
    recovery), and how many events the source pushed *while OP3 was dead*
    (> 0 == the paper's non-blocking property across real processes).
  * **back-pressure sweep** (``BENCH_transport.json``) — fast producer,
    slow consumer, per (transport x credit window): throughput, the peak
    number of events buffered in the supervisor, and the supervisor's
    peak RSS growth.  The point of credit-based flow control: a slow
    consumer bounds sender/supervisor memory at the window instead of
    growing the supervisor without bound (the pre-transport-layer
    ``force_put`` behaviour).  On the byte transports (socket/tcp/shm)
    the sweep also reports the wire-protocol counters: superframes,
    bytes, mean events per superframe, and the ack-coalescing ratio
    (control entries per control-carrying frame).

Run:  PYTHONPATH=src:. python benchmarks/process_mode.py [--quick]
                       [--json BENCH_process.json]
                       [--transport-json BENCH_transport.json]
CSV:  name,us_per_call,derived
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import threading
import time

from benchmarks.uc1 import build_uc1
from repro.core import (Engine, GeneratorSource, MapOperator, Pipeline,
                        ReadSource, TerminalSink)
from repro.core.logstore import build_store


def _mk_store(spec: str, tag: str):
    if spec.startswith("sqlite"):
        d = tempfile.mkdtemp(prefix=f"procbench_{tag}_")
        return build_store(spec, path=os.path.join(d, "log.db"), shards=4,
                           batch_size=32, interval=0.005)
    return build_store(spec, shards=4, batch_size=32, interval=0.005)


def _run_once(build, mode: str, spec: str, timeout: float = 300.0,
              transport=None) -> float:
    eng = Engine(build(), mode=mode, store=_mk_store(spec, mode),
                 transport=transport)
    t0 = time.time()
    eng.start()
    ok = eng.wait(timeout)
    dt = time.time() - t0
    eng.stop()
    if not ok:
        raise TimeoutError(f"UC1 did not finish in mode={mode}")
    return dt


def normal_overhead(rows, *, n_events: int, repeats: int,
                    spec: str = "sqlite+sharded+group"):
    build = build_uc1(n_events=n_events, rate_s=0.1, op2_pt=0.05,
                      op3_pt=0.5, op3_window=2, op4_window=10, kb=4.0)
    base = None
    for label, mode, transport in (("thread", "thread", None),
                                   ("process_routed", "process", "routed"),
                                   ("process_socket", "process", "socket")):
        best = min(_run_once(build, mode, spec, transport=transport)
                   for _ in range(repeats))
        if base is None:
            base = best
        over = 100.0 * (best - base) / base if base else float("nan")
        row = (f"process_mode/normal/{label}", best * 1e6, round(over, 1))
        rows.append(row)
        print(f"{row[0]},{row[1]:.0f},{row[2]}", flush=True)


def recovery_latency(rows, *, n_events: int,
                     spec: str = "sqlite+sharded+group",
                     restart_delay: float = 0.25):
    build = build_uc1(n_events=n_events, rate_s=0.1, op2_pt=0.05,
                      op3_pt=0.5, op3_window=2, op4_window=10, kb=4.0)
    eng = Engine(build(), mode="process", store=_mk_store(spec, "rec"),
                 restart_delay=restart_delay)
    eng.start()
    # let the pipeline reach steady state, then kill the straggler's pod
    warmup_deadline = time.time() + 120.0
    while eng.metrics().op("OP3").processed < n_events // 8:
        if time.time() > warmup_deadline:
            eng.stop()
            raise TimeoutError("OP3 never reached steady state")
        time.sleep(0.01)
    at_kill = eng.metrics()
    t_kill = time.time()
    eng.kill_group("OP3")
    # poll until OP3 processes events again (restart + rollback recovery)
    recovered_at = None
    src_during = 0
    while time.time() - t_kill < 60.0:
        m = eng.metrics()
        if m.op("OP3").processed > at_kill.op("OP3").processed:
            recovered_at = time.time()
            src_during = (m.op("OP1").processed
                          - at_kill.op("OP1").processed)
            break
        time.sleep(0.005)
    ok = eng.wait(300.0)
    eng.stop()
    if recovered_at is None or not ok:
        raise TimeoutError("OP3 never recovered")
    latency = recovered_at - t_kill
    rows.append(("process_mode/recovery/latency", latency * 1e6,
                 round(latency * 1e3, 1)))
    rows.append(("process_mode/recovery/src_events_during_outage",
                 float(src_during), src_during))
    assert eng.failures >= 1
    print(f"process_mode/recovery/latency,{latency * 1e6:.0f},"
          f"{latency * 1e3:.1f}ms", flush=True)
    print(f"process_mode/recovery/src_events_during_outage,"
          f"{src_during},{src_during}", flush=True)
    if src_during == 0:
        print("# WARNING: source made no progress during the outage",
              flush=True)


def _rss_kb() -> int:
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    return int(line.split()[1])
    except OSError:
        pass
    return 0


def _bp_build(n: int, window: int, sink_pt: float):
    def build():
        p = Pipeline()
        p.add(lambda: GeneratorSource(
            "src", ReadSource([{"v": i, "pad": "x" * 512}
                               for i in range(n)])))
        p.add(lambda: MapOperator("map", fn=lambda b: b))
        p.add(lambda: TerminalSink("sink", target=n, record=False,
                                   processing_time=sink_pt))
        p.connect("src", "out", "map", "in", capacity=window)
        p.connect("map", "out", "sink", "in", capacity=window)
        return p
    return build


def backpressure_sweep(rows, *, quick: bool = False,
                       windows=(8, 64, 512)):
    """Slow-consumer scenario per (transport x credit window): throughput,
    peak events buffered in the supervisor, peak supervisor RSS growth."""
    n = 400 if quick else 1500
    sink_pt = 0.001
    for transport in ("routed", "socket", "tcp", "shm"):
        for window in windows:
            eng = Engine(_bp_build(n, window, sink_pt)(), mode="process",
                         transport=transport, store="memory")
            rss0 = _rss_kb()
            peak = [0]
            rss_peak = [rss0]
            stop = threading.Event()

            def watch():
                while not stop.is_set():
                    peak[0] = max(peak[0], max((len(c) for c in
                                                eng.channels), default=0))
                    rss_peak[0] = max(rss_peak[0], _rss_kb())
                    time.sleep(0.002)
            t0 = time.time()
            eng.start()
            wt = threading.Thread(target=watch, daemon=True)
            wt.start()
            ok = eng.wait(300.0)
            dt = time.time() - t0
            stop.set()
            wt.join(timeout=5.0)
            tm = eng.metrics().transport
            eng.stop()
            if not ok:
                raise TimeoutError(
                    f"back-pressure run stalled ({transport}, w={window})")
            cols = [("throughput", dt * 1e6 / n, round(n / dt, 1)),
                    ("peak_sup_buffered", float(peak[0]), peak[0]),
                    ("peak_sup_rss_delta_kb", float(rss_peak[0] - rss0),
                     rss_peak[0] - rss0)]
            if tm.frames:
                # batching quality on the byte transports: how many events
                # ride each superframe, how many acks each control frame
                # coalesces, and the total wire volume
                epf = tm.events_per_frame
                apc = tm.ctrl_per_ctrl_frame
                cols += [("wire_frames", float(tm.frames), tm.frames),
                         ("wire_kb", tm.bytes / 1024.0,
                          round(tm.bytes / 1024.0, 1)),
                         ("events_per_frame", epf, round(epf, 2)),
                         ("acks_per_ctrl_frame", apc, round(apc, 2))]
            for suffix, us, derived in cols:
                name = f"transport/bp/{transport}/w{window}/{suffix}"
                rows.append((name, us, derived))
                print(f"{name},{us:.0f},{derived}", flush=True)


def run(rows, repeats: int = 2, full: bool = False, quick: bool = False):
    n = 80 if quick else (400 if full else 200)
    normal_overhead(rows, n_events=n, repeats=1 if quick else repeats)
    recovery_latency(rows, n_events=max(n, 160))
    backpressure_sweep(rows, quick=quick or not full,
                       windows=(8, 64) if quick else (8, 64, 512))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (seconds, not minutes)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--json", default=None,
                    help="also write rows as JSON (perf trajectory artifact)")
    ap.add_argument("--transport-json", default=None,
                    help="write the transport/back-pressure rows as JSON "
                         "(BENCH_transport.json artifact)")
    args = ap.parse_args()
    rows = []
    print("name,us_per_call,derived")
    run(rows, repeats=args.repeats, full=args.full, quick=args.quick)
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us_per_call": u, "derived": d}
                       for n, u, d in rows], f, indent=2)
        print(f"# wrote {args.json}", flush=True)
    if args.transport_json:
        tr = [r for r in rows if r[0].startswith("transport/")
              or "/normal/process_" in r[0]]
        with open(args.transport_json, "w") as f:
            json.dump([{"name": n, "us_per_call": u, "derived": d}
                       for n, u, d in tr], f, indent=2)
        print(f"# wrote {args.transport_json}", flush=True)


if __name__ == "__main__":
    main()
